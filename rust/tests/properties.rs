//! Property-based tests over the pure (non-PJRT) stack, driven by the
//! first-party shrinking driver in `fw_stage::util::proptest`.
//!
//! Invariants covered:
//! * solver agreement: blocked(s) == naive == parallel(s, t) for random
//!   graphs, tiles, and thread counts;
//! * APSP postconditions: triangle inequality, non-lengthening, zero diag,
//!   reachability closure (via `apsp::check_invariants`);
//! * layout transforms are bijections; tiled round-trip is exact;
//! * batch planning covers every ticket exactly once within bucket bounds;
//! * JSON round-trips arbitrary trees; wire codec round-trips requests;
//! * padding invariance: solving a padded graph preserves the corner.

use fw_stage::apsp;
use fw_stage::coordinator::batcher::{plan, BatchPolicy, Item};
use fw_stage::coordinator::types::{decode_request, encode_request, Request};
use fw_stage::graph::{generators, DistMatrix};
use fw_stage::layout;
use fw_stage::util::json::Json;
use fw_stage::util::prng::Rng;
use fw_stage::util::proptest::{check, Config};

/// Random graph scaled by the driver's size hint.
fn arb_graph(rng: &mut Rng, size: usize) -> DistMatrix {
    let n = 2 + rng.range(0, size.max(2));
    let density = rng.next_f64();
    generators::erdos_renyi_weighted(n, density, 0.1, 50.0, rng.next_u64())
}

#[test]
fn prop_blocked_matches_naive() {
    check("blocked == naive", Config { cases: 48, max_size: 72, ..Config::default() }, |rng, size| {
        let g = arb_graph(rng, size);
        let tile = [4, 8, 16, 32][rng.range(0, 4)];
        let naive = apsp::naive::solve(&g);
        let blocked = apsp::blocked::solve(&g, tile);
        if blocked.allclose(&naive, 1e-4, 1e-5) {
            Ok(())
        } else {
            Err(format!(
                "n={} tile={tile} max diff {}",
                g.n(),
                blocked.max_abs_diff(&naive)
            ))
        }
    });
}

#[test]
fn prop_parallel_matches_blocked_bitwise() {
    check("parallel == blocked", Config { cases: 32, max_size: 96, ..Config::default() }, |rng, size| {
        let g = arb_graph(rng, size);
        let tile = [8, 16][rng.range(0, 2)];
        let threads = 1 + rng.range(0, 6);
        let blocked = apsp::blocked::solve(&g, tile);
        let parallel = apsp::parallel::solve(&g, tile, threads);
        if blocked == parallel {
            Ok(())
        } else {
            Err(format!("n={} tile={tile} threads={threads}", g.n()))
        }
    });
}

#[test]
fn prop_apsp_invariants_hold() {
    check("APSP invariants", Config { cases: 32, max_size: 48, ..Config::default() }, |rng, size| {
        let g = arb_graph(rng, size);
        let d = apsp::blocked::solve(&g, 16);
        apsp::check_invariants(&g, &d).map_err(|e| format!("n={}: {e}", g.n()))
    });
}

#[test]
fn prop_padding_invariance() {
    check("padding invariance", Config { cases: 32, max_size: 48, ..Config::default() }, |rng, size| {
        let g = arb_graph(rng, size);
        let pad = g.n() + 1 + rng.range(0, 32);
        let solved_padded = apsp::naive::solve(&g.padded(pad)).truncated(g.n());
        let solved = apsp::naive::solve(&g);
        // identical relaxation order on the corner ⇒ bitwise equal
        if solved_padded == solved {
            Ok(())
        } else {
            Err(format!("n={} pad={pad}", g.n()))
        }
    });
}

#[test]
fn prop_paths_are_consistent() {
    check("path reconstruction", Config { cases: 24, max_size: 32, ..Config::default() }, |rng, size| {
        let g = arb_graph(rng, size);
        let r = apsp::paths::solve(&g);
        for i in 0..g.n() {
            for j in 0..g.n() {
                let d = r.dist.get(i, j);
                match r.path(i, j) {
                    Some(p) => {
                        if !d.is_finite() {
                            return Err(format!("path exists but dist inf ({i},{j})"));
                        }
                        if p[0] != i || *p.last().unwrap() != j {
                            return Err(format!("bad endpoints {p:?}"));
                        }
                        let w = r
                            .path_weight(&g, i, j)
                            .ok_or_else(|| format!("corrupt path {p:?}"))?;
                        if (w - d as f64).abs() > 1e-3 {
                            return Err(format!("weight {w} != dist {d} at ({i},{j})"));
                        }
                    }
                    None => {
                        if d.is_finite() && i != j {
                            return Err(format!("dist finite but no path ({i},{j})"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_layout_roundtrip() {
    check("doubly-tiled roundtrip", Config { cases: 24, max_size: 4, ..Config::default() }, |rng, size| {
        // n must be a multiple of s; s a multiple of t
        let t = [2, 4][rng.range(0, 2)];
        let s = t * [2, 4, 8][rng.range(0, 3)];
        let n = s * (1 + rng.range(0, size.max(1)));
        let data: Vec<f32> = (0..n * n).map(|_| rng.next_f32()).collect();
        let tiled = layout::to_doubly_tiled(&data, n, s, t);
        // bijection: sorted values identical
        let mut a = data.clone();
        let mut b = tiled.clone();
        a.sort_by(f32::total_cmp);
        b.sort_by(f32::total_cmp);
        if a != b {
            return Err(format!("not a permutation (n={n}, s={s}, t={t})"));
        }
        if layout::from_doubly_tiled(&tiled, n, s, t) != data {
            return Err(format!("roundtrip failed (n={n}, s={s}, t={t})"));
        }
        Ok(())
    });
}

#[test]
fn prop_batch_plan_is_partition() {
    check("batch plan partitions tickets", Config { cases: 64, max_size: 40, ..Config::default() }, |rng, size| {
        let buckets = [64usize, 128, 256, 512];
        let count = rng.range(1, size.max(2) + 1);
        let items: Vec<Item> = (0..count)
            .map(|i| Item {
                ticket: i as u64,
                n: 1 + rng.range(0, 700),
            })
            .collect();
        let policy = BatchPolicy {
            pack: rng.chance(0.7),
        };
        let batches = plan(&items, &buckets, &policy);
        let mut seen = vec![false; count];
        for b in &batches {
            let mut spans: Vec<(usize, usize)> = Vec::new();
            for p in &b.placements {
                if seen[p.ticket as usize] {
                    return Err(format!("ticket {} placed twice", p.ticket));
                }
                seen[p.ticket as usize] = true;
                if b.bucket > 0 {
                    if p.offset + p.n > b.bucket {
                        return Err(format!(
                            "placement {}+{} exceeds bucket {}",
                            p.offset, p.n, b.bucket
                        ));
                    }
                    // cost-model invariant: items run in their *natural*
                    // bucket — never escalated to a larger (Θ(b³)) one
                    let natural = buckets.iter().copied().find(|&bk| bk >= p.n);
                    if natural != Some(b.bucket) {
                        return Err(format!(
                            "item n={} (natural {:?}) placed in bucket {}",
                            p.n, natural, b.bucket
                        ));
                    }
                    spans.push((p.offset, p.offset + p.n));
                }
            }
            spans.sort();
            for w in spans.windows(2) {
                if w[0].1 > w[1].0 {
                    return Err(format!("overlapping placements {spans:?}"));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("ticket dropped from plan".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    fn arb_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::num((rng.next_f32() * 1000.0) as f64),
            3 => {
                let len = rng.range(0, 8);
                Json::Str((0..len).map(|_| ('a'..='z').nth(rng.range(0, 26)).unwrap()).collect())
            }
            4 => Json::Arr((0..rng.range(0, 4)).map(|_| arb_json(rng, depth - 1)).collect()),
            _ => Json::obj(
                (0..rng.range(0, 4))
                    .map(|i| {
                        let key = format!("k{i}");
                        (key, arb_json(rng, depth - 1))
                    })
                    .collect::<Vec<_>>()
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", Config { cases: 128, max_size: 4, ..Config::default() }, |rng, size| {
        let v = arb_json(rng, size.min(4));
        let text = v.to_string();
        match Json::parse(&text) {
            Ok(back) if back == v => Ok(()),
            Ok(back) => Err(format!("{v} reparsed as {back}")),
            Err(e) => Err(format!("{v} failed to reparse: {e}")),
        }
    });
}

#[test]
fn prop_wire_request_roundtrip() {
    check("wire request roundtrip", Config { cases: 32, max_size: 48, ..Config::default() }, |rng, size| {
        let graph = arb_graph(rng, size);
        let req = Request {
            id: rng.next_u64() % 1_000_000,
            graph,
            variant: ["staged", "blocked", "naive"][rng.range(0, 3)].to_string(),
            no_cache: rng.chance(0.5),
            want_paths: rng.chance(0.5),
            objective: ["shortest", "bottleneck", "minimax", "reachability"][rng.range(0, 4)]
                .to_string(),
            trace: rng.chance(0.5),
        };
        let back = decode_request(&encode_request(&req)).map_err(|e| e.to_string())?;
        if back.id != req.id || back.variant != req.variant || back.graph != req.graph {
            return Err("fields diverged".to_string());
        }
        if back.want_paths != req.want_paths {
            return Err("want_paths diverged".to_string());
        }
        if back.objective != req.objective {
            return Err("objective diverged".to_string());
        }
        if back.trace != req.trace {
            return Err("trace flag diverged".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_fw_monotone_in_edges() {
    // adding an edge can only shorten distances
    check("FW monotone in edges", Config { cases: 24, max_size: 40, ..Config::default() }, |rng, size| {
        let g = arb_graph(rng, size);
        let base = apsp::naive::solve(&g);
        let mut g2 = g.clone();
        let (i, j) = (rng.range(0, g.n()), rng.range(0, g.n()));
        if i != j {
            let w = rng.uniform(0.1, 5.0).min(g2.get(i, j));
            g2.set(i, j, w);
        }
        let improved = apsp::naive::solve(&g2);
        for a in 0..g.n() {
            for b in 0..g.n() {
                if improved.get(a, b) > base.get(a, b) + 1e-4 {
                    return Err(format!(
                        "adding edge lengthened d[{a}][{b}]: {} -> {}",
                        base.get(a, b),
                        improved.get(a, b)
                    ));
                }
            }
        }
        Ok(())
    });
}

//! Crash-safety and restart tests for the persistent closure store.
//!
//! Two layers:
//!
//! * Store + cache tests run artifact-free: they exercise the on-disk
//!   format, corruption quarantine, and warm-start round trips directly.
//!   "Kill and restart" is modeled as dropping one cache/store generation
//!   (the write-behind queue drained first) and opening a fresh one over
//!   the same directory — exactly what a process death plus re-exec does
//!   to the store's on-disk state.
//! * The coordinator-level test needs built artifacts and is skipped
//!   (with a notice) when `artifacts/` is absent, like the other
//!   integration suites.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use fw_stage::apsp;
use fw_stage::coordinator::cache::{graph_fingerprint, ResultCache};
use fw_stage::coordinator::metrics::Metrics;
use fw_stage::coordinator::store::{Store, StoreConfig};
use fw_stage::coordinator::{self, Coordinator};
use fw_stage::graph::generators;
use fw_stage::util::pool::{JobPool, PoolConfig};

/// Unique per-test scratch dir, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let path = std::env::temp_dir().join(format!(
            "fw-store-it-{tag}-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&path);
        TempDir(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// One store-backed cache "process generation" over `dir`.
fn generation(dir: &TempDir, capacity: usize) -> (ResultCache, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let store = Arc::new(
        Store::open(
            StoreConfig { dir: dir.0.clone(), max_bytes: 0 },
            metrics.clone(),
        )
        .expect("store opens"),
    );
    let writer = JobPool::new(PoolConfig {
        workers: 1,
        queue_depth: 64,
        name: "it-store".into(),
    });
    (ResultCache::with_store(capacity, store, writer), metrics)
}

fn counter(metrics: &Metrics, key: &str) -> usize {
    metrics.snapshot().get(key).as_usize().unwrap_or(0)
}

/// The single `.fwc` entry in `dir` (panics unless exactly one exists).
fn only_entry(dir: &Path) -> PathBuf {
    let entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|e| e == "fwc"))
        .collect();
    assert_eq!(entries.len(), 1, "expected exactly one store entry in {dir:?}");
    entries.into_iter().next().unwrap()
}

fn quarantine_count(dir: &Path) -> usize {
    std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.to_string_lossy().ends_with(".quarantine"))
        .count()
}

#[test]
fn kill_and_restart_round_trips_closures_bitwise() {
    let dir = TempDir::new("restart");
    let g_dist = generators::erdos_renyi(24, 0.4, 11);
    let g_pair = generators::erdos_renyi(24, 0.4, 12);
    let d = apsp::naive::solve(&g_dist);
    let r = apsp::paths::solve(&g_pair);
    {
        let (gen1, _) = generation(&dir, 8);
        gen1.put("staged", &g_dist, d.clone());
        gen1.put_paths("staged", &g_pair, r.dist.clone(), r.succ().to_vec());
        gen1.flush_store();
    } // process death

    // generation 2 over the same directory: both closures come back
    // bitwise — first via boot warm-start, then (generation 3, capacity
    // too small to warm everything) via request-path read-through
    let (gen2, metrics2) = generation(&dir, 8);
    assert_eq!(gen2.warm_from_store(), 2);
    let dist = gen2.get("staged", &g_dist).expect("distance closure survived");
    for (a, b) in dist.as_slice().iter().zip(d.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits(), "dist must round-trip bitwise");
    }
    let (pd, ps) = gen2.get_paths("staged", &g_pair).expect("paths pair survived");
    for (a, b) in pd.as_slice().iter().zip(r.dist.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(ps, r.succ(), "successors must round-trip exactly");
    assert!(counter(&metrics2, "store_hits") >= 2);
    assert_eq!(counter(&metrics2, "store_corrupt"), 0);
    drop(gen2);

    let (gen3, metrics3) = generation(&dir, 1);
    assert_eq!(gen3.warm_from_store(), 1, "capacity bounds the warm start");
    // whichever entry was not warmed reads through from disk on demand
    assert!(gen3.get("staged", &g_dist).is_some());
    assert!(gen3.get_paths("staged", &g_pair).is_some());
    assert!(counter(&metrics3, "store_hits") >= 2);
}

#[test]
fn chained_closures_rebaseline_across_generations() {
    // a delta chain's disk state: the chained entry (depth included)
    // must survive a restart so updates keep chaining from it
    let dir = TempDir::new("chain");
    let g = generators::erdos_renyi(16, 0.5, 21);
    let r = apsp::paths::solve(&g);
    let fp = graph_fingerprint(&g);
    {
        let (gen1, _) = generation(&dir, 8);
        gen1.put_chained("staged", &g, r.dist.clone(), Some(r.succ().to_vec()), 3);
        gen1.flush_store();
    }
    let (gen2, _) = generation(&dir, 8);
    let base = gen2.get_base("staged", g.n(), fp).expect("chained base survived");
    assert_eq!(base.chain, 3, "chain depth is part of the persisted state");
    assert_eq!(*base.graph, g);
    assert_eq!(*base.dist, r.dist);
    assert_eq!(base.succ.as_ref().map(|s| s.as_slice()), Some(r.succ()));
    // re-baselining writes a fresh chain-0 entry over the same key
    gen2.put_chained("staged", &g, r.dist.clone(), Some(r.succ().to_vec()), 0);
    gen2.flush_store();
    drop(gen2);
    let (gen3, _) = generation(&dir, 8);
    assert_eq!(gen3.get_base("staged", g.n(), fp).unwrap().chain, 0);
}

#[test]
fn flipped_byte_is_quarantined_and_resolved_clean() {
    let dir = TempDir::new("bitflip");
    let g = generators::erdos_renyi(16, 0.4, 31);
    let d = apsp::naive::solve(&g);
    {
        let (gen1, _) = generation(&dir, 8);
        gen1.put("staged", &g, d.clone());
        gen1.flush_store();
    }
    // flip one body byte: the checksum seal must catch it
    let path = only_entry(&dir.0);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, bytes).unwrap();

    let (gen2, metrics2) = generation(&dir, 8);
    assert_eq!(gen2.warm_from_store(), 0, "a corrupt entry must never warm the cache");
    assert!(gen2.get("staged", &g).is_none(), "a corrupt entry must never be served");
    assert_eq!(counter(&metrics2, "store_corrupt"), 1);
    assert_eq!(quarantine_count(&dir.0), 1, "the bad bytes are kept for post-mortem");
    // the miss falls through to a clean re-solve + re-persist
    gen2.put("staged", &g, d.clone());
    gen2.flush_store();
    assert_eq!(gen2.get("staged", &g), Some(d.clone()));
    drop(gen2);
    let (gen3, metrics3) = generation(&dir, 8);
    assert_eq!(gen3.warm_from_store(), 1, "the re-persisted entry is healthy");
    assert_eq!(counter(&metrics3, "store_corrupt"), 0);
}

#[test]
fn truncated_entry_is_quarantined_not_served() {
    let dir = TempDir::new("truncate");
    let g = generators::erdos_renyi(16, 0.4, 41);
    {
        let (gen1, _) = generation(&dir, 8);
        gen1.put("staged", &g, apsp::naive::solve(&g));
        gen1.flush_store();
    }
    // cut the file mid-body: a crash mid-write could leave this shape
    // only if the atomic temp+rename protocol were broken — the store
    // must treat it as corruption either way
    let path = only_entry(&dir.0);
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();

    let (gen2, metrics2) = generation(&dir, 8);
    assert!(gen2.get("staged", &g).is_none());
    assert_eq!(counter(&metrics2, "store_corrupt"), 1);
    assert_eq!(quarantine_count(&dir.0), 1);
}

#[test]
fn version_skew_is_quarantined_not_served() {
    let dir = TempDir::new("version");
    let g = generators::erdos_renyi(16, 0.4, 51);
    {
        let (gen1, _) = generation(&dir, 8);
        gen1.put("staged", &g, apsp::naive::solve(&g));
        gen1.flush_store();
    }
    // byte 4 is the format version: a downgrade reading a future format
    // must refuse rather than misinterpret the layout
    let path = only_entry(&dir.0);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[4] = 99;
    std::fs::write(&path, bytes).unwrap();

    let (gen2, metrics2) = generation(&dir, 8);
    assert!(gen2.get("staged", &g).is_none());
    assert_eq!(counter(&metrics2, "store_corrupt"), 1);
    assert_eq!(quarantine_count(&dir.0), 1);
}

#[test]
fn stale_tmp_from_a_crashed_write_is_swept_at_open() {
    let dir = TempDir::new("staletmp");
    std::fs::create_dir_all(&dir.0).unwrap();
    // a crash between temp-write and rename leaves exactly this debris
    std::fs::write(dir.0.join("deadbeef-8-staged.tmp"), b"partial write").unwrap();
    let (gen1, metrics1) = generation(&dir, 8);
    assert_eq!(counter(&metrics1, "store_corrupt"), 1, "the sweep is counted");
    assert!(
        !dir.0.join("deadbeef-8-staged.tmp").exists(),
        "stale temp files are removed, never decoded"
    );
    // the directory is fully usable afterwards
    let g = generators::ring(8);
    gen1.put("staged", &g, apsp::naive::solve(&g));
    gen1.flush_store();
    assert!(gen1.get("staged", &g).is_some());
}

// ---------------------------------------------- coordinator level --

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn coordinator_restart_serves_from_store_without_resolving() {
    let Some(artifacts) = artifact_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let dir = TempDir::new("coord");
    let g = generators::erdos_renyi(100, 0.3, 61);

    let mut config = coordinator::Config::new(&artifacts);
    config.store = Some(StoreConfig { dir: dir.0.clone(), max_bytes: 0 });
    let gen1 = Coordinator::start(config).expect("gen-1 coordinator");
    let resp1 = gen1.solve_graph(&g, "staged").expect("gen-1 solve");
    gen1.flush_store();
    drop(gen1); // process death

    let mut config = coordinator::Config::new(&artifacts);
    config.store = Some(StoreConfig { dir: dir.0.clone(), max_bytes: 0 });
    config.cache_capacity = 4;
    let gen2 = Coordinator::start(config).expect("gen-2 coordinator");
    let resp2 = gen2
        .solve(&coordinator::Request {
            id: 0,
            graph: g.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: false,
            objective: "shortest".into(),
            trace: false,
        })
        .expect("gen-2 solve");
    assert_eq!(resp2.source, coordinator::Source::Cache, "restart must not re-solve");
    for (a, b) in resp2.dist.as_slice().iter().zip(resp1.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits(), "restart must serve bitwise-identical state");
    }
    let snap = gen2.metrics().snapshot();
    assert!(snap.get("store_hits").as_usize().unwrap_or(0) >= 1);
    assert_eq!(snap.get("store_corrupt").as_usize().unwrap_or(1), 0);
    assert_eq!(snap.get("device_solves").as_usize().unwrap_or(1), 0);
    assert_eq!(snap.get("superblock_solves").as_usize().unwrap_or(1), 0);
    assert_eq!(snap.get("cpu_solves").as_usize().unwrap_or(1), 0);
}

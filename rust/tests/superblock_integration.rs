//! Super-block tier integration tests.
//!
//! The CPU-path tests run **without artifacts** (the tier's schedule,
//! pool, and exactness guarantees are device-independent), so CI's
//! artifact-free job covers them.  The coordinator-path tests need the
//! artifact manifest and skip politely when it is absent, like the rest of
//! the integration suite.

use std::path::PathBuf;
use std::sync::Arc;

use fw_stage::apsp;
use fw_stage::coordinator::{self, Config, Coordinator, Engine, EngineConfig, Source};
use fw_stage::graph::{generators, DistMatrix};
use fw_stage::superblock::{self, SuperBlockConfig};

fn sb(bucket: usize, workers: usize) -> SuperBlockConfig {
    SuperBlockConfig {
        bucket,
        workers,
        profile: false,
    }
}

// ---------------------------------------------------------- artifact-free --

/// The issue's exactness bar: n = 768 (a multiple of the 256 bucket) must
/// agree **bitwise** with `apsp::blocked` at the same tile size — the
/// super-blocked schedule performs the same f32 relaxations in a
/// dependency-equivalent order (see superblock module docs).
#[test]
fn n768_exactly_matches_blocked() {
    let g = generators::erdos_renyi(768, 0.03, 31);
    let oracle = apsp::blocked::solve(&g, 256);
    let (dist, report) = superblock::solve_cpu(&g, &sb(256, 0));
    assert_eq!(dist, oracle, "superblock diverges from apsp::blocked at n=768");
    assert_eq!(report.blocks, 3);
    assert_eq!(report.round_count(), 3);
    assert_eq!(report.total_tiles(), 3 * (4 + 4));
}

/// Non-multiple-of-bucket n: padded schedule, truncated result; bitwise
/// against the padded blocked oracle and close to the naive oracle.
#[test]
fn non_multiple_of_bucket_exact() {
    let g = generators::erdos_renyi(200, 0.15, 37);
    let (dist, report) = superblock::solve_cpu(&g, &sb(64, 4));
    assert_eq!(report.padded, 256);
    assert_eq!(report.blocks, 4);
    let oracle = apsp::blocked::solve(&g.padded(256), 64).truncated(200);
    assert_eq!(dist, oracle);
    assert!(dist.allclose(&apsp::naive::solve(&g), 1e-5, 1e-6));
}

/// Pool width must never change results (bitwise).
#[test]
fn pool_width_is_value_invariant() {
    let g = generators::scale_free(160, 2, 11);
    let (one, _) = superblock::solve_cpu(&g, &sb(32, 1));
    for workers in [2, 3, 8] {
        let (many, _) = superblock::solve_cpu(&g, &sb(32, workers));
        assert_eq!(one, many, "workers={workers}");
    }
}

// ------------------------------------------------------- need artifacts --

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn start() -> Option<Coordinator> {
    let dir = artifact_dir()?;
    let mut config = Config::new(&dir);
    config.engine.batch_window = std::time::Duration::from_millis(1);
    Some(Coordinator::start(config).expect("coordinator"))
}

macro_rules! with_coordinator {
    (|$coord:ident| $body:block) => {
        match start() {
            Some($coord) => $body,
            None => eprintln!("SKIP: artifacts/ not built (run `make artifacts`)"),
        }
    };
}

/// Regression for the pre-superblock hard error: an n = 1024 request
/// (larger than every artifact bucket, the old batcher `bucket == 0` case)
/// is now served through the coordinator, matches the `apsp::blocked`
/// closure, and hits the cache on repeat.
#[test]
fn oversized_request_served_and_cached() {
    with_coordinator!(|coord| {
        let g = generators::erdos_renyi(1024, 0.01, 41);
        let req = coordinator::Request {
            id: 9,
            graph: g.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: false,
            objective: "shortest".into(),
            trace: false,
        };
        let first = coord.solve(&req).expect("n=1024 must be served now");
        assert_eq!(first.source, Source::SuperBlock);
        assert_eq!(first.bucket, 256, "policy picks the parallel-friendly bucket");
        let oracle = apsp::blocked::solve(&g, 32);
        assert!(
            first.dist.allclose(&oracle, 1e-5, 1e-5),
            "superblock closure diverges from apsp::blocked by {}",
            first.dist.max_abs_diff(&oracle)
        );

        // repeat: served from the result cache, byte-identical
        let second = coord.solve(&req).unwrap();
        assert_eq!(second.source, Source::Cache);
        assert_eq!(second.dist, first.dist);

        let snap = coord.metrics().snapshot();
        assert_eq!(snap.get("superblock_solves").as_usize(), Some(1));
        assert_eq!(snap.get("superblock_rounds").as_usize(), Some(4));
        assert_eq!(snap.get("superblock_tiles").as_usize(), Some(4 * 15));
        assert!(snap.get("latency_p95_s").as_f64().is_some(), "{snap}");
    });
}

/// The explicit "superblock" pseudo-variant is honored at any n.
#[test]
fn explicit_superblock_variant() {
    with_coordinator!(|coord| {
        let g = generators::erdos_renyi(300, 0.1, 43);
        let resp = coord
            .solve(&coordinator::Request {
                id: 1,
                graph: g.clone(),
                variant: "superblock".into(),
                no_cache: true,
                want_paths: false,
                objective: "shortest".into(),
                trace: false,
            })
            .unwrap();
        assert_eq!(resp.source, Source::SuperBlock);
        assert_eq!(resp.bucket, 64); // min padding (320) with ≥3 blocks
        assert!(resp.dist.allclose(&apsp::naive::solve(&g), 1e-5, 1e-5));
    });
}

/// The engine itself still reports oversize on direct submits — the
/// batcher's `bucket == 0` contract is unchanged; only the coordinator's
/// routing in front of it grew the new tier.
#[test]
fn engine_direct_submit_still_reports_oversize() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let metrics = Arc::new(coordinator::metrics::Metrics::new());
    let engine = Engine::start(EngineConfig::new(&dir), metrics).expect("engine");
    let err = engine
        .solve("staged", DistMatrix::unconnected(1024))
        .unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("exceeds largest artifact bucket"),
        "engine oversize contract changed: {msg}"
    );
}

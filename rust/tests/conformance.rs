//! Cross-tier differential conformance suite.
//!
//! Every serving tier must be indistinguishable to a caller.  This suite
//! drives random graphs — with unreachable pairs, negative edges (no
//! negative cycles), and sizes that are *not* multiples of the tile or
//! bucket — through the naive, blocked, parallel, johnson, and superblock
//! solvers and pins two levels of agreement:
//!
//! * **bitwise** within the blocked family: `blocked(s)`, `parallel(s, t)`,
//!   and `superblock(bucket = s)` share relaxation order, so their
//!   distances must be identical to the last bit — including each tier's
//!   successor-tracking variant against its distance-only twin.  All three
//!   route phase 3 through the shared register-tiled microkernel
//!   (`apsp::kernel`), whose own bitwise contract against a scalar
//!   reference is pinned here too (phase 3 is a pure min-reduction over
//!   NaN-free, `-0.0`-free candidates, so register blocking cannot perturb
//!   a bit — the property that makes one kernel serve every tier);
//! * **tolerance** across algorithm families: naive FW and Johnson
//!   associate float additions differently, so they agree within
//!   `allclose` bounds, never bitwise.
//!
//! Successor agreement against the reference (`paths::solve`) is semantic,
//! not literal: float rounding can tie two distinct shortest paths, so each
//! tier's successor matrix must *reconstruct a valid walk of the reference
//! distance* (and agree exactly on reachability), not hop through the same
//! vertices.
//!
//! The **update-conformance** section gates the dynamic-graph tier
//! (`apsp::incremental`): random update batches — decrease-only,
//! increase-only, mixed, with no-op and duplicate-edge updates — applied
//! to a cached closure must reproduce a from-scratch
//! `parallel::solve_paths` of the mutated graph.  Distances are compared
//! **bitwise** on the dyadic-lattice workload (weights k/16: every path
//! sum is exact in f32, so any correct algorithm returns identical bits —
//! the one regime where bitwise equality across *different* algorithms is
//! a meaningful and complete oracle), and to `allclose` tolerance at
//! arbitrary float weights, where the incremental candidates associate
//! additions differently than a from-scratch pivot order.  Successors are
//! compared semantically (exact reachability agreement + valid walks of
//! the recomputed cost) — equal-cost ties may legally pick different
//! hops — and bitwise on the recompute fallback, which runs the oracle's
//! exact call.
//!
//! The suite also covers the serving surface: wire-protocol robustness for
//! `server::handle_line` (via a synthetic manifest, so it runs without
//! `make artifacts`), a client → server → cache paths round-trip,
//! update-request round-trips with fingerprint chaining, a cache
//! concurrency property (no torn `(dist, succ)` pairs under interleaved
//! puts), and batch-plan determinism (the cache-key contract).
//!
//! The **semiring conformance** section gates the generic refactor: the
//! generic kernel monomorphized at `(min, +)` is pinned bitwise against a
//! frozen copy of the pre-refactor specialized scalar loop (dist and succ,
//! packed and ragged, tile sizes {8, 16, 32, 33}); the selection-only
//! semirings — bottleneck `(max, min)`, minimax `(min, max)`,
//! reachability `(or, and)` — are compared with exact `==` against naive
//! generic FW and (for reachability) an independent BFS closure, since
//! their ⊕/⊗ always *select* an operand and never round.  The typed
//! `objective_unsupported` wire error and per-objective cache isolation
//! are pinned here too.
//!
//! The **observability regime** section gates the tracing layer: traced
//! requests take the profiled solver twins (`solve_profiled`,
//! `profile: true` super-block configs), whose `Instant` reads sit
//! *between* phases — so traced and untraced solves must be bitwise
//! identical, span shapes and route reasons are pinned over the wire, the
//! trace journal serves newest-first with source filters, and the
//! per-code error counters plus the Prometheus exposition round-trip
//! through `parse_exposition`.
//!
//! Every property here sizes its case count through
//! `util::proptest::env_cases`, so the dedicated CI conformance job can
//! run the same suites harder (`FW_PROPTEST_CASES=8`) without forking the
//! test code.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use fw_stage::apsp::incremental::{self, EdgeUpdate, UpdateConfig};
use fw_stage::apsp::semiring::{self, BoolOrAnd, MaxMin, MinMax, MinPlus, Objective, Semiring};
use fw_stage::apsp::{self, paths::PathsResult, paths::NO_PATH};
use fw_stage::coordinator::batcher::{plan, BatchPolicy, Item};
use fw_stage::coordinator::cache::{graph_fingerprint, ResultCache};
use fw_stage::coordinator::{self, server, types, Coordinator, Source, UpdateOutcome};
use fw_stage::graph::{generators, DistMatrix};
use fw_stage::superblock::{self, SuperBlockConfig};
use fw_stage::util::json::Json;
use fw_stage::util::prng::Rng;
use fw_stage::util::proptest::{check, env_cases, Config};
use fw_stage::INF;

// ------------------------------------------------------------ generators --

/// Measurement-free superblock config (the conformance suite pins the
/// profiled twin separately, in `prop_observability_is_bitwise_neutral`).
fn sb_cfg(bucket: usize, workers: usize) -> SuperBlockConfig {
    SuperBlockConfig {
        bucket,
        workers,
        profile: false,
    }
}

/// Random graph mixing the shapes the tiers must agree on: sparse digraphs
/// (unreachable pairs), dense digraphs, and layered DAGs with negative
/// edges but no negative cycles.
fn arb_graph(rng: &mut Rng, n: usize) -> DistMatrix {
    match rng.range(0, 3) {
        0 => generators::erdos_renyi_weighted(n, 0.08, 0.1, 10.0, rng.next_u64()),
        1 => generators::erdos_renyi_weighted(n, rng.next_f64(), 0.1, 10.0, rng.next_u64()),
        _ => {
            // layered DAG with negative edges, sized *exactly* n (the
            // bitwise test needs n to stay a multiple of the tile): use
            // the largest width in {4, 2, 1} that divides n
            let width = [4usize, 2, 1].into_iter().find(|w| n % w == 0).unwrap();
            generators::layered_dag(n / width, width, rng.next_u64())
        }
    }
}

/// Path-validity property: every reconstructed path is a real edge walk in
/// the *original* graph whose weight sum matches the reported distance,
/// endpoints are correct, and `NO_PATH` appears iff the distance is `+inf`.
fn assert_paths_valid(g: &DistMatrix, r: &PathsResult, label: &str) -> Result<(), String> {
    let n = g.n();
    if r.n() != n {
        return Err(format!("{label}: result size {} != {n}", r.n()));
    }
    for i in 0..n {
        for j in 0..n {
            let d = r.dist.get(i, j);
            if i == j {
                continue;
            }
            if (r.succ_at(i, j) == NO_PATH) != !d.is_finite() {
                return Err(format!("{label}: succ/dist reachability differs at ({i},{j})"));
            }
            match r.path(i, j) {
                Some(p) => {
                    if p[0] != i || *p.last().unwrap() != j {
                        return Err(format!("{label}: bad endpoints {p:?} for ({i},{j})"));
                    }
                    for hop in p.windows(2) {
                        if !g.get(hop[0], hop[1]).is_finite() {
                            return Err(format!(
                                "{label}: path ({i},{j}) uses non-edge {}->{}",
                                hop[0], hop[1]
                            ));
                        }
                    }
                    let w = r
                        .path_weight(g, i, j)
                        .ok_or_else(|| format!("{label}: corrupt path at ({i},{j})"))?;
                    let d = d as f64;
                    if (w - d).abs() > 1e-3 + 1e-4 * d.abs() {
                        return Err(format!("{label}: ({i},{j}) walk weight {w} != dist {d}"));
                    }
                }
                None => {
                    if d.is_finite() {
                        return Err(format!("{label}: dist finite but no path at ({i},{j})"));
                    }
                }
            }
        }
    }
    Ok(())
}

// -------------------------------------------- distance conformance (all) --

#[test]
fn prop_blocked_family_distances_bitwise_equal() {
    let cfg = Config { cases: env_cases(24), max_size: 4, ..Config::default() };
    check("blocked-family bitwise distances", cfg, |rng, size| {
        let s = [8, 16][rng.range(0, 2)];
        let n = s * (1 + rng.range(0, size.max(1))); // multiple of the tile
        let g = arb_graph(rng, n);
        let threads = 1 + rng.range(0, 4);
        let workers = 1 + rng.range(0, 4);

        let blocked = apsp::blocked::solve(&g, s);
        let parallel = apsp::parallel::solve(&g, s, threads);
        let (sb, _) = superblock::solve_cpu(&g, &sb_cfg(s, workers));
        let blocked_p = apsp::blocked::solve_paths(&g, s);
        let parallel_p = apsp::parallel::solve_paths(&g, s, threads);
        let (sb_p, _) = superblock::solve_paths(&g, &sb_cfg(s, workers));

        for (name, dist) in [
            ("parallel", &parallel),
            ("superblock", &sb),
            ("blocked_paths", &blocked_p.dist),
            ("parallel_paths", &parallel_p.dist),
            ("superblock_paths", &sb_p.dist),
        ] {
            if *dist != blocked {
                return Err(format!("{name} != blocked (n={n}, s={s}, t={threads})"));
            }
        }
        Ok(())
    });
}

// ------------------------------------------ microkernel bitwise contract --

// The scalar oracle is `apsp::kernel::minplus_panel_reference` — the one
// exported source of truth the register path is pinned against (the kernel
// unit tests use the same function).
use fw_stage::apsp::kernel::minplus_panel_reference as scalar_phase3;

/// `rows × stride` buffer with a `density` fraction of `+inf` entries —
/// the finiteness-guard stressor the kernel property sweeps over.
fn arb_kernel_panel(rng: &mut Rng, rows: usize, stride: usize, density: f64) -> Vec<f32> {
    let mut out = vec![f32::INFINITY; rows * stride];
    for v in out.iter_mut() {
        if rng.next_f64() >= density {
            *v = (rng.next_f64() * 20.0 - 5.0) as f32;
        }
    }
    out
}

#[test]
fn prop_microkernel_bitwise_vs_scalar_reference() {
    // the contract every tier's phase 3 now rests on: packed and unpacked,
    // succ and dist-only register tiling is bitwise equal to the scalar
    // loop across tile sizes (33 = ragged in both register dimensions) and
    // infinite-weight densities
    let cfg = Config { cases: env_cases(48), max_size: 4, ..Config::default() };
    check("microkernel vs scalar phase-3", cfg, |rng, _size| {
        let s = [8usize, 16, 32, 33][rng.range(0, 4)];
        let density = [0.0, 0.3, 0.9, 1.0][rng.range(0, 4)];
        let stride = s + rng.range(0, 40);
        let base = arb_kernel_panel(rng, s, stride, density);
        let col = arb_kernel_panel(rng, s, stride, density);
        let row = arb_kernel_panel(rng, s, stride, density);

        let mut expect = base.clone();
        scalar_phase3(&mut expect, stride, &col, stride, &row, stride, s, s, s);

        // unpacked (strided column panel)
        let mut got = base.clone();
        apsp::kernel::minplus_panel(&mut got, stride, &col, stride, &row, stride, s, s, s);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("strided kernel != scalar (s={s}, density={density})"));
        }

        // packed column panel (the §4.3 coalescing analog)
        let mut pack = apsp::kernel::PanelBuf::default();
        pack.pack_dist(&col, stride, s, s);
        let mut got = base.clone();
        apsp::kernel::minplus_panel(&mut got, stride, pack.dist(), s, &row, stride, s, s, s);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("packed kernel != scalar (s={s}, density={density})"));
        }

        // succ twin: distances must stay bitwise identical to the
        // distance-only kernel (accept order is the scalar order)
        let mut got = base.clone();
        let mut dsucc: Vec<usize> = (0..s * stride).collect();
        let colsucc: Vec<usize> = (0..s * stride).map(|v| v + 10_000).collect();
        apsp::kernel::minplus_panel_succ(
            &mut got, &mut dsucc, stride, &col, &colsucc, stride, &row, stride, s, s, s,
        );
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("succ kernel dist != scalar (s={s}, density={density})"));
        }

        // ragged remainder blocks (rows/cols/k straddling the register
        // tile; all bounded by the panel stride so views stay in range)
        let rr = 1 + rng.range(0, 9);
        let cc = 1 + rng.range(0, stride.min(17));
        let kk = rng.range(0, stride.min(13));
        let base = arb_kernel_panel(rng, rr, stride, density);
        let col = arb_kernel_panel(rng, rr, stride, density);
        let row = arb_kernel_panel(rng, kk.max(1), stride, density);
        let mut expect = base.clone();
        scalar_phase3(&mut expect, stride, &col, stride, &row, stride, rr, cc, kk);
        let mut got = base.clone();
        apsp::kernel::minplus_panel(&mut got, stride, &col, stride, &row, stride, rr, cc, kk);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("ragged kernel != scalar ({rr}x{cc}x{kk}, stride={stride})"));
        }
        Ok(())
    });
}

#[test]
fn prop_algorithm_families_distances_close() {
    let cfg = Config { cases: env_cases(24), max_size: 48, ..Config::default() };
    check("naive/johnson/blocked tolerance distances", cfg, |rng, size| {
        let n = 2 + rng.range(0, size.max(2));
        let g = arb_graph(rng, n);
        let s = 1 + rng.range(0, 24); // any tile: non-multiples pad + truncate
        let naive = apsp::naive::solve(&g);
        let blocked = apsp::blocked::solve(&g, s);
        if !blocked.allclose(&naive, 1e-4, 1e-4) {
            return Err(format!("blocked(s={s}) vs naive, n={n}"));
        }
        let johnson = apsp::johnson::solve(&g).map_err(|e| format!("johnson: {e}"))?;
        if !johnson.allclose(&naive, 1e-4, 1e-4) {
            return Err(format!("johnson vs naive, n={n}"));
        }
        // superblock pads non-multiple n internally
        let bucket = [8, 16][rng.range(0, 2)];
        let (sb, _) = superblock::solve_cpu(&g, &sb_cfg(bucket, 2));
        if !sb.allclose(&naive, 1e-4, 1e-4) {
            return Err(format!("superblock(b={bucket}) vs naive, n={n}"));
        }
        Ok(())
    });
}

// ----------------------------------------------- successor conformance --

#[test]
fn prop_every_path_tier_reconstructs_reference_distances() {
    let cfg = Config { cases: env_cases(16), max_size: 40, ..Config::default() };
    check("successor agreement vs paths::solve", cfg, |rng, size| {
        let n = 2 + rng.range(0, size.max(2));
        let g = arb_graph(rng, n);
        let s = [8, 16][rng.range(0, 2)]; // multiples and non-multiples both occur
        let reference = apsp::paths::solve(&g);

        let tiers: [(&str, PathsResult); 3] = [
            ("blocked", apsp::blocked::solve_paths(&g, s)),
            ("parallel", apsp::parallel::solve_paths(&g, s, 3)),
            (
                "superblock",
                superblock::solve_paths(&g, &sb_cfg(s, 2)).0,
            ),
        ];
        for (name, r) in &tiers {
            // validity of the tier's own reconstruction
            assert_paths_valid(&g, r, name)?;
            // exact reachability agreement with the reference
            for i in 0..n {
                for j in 0..n {
                    if (r.succ_at(i, j) == NO_PATH) != (reference.succ_at(i, j) == NO_PATH) {
                        return Err(format!("{name}: reachability differs at ({i},{j})"));
                    }
                }
            }
            // the tier's walk must cost the *reference* distance too
            // (ties may pick different hops; the total cannot differ)
            for i in 0..n {
                for j in 0..n {
                    if i == j {
                        continue;
                    }
                    if let Some(w) = r.path_weight(&g, i, j) {
                        let d = reference.dist.get(i, j) as f64;
                        if (w - d).abs() > 1e-3 + 1e-4 * d.abs() {
                            return Err(format!(
                                "{name}: walk ({i},{j}) costs {w}, reference dist {d}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_path_validity_holds_for_reference_solver() {
    // the reference itself must satisfy the validity property the tiers
    // are measured against
    let cfg = Config { cases: env_cases(16), max_size: 40, ..Config::default() };
    check("path validity (reference)", cfg, |rng, size| {
        let n = 2 + rng.range(0, size.max(2));
        let g = arb_graph(rng, n);
        assert_paths_valid(&g, &apsp::paths::solve(&g), "reference")
    });
}

// ---------------------------------------- update conformance (dynamic) --

/// Dyadic-lattice graph: weights k/16 with k ∈ [1, 2048].  Any sum of up
/// to ~40 such terms stays below 2¹⁸ lattice units — comfortably inside
/// f32's 24-bit mantissa — so every path sum is *exact* and any correct
/// APSP algorithm returns the same bits.  This is the one regime where
/// bitwise distance equality across different algorithms is a complete
/// correctness oracle, which is exactly what the update property needs.
fn arb_lattice_graph(rng: &mut Rng, n: usize, edge_p: f64) -> DistMatrix {
    let mut g = DistMatrix::unconnected(n);
    for i in 0..n {
        for j in 0..n {
            if i != j && rng.next_f64() < edge_p {
                g.set(i, j, (rng.range(1, 2049) as f32) * 0.0625);
            }
        }
    }
    g
}

/// Update batch of the given character (0 = decrease-only, 1 =
/// increase-only, 2 = mixed) against `g`, staying on the lattice.
/// Randomly appends an explicit no-op (rewrite an edge to its current
/// weight) and a duplicate-edge update (same endpoints twice; the last
/// write must win).
fn arb_lattice_batch(rng: &mut Rng, g: &DistMatrix, kind: usize) -> Vec<EdgeUpdate> {
    fn pick_pair(rng: &mut Rng, n: usize) -> (usize, usize) {
        let src = rng.range(0, n);
        let mut dst = rng.range(0, n - 1);
        if dst >= src {
            dst += 1;
        }
        (src, dst)
    }
    let n = g.n();
    let mut batch = Vec::new();
    for _ in 0..(1 + rng.range(0, 4)) {
        let (src, dst) = pick_pair(rng, n);
        let old = g.get(src, dst);
        let decrease = match kind {
            0 => true,
            1 => false,
            _ => rng.next_f64() < 0.5,
        };
        let weight = if decrease {
            if old.is_finite() {
                // at or below the current lattice weight (equality: no-op)
                (rng.range(1, (old * 16.0) as usize + 1) as f32) * 0.0625
            } else {
                (rng.range(1, 2049) as f32) * 0.0625 // insertion
            }
        } else if old.is_finite() && rng.next_f64() < 0.8 {
            // strictly above the current weight, ≤ 4096/16 (sums stay exact)
            (rng.range((old * 16.0) as usize + 1, (old * 16.0) as usize + 2049) as f32) * 0.0625
        } else {
            INF // deletion (a no-op when the edge does not exist)
        };
        batch.push(EdgeUpdate { src, dst, weight });
    }
    if rng.next_f64() < 0.5 {
        // explicit no-op: rewrite an edge to its current weight
        let (src, dst) = pick_pair(rng, n);
        let old = g.get(src, dst);
        batch.push(EdgeUpdate {
            src,
            dst,
            weight: if old.is_finite() { old } else { INF },
        });
    }
    if rng.next_f64() < 0.5 {
        // duplicate-edge update: re-issue the first target; the kind-pure
        // extremes (lattice minimum / deletion) can never flip the batch's
        // character, and the *last* write must win
        let first = batch[0];
        let weight = match kind {
            0 => 0.0625,
            1 => INF,
            _ => 0.5,
        };
        batch.push(EdgeUpdate { src: first.src, dst: first.dst, weight });
    }
    batch
}

#[test]
fn prop_incremental_update_bitwise_equals_recompute() {
    // THE update-conformance gate: for random lattice graphs and random
    // batches of every character, the incremental tier's distances are
    // bitwise-equal to a from-scratch parallel::solve_paths of the mutated
    // graph — across tile sizes {8, 16, 32, 33} (33 = the n < s reference
    // path for small n), edge/inf densities, thread counts, and all three
    // internal serving paths (pure relaxation, bounded re-solve, threshold
    // recompute — swept via recompute_fraction).
    let cfg = Config { cases: env_cases(36), max_size: 5, ..Config::default() };
    check("incremental update vs recompute (lattice, bitwise)", cfg, |rng, size| {
        let s = [8usize, 16, 32, 33][rng.range(0, 4)];
        let n = 4 + rng.range(0, 6 * size.max(1));
        let edge_p = [0.05, 0.3, 0.9][rng.range(0, 3)];
        let g = arb_lattice_graph(rng, n, edge_p);
        let threads = 1 + rng.range(0, 3);
        let base = apsp::parallel::solve_paths(&g, s, threads);
        let kind = rng.range(0, 3);
        let batch = arb_lattice_batch(rng, &g, kind);
        let ucfg = UpdateConfig {
            recompute_fraction: [0.0, 0.25, 1.0][rng.range(0, 3)],
            tile: s,
            threads,
        };
        let (got, stats) = incremental::update_paths(&g, &base, &batch, &ucfg)
            .map_err(|e| format!("update failed: {e}"))?;
        let g2 = incremental::mutated(&g, &batch).map_err(|e| format!("mutated: {e}"))?;
        let expect = apsp::parallel::solve_paths(&g2, s, threads);
        if got.dist != expect.dist {
            return Err(format!(
                "dist mismatch (n={n}, s={s}, kind={kind}, batch={batch:?}, stats={stats:?})"
            ));
        }
        // successors: bitwise reachability agreement, walks of the exact
        // recomputed cost (ties may pick different hops)
        for i in 0..n {
            for j in 0..n {
                if (got.succ_at(i, j) == NO_PATH) != (expect.succ_at(i, j) == NO_PATH) {
                    return Err(format!("reachability differs at ({i},{j})"));
                }
            }
        }
        assert_paths_valid(&g2, &got, "incremental")?;
        if stats.recomputed && got.succ() != expect.succ() {
            // the recompute fallback runs the oracle's exact call, so even
            // the successor matrix must match bit for bit there
            return Err("recompute path diverged in succ".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_update_close_on_arbitrary_floats() {
    // arbitrary float weights: the incremental candidates associate
    // additions differently than the from-scratch pivot order, so the
    // honest contract is tolerance + path validity, not bits
    let cfg = Config { cases: env_cases(16), max_size: 36, ..Config::default() };
    check("incremental update vs recompute (floats, tolerance)", cfg, |rng, size| {
        let n = 4 + rng.range(0, size.max(2));
        let g = generators::erdos_renyi_weighted(n, 0.25, 0.1, 10.0, rng.next_u64());
        let base = apsp::parallel::solve_paths(&g, 16, 2);
        let mut batch = Vec::new();
        for _ in 0..(1 + rng.range(0, 4)) {
            let src = rng.range(0, n);
            let mut dst = rng.range(0, n - 1);
            if dst >= src {
                dst += 1;
            }
            let weight = match rng.range(0, 3) {
                0 => (rng.next_f64() * 0.09 + 0.001) as f32, // below every weight
                1 => (rng.next_f64() * 30.0 + 10.0) as f32,  // above every weight
                _ => INF,                                    // deletion
            };
            batch.push(EdgeUpdate { src, dst, weight });
        }
        let ucfg = UpdateConfig { recompute_fraction: 0.25, tile: 16, threads: 2 };
        let (got, _) = incremental::update_paths(&g, &base, &batch, &ucfg)
            .map_err(|e| format!("update: {e}"))?;
        let g2 = incremental::mutated(&g, &batch).map_err(|e| format!("mutated: {e}"))?;
        let expect = apsp::parallel::solve_paths(&g2, 16, 2);
        if !got.dist.allclose(&expect.dist, 1e-4, 1e-4) {
            return Err(format!(
                "diverges by {} (n={n}, batch={batch:?})",
                got.dist.max_abs_diff(&expect.dist)
            ));
        }
        assert_paths_valid(&g2, &got, "incremental-float")
    });
}

// ---------------------------------------------- cache concurrency (pairs) --

#[test]
fn cache_concurrent_puts_never_split_pairs_or_serve_stale() {
    // Writers only ever insert members of a closed set of internally
    // consistent closures; readers assert every observation is a member.
    // Any torn write — a dist from one pair with the succ of another, a
    // dist-only put clobbering a cached successor matrix, or a chained
    // re-baseline handing out half-updated state — fails deterministically
    // under *any* thread interleaving (no timing assumptions).
    let graphs = [generators::ring(12), generators::erdos_renyi(12, 0.4, 99)];
    let mut pair_a = Vec::new();
    let mut pair_b = Vec::new();
    let mut lone = Vec::new();
    for g in &graphs {
        let a = apsp::blocked::solve_paths(g, 8);
        let b = apsp::paths::solve(g); // different solver: a distinct valid pair
        let mut c = a.dist.clone();
        let v = c.get(0, 1);
        c.set(0, 1, if v.is_finite() { v + 0.5 } else { 123.0 }); // recognizable lone dist
        pair_a.push(a);
        pair_b.push(b);
        lone.push(c);
    }
    let cache = ResultCache::new(4);
    std::thread::scope(|scope| {
        for t in 0..6u64 {
            let cache = &cache;
            let graphs = &graphs;
            let pair_a = &pair_a;
            let pair_b = &pair_b;
            let lone = &lone;
            scope.spawn(move || {
                let mut rng = Rng::new(0xCAC4E + t);
                for _ in 0..250 {
                    let gi = rng.range(0, graphs.len());
                    let g = &graphs[gi];
                    match rng.range(0, 6) {
                        0 => cache.put("v", g, lone[gi].clone()),
                        1 => cache.put_paths(
                            "v",
                            g,
                            pair_a[gi].dist.clone(),
                            pair_a[gi].succ().to_vec(),
                        ),
                        2 => cache.put_chained(
                            "v",
                            g,
                            pair_b[gi].dist.clone(),
                            Some(pair_b[gi].succ().to_vec()),
                            1 + t as u32,
                        ),
                        3 => {
                            if let Some((d, s)) = cache.get_paths("v", g) {
                                let ok = (d == pair_a[gi].dist && s == pair_a[gi].succ())
                                    || (d == pair_b[gi].dist && s == pair_b[gi].succ());
                                assert!(ok, "split (dist, succ) pair served");
                            }
                        }
                        4 => {
                            if let Some(base) = cache.get_base("v", g.n(), graph_fingerprint(g))
                            {
                                assert_eq!(*base.graph, *g, "base graph mismatch");
                                match &base.succ {
                                    Some(s) => {
                                        let ok = (*base.dist == pair_a[gi].dist
                                            && s.as_slice() == pair_a[gi].succ())
                                            || (*base.dist == pair_b[gi].dist
                                                && s.as_slice() == pair_b[gi].succ());
                                        assert!(ok, "stale or torn base closure");
                                    }
                                    None => assert_eq!(
                                        *base.dist, lone[gi],
                                        "dist-only base must be the lone closure"
                                    ),
                                }
                            }
                        }
                        _ => {
                            if let Some(d) = cache.get("v", g) {
                                assert!(
                                    d == pair_a[gi].dist || d == pair_b[gi].dist || d == lone[gi],
                                    "unknown distance closure served"
                                );
                            }
                        }
                    }
                }
            });
        }
    });
    // quiescent state: whatever pair won, it is still internally consistent
    for (gi, g) in graphs.iter().enumerate() {
        if let Some(base) = cache.get_base("v", g.n(), graph_fingerprint(g)) {
            if let Some(s) = &base.succ {
                let ok = (*base.dist == pair_a[gi].dist && s.as_slice() == pair_a[gi].succ())
                    || (*base.dist == pair_b[gi].dist && s.as_slice() == pair_b[gi].succ());
                assert!(ok);
            }
        }
    }
}

// --------------------------------------------------- batcher determinism --

#[test]
fn batcher_plan_is_deterministic_for_identical_inputs() {
    // the plan feeds the engine's packing (and through it which graphs
    // share a device call), so identical inputs must yield identical
    // layouts run after run — the cache-key contract depends on it
    let buckets = [64, 128, 256, 512];
    let policy = BatchPolicy::default();
    let mut rng = Rng::new(0xD37E_0001);
    for round in 0..32 {
        let items: Vec<Item> = (0..rng.range(1, 40))
            .map(|i| Item { ticket: i as u64, n: 1 + rng.range(0, 700) })
            .collect();
        let first = format!("{:?}", plan(&items, &buckets, &policy));
        for repeat in 0..5 {
            let again = format!("{:?}", plan(&items, &buckets, &policy));
            assert_eq!(first, again, "round {round} repeat {repeat} diverged");
        }
    }
}

#[test]
fn batcher_plan_pinned_layout() {
    // freeze one concrete layout: a change here silently re-shuffles which
    // graphs get co-packed and invalidates recorded batching behavior
    let items: Vec<Item> = [30usize, 100, 30, 300, 16, 16]
        .iter()
        .enumerate()
        .map(|(i, &n)| Item { ticket: i as u64, n })
        .collect();
    let batches = plan(&items, &[64, 128, 256, 512], &BatchPolicy::default());
    let layout: Vec<(usize, Vec<(u64, usize)>)> = batches
        .iter()
        .map(|b| (b.bucket, b.placements.iter().map(|p| (p.ticket, p.offset)).collect()))
        .collect();
    assert_eq!(
        layout,
        vec![
            // 64-bucket, first-fit-decreasing: 30+30 fill one call (60/64);
            // 16+16 open a second (16+16 would overflow the first)
            (64, vec![(0, 0), (2, 30)]),
            (64, vec![(4, 0), (5, 16)]),
            (128, vec![(1, 0)]),
            (512, vec![(3, 0)]),
        ]
    );
}

// ------------------------------------------- wire-protocol robustness --

static SYNTH_DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Start a coordinator against a synthetic single-artifact manifest, so the
/// serving surface is testable without `make artifacts`.  The fake HLO file
/// is never compiled (warm-up is disabled and the tests below never route
/// to the device tier).
fn synthetic_coordinator() -> Coordinator {
    synthetic_coordinator_with(|_| {})
}

/// [`synthetic_coordinator`] with a config tweak (chain caps, cache sizes).
fn synthetic_coordinator_with(tweak: impl FnOnce(&mut coordinator::Config)) -> Coordinator {
    let dir = std::env::temp_dir().join(format!(
        "fw-stage-conformance-{}-{}",
        std::process::id(),
        SYNTH_DIR_SEQ.fetch_add(1, Ordering::SeqCst)
    ));
    std::fs::create_dir_all(&dir).expect("create synthetic artifact dir");
    let hlo = "HLO placeholder (never compiled by these tests)\n";
    std::fs::write(dir.join("apsp_staged_n64.hlo.txt"), hlo).expect("write fake artifact");
    let manifest = format!(
        r#"{{"version": 2, "tile": 32, "artifacts": [
            {{"name": "apsp_staged_n64.hlo.txt", "variant": "staged", "n": 64,
              "tile": 32, "dtype": "f32", "input_shape": [64, 64],
              "output_shape": [64, 64], "bytes": {}}}]}}"#,
        hlo.len()
    );
    std::fs::write(dir.join("manifest.json"), manifest).expect("write manifest");
    let mut config = coordinator::Config::new(&dir);
    config.engine.warm_variants = Vec::new();
    tweak(&mut config);
    Coordinator::start(config).expect("synthetic coordinator")
}

/// Every failure mode must come back as the pinned error shape — a JSON
/// object with `type: "error"`, a numeric `id`, and a `message` — never a
/// panic or a dropped line.
fn assert_error_shape(reply: &str, expect_in_message: &str) {
    let v = Json::parse(reply).expect("error reply is valid JSON");
    assert_eq!(v.get("type").as_str(), Some("error"), "reply: {reply}");
    assert!(v.get("id").as_f64().is_some(), "error lacks id: {reply}");
    let msg = v.get("message").as_str().expect("error lacks message");
    assert!(
        msg.to_lowercase().contains(&expect_in_message.to_lowercase()),
        "message {msg:?} does not mention {expect_in_message:?}"
    );
}

#[test]
fn handle_line_malformed_json_returns_error_shape() {
    let coord = synthetic_coordinator();
    for line in ["{not json", "", "42", "\"solve\"", "{\"type\":\"solve\",\"n\":"] {
        let reply = server::handle_line(&coord, line);
        assert_error_shape(&reply, "");
    }
}

#[test]
fn handle_line_unknown_variant_returns_error_shape() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":7,"n":8,"variant":"warp9","edges":[]}"#,
    );
    assert_error_shape(&reply, "warp9");
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("id").as_f64(), Some(7.0), "id echoed for routable errors");
}

#[test]
fn handle_line_zero_size_graph_returns_error_shape() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(&coord, r#"{"type":"solve","n":0,"edges":[]}"#);
    assert_error_shape(&reply, "empty graph");
}

#[test]
fn handle_line_oversized_n_returns_error_shape() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(&coord, r#"{"type":"solve","n":999999,"edges":[]}"#);
    assert_error_shape(&reply, "exceeds server limit");
}

#[test]
fn handle_line_unknown_request_type_returns_error_shape() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(&coord, r#"{"type":"frobnicate"}"#);
    assert_error_shape(&reply, "unknown request type");
}

#[test]
fn handle_line_johnson_paths_rejected_cleanly() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":3,"n":8,"variant":"johnson","paths":true,"edges":[[0,1,1.0]]}"#,
    );
    assert_error_shape(&reply, "johnson");
}

#[test]
fn handle_line_cpu_solve_works_without_artifacts() {
    // the synthetic stack must still *serve* (CPU tier), proving the
    // robustness tests exercise a live coordinator, not a stub
    let coord = synthetic_coordinator();
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":5,"n":3,"edges":[[0,1,2.0],[1,2,3.0]]}"#,
    );
    let v = Json::parse(&reply).expect("valid JSON");
    assert_eq!(v.get("type").as_str(), Some("result"), "reply: {reply}");
    assert_eq!(v.get("source").as_str(), Some("cpu"));
}

// --------------------------------------- end-to-end paths over the wire --

#[test]
fn paths_roundtrip_client_server_cache() {
    // acceptance: a path-carrying request served through the coordinator
    // (client → server → cache hit on repeat) round-trips successors
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn(coord.clone(), "127.0.0.1:0").expect("server");
    let mut client =
        coordinator::client::Client::connect(&srv.addr().to_string()).expect("connect");

    let g = generators::erdos_renyi(24, 0.25, 404); // n ≤ cpu_threshold → CPU tier
    let first = client.solve_paths(&g, "staged").expect("paths solve");
    assert_ne!(first.source, Source::Cache);
    let succ = first.succ.clone().expect("successors present");
    let r = PathsResult::from_parts(first.dist.clone(), succ);
    assert_paths_valid(&g, &r, "wire").expect("wire paths valid");
    // the wire result must reconstruct exactly what the local tier computes
    let local = apsp::blocked::solve_paths(&g, 32);
    assert_eq!(r.dist, local.dist);
    assert_eq!(r.succ(), local.succ());

    // repeat: served from the cache, successors intact
    let second = client.solve_paths(&g, "staged").expect("cached paths solve");
    assert_eq!(second.source, Source::Cache);
    assert_eq!(second.dist, first.dist);
    assert_eq!(second.succ, first.succ);

    // a distance-only request for the same graph shares the cache entry
    let dist_only = client.solve(&g, "staged").expect("distance solve");
    assert_eq!(dist_only.source, Source::Cache);
    assert!(dist_only.succ.is_none(), "distance responses carry no succ");
    assert_eq!(dist_only.dist, first.dist);
}

// ------------------------------------------ updates over the wire --

#[test]
fn update_roundtrip_chains_through_server_and_cache() {
    let coord = synthetic_coordinator();
    let g = generators::erdos_renyi(24, 0.3, 606); // n ≤ cpu_threshold → CPU tier
    // prime: solve the base with paths, so the cached closure carries
    // successors and increases stay incremental
    let prime = server::handle_line(
        &coord,
        &types::encode_request(&coordinator::Request {
            id: 1,
            graph: g.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: true,
            objective: "shortest".into(),
            trace: false,
        }),
    );
    assert_eq!(Json::parse(&prime).unwrap().get("type").as_str(), Some("result"));

    let batch = vec![EdgeUpdate { src: 0, dst: 7, weight: 0.01 }];
    let reply = server::handle_line(
        &coord,
        &types::encode_update_request(&types::UpdateRequest {
            id: 2,
            variant: "staged".into(),
            n: g.n(),
            base_fingerprint: graph_fingerprint(&g),
            updates: batch.clone(),
            want_paths: true,
            objective: "shortest".into(),
        }),
    );
    let resp = types::decode_response(&reply).expect("update served");
    assert_eq!(resp.source, Source::Incremental);
    // the served closure is exactly what the local incremental tier
    // computes from the same base (same code path, same config)
    let base = apsp::blocked::solve_paths(&g, 32);
    let ucfg = UpdateConfig { tile: 32, ..UpdateConfig::default() };
    let (expect, _) = incremental::update_paths(&g, &base, &batch, &ucfg).unwrap();
    assert_eq!(resp.dist, expect.dist);
    assert_eq!(resp.succ.as_deref(), Some(expect.succ()));

    // chaining, leg 1: a plain solve of the *mutated* graph hits the cache
    let g2 = incremental::mutated(&g, &batch).unwrap();
    let hit = server::handle_line(
        &coord,
        &types::encode_request(&coordinator::Request {
            id: 3,
            graph: g2.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: true,
            objective: "shortest".into(),
            trace: false,
        }),
    );
    let hit = types::decode_response(&hit).expect("cache hit");
    assert_eq!(hit.source, Source::Cache);
    assert_eq!(hit.dist, expect.dist);

    // chaining, leg 2: a second delta against the mutated fingerprint is
    // itself served incrementally (the chain is cache-hittable)
    let batch2 = vec![EdgeUpdate { src: 3, dst: 11, weight: 0.02 }];
    let reply2 = server::handle_line(
        &coord,
        &types::encode_update_request(&types::UpdateRequest {
            id: 4,
            variant: "staged".into(),
            n: g2.n(),
            base_fingerprint: graph_fingerprint(&g2),
            updates: batch2.clone(),
            want_paths: false,
            objective: "shortest".into(),
        }),
    );
    let resp2 = types::decode_response(&reply2).expect("chained update served");
    assert_eq!(resp2.source, Source::Incremental);
    let (expect2, _) = incremental::update_paths(&g2, &expect, &batch2, &ucfg).unwrap();
    assert_eq!(resp2.dist, expect2.dist);
    assert!(resp2.succ.is_none(), "paths not requested");
}

#[test]
fn update_base_missing_is_typed_and_client_falls_back() {
    let coord = Arc::new(synthetic_coordinator());
    // server side: unknown fingerprint → the typed error, not a plain one
    let reply = server::handle_line(
        &coord,
        &types::encode_update_request(&types::UpdateRequest {
            id: 9,
            variant: "staged".into(),
            n: 8,
            base_fingerprint: 0xDEAD_BEEF,
            updates: vec![EdgeUpdate { src: 0, dst: 1, weight: 1.0 }],
            want_paths: false,
            objective: "shortest".into(),
        }),
    );
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("type").as_str(), Some("error"));
    assert_eq!(v.get("code").as_str(), Some(types::CODE_UPDATE_BASE_MISSING));
    assert_eq!(v.get("id").as_f64(), Some(9.0));

    // client side: update_or_solve transparently re-solves the mutated
    // graph on the miss — and *that* primes the cache, so the next delta
    // against the mutated graph is served incrementally
    let srv = server::Server::spawn(coord.clone(), "127.0.0.1:0").expect("server");
    let mut client =
        coordinator::client::Client::connect(&srv.addr().to_string()).expect("connect");
    let g = generators::erdos_renyi(16, 0.3, 707);
    let batch = vec![EdgeUpdate { src: 1, dst: 2, weight: 0.01 }];
    let resp = client
        .update_or_solve(&g, &batch, "staged", false)
        .expect("fallback");
    assert_ne!(resp.source, Source::Incremental, "fresh server must miss");
    let g2 = incremental::mutated(&g, &batch).unwrap();
    assert_eq!(resp.dist, apsp::blocked::solve(&g2, 32));
    let resp2 = client
        .update_or_solve(&g2, &[EdgeUpdate { src: 2, dst: 3, weight: 0.02 }], "staged", false)
        .expect("chained");
    assert_eq!(resp2.source, Source::Incremental);
}

#[test]
fn chain_cap_rebaselines_through_a_full_solve() {
    let coord = synthetic_coordinator_with(|c| c.update_max_chain = 1);
    let g = generators::erdos_renyi(20, 0.3, 808);
    coord
        .solve(&coordinator::Request {
            id: 0,
            graph: g.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: true,
            objective: "shortest".into(),
            trace: false,
        })
        .expect("prime");
    let solve_update = |base: &DistMatrix, batch: &[EdgeUpdate]| {
        match coord
            .update(&types::UpdateRequest {
                id: 0,
                variant: "staged".into(),
                n: base.n(),
                base_fingerprint: graph_fingerprint(base),
                updates: batch.to_vec(),
                want_paths: false,
                objective: "shortest".into(),
            })
            .expect("update")
        {
            UpdateOutcome::Solved(resp) => resp,
            UpdateOutcome::BaseMissing { .. } => panic!("base should be cached"),
        }
    };
    // chain 1: incremental
    let b1 = vec![EdgeUpdate { src: 0, dst: 5, weight: 0.01 }];
    let r1 = solve_update(&g, &b1);
    assert_eq!(r1.source, Source::Incremental);
    let g2 = incremental::mutated(&g, &b1).unwrap();
    // chain 2 > cap: re-baselined by a full solve of the mutated graph —
    // still reported as the update tier, closure bitwise-equal to the CPU
    // tier's from-scratch solve, and cached with a fresh chain
    let b2 = vec![EdgeUpdate { src: 1, dst: 6, weight: 0.02 }];
    let r2 = solve_update(&g2, &b2);
    let g3 = incremental::mutated(&g2, &b2).unwrap();
    assert_eq!(r2.source, Source::Incremental);
    assert_eq!(r2.dist, apsp::blocked::solve_paths(&g3, 32).dist);
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.get("update_recomputes").as_usize(), Some(1));
    // chain restarts at the fresh baseline: next delta is incremental again
    let b3 = vec![EdgeUpdate { src: 2, dst: 7, weight: 0.03 }];
    let r3 = solve_update(&g3, &b3);
    assert_eq!(r3.source, Source::Incremental);
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.get("update_recomputes").as_usize(), Some(1), "no second re-baseline");
    assert_eq!(snap.get("incremental_solves").as_usize(), Some(3));
}

#[test]
fn handle_line_update_error_shapes() {
    let coord = synthetic_coordinator();
    // malformed deltas keep the pinned error shape
    let reply = server::handle_line(
        &coord,
        r#"{"type":"update","n":8,"base":"00ff","updates":[[1,1,2.0]]}"#,
    );
    assert_error_shape(&reply, "self-loop");
    let reply = server::handle_line(&coord, r#"{"type":"update","n":8,"updates":[]}"#);
    assert_error_shape(&reply, "base");
    let reply = server::handle_line(
        &coord,
        r#"{"type":"update","n":8,"base":"00ff","updates":[[0,9,1.0]]}"#,
    );
    assert_error_shape(&reply, "out of range");
    // johnson is rejected by policy before any cache traffic, id echoed
    let reply = server::handle_line(
        &coord,
        r#"{"type":"update","id":4,"n":8,"variant":"johnson","base":"00ff","updates":[[0,1,2.0]]}"#,
    );
    assert_error_shape(&reply, "johnson");
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("id").as_f64(), Some(4.0));
    assert!(v.get("code").is_null(), "only base-miss errors are typed");
}

#[test]
fn paths_through_coordinator_superblock_tier() {
    // explicit superblock variant with the synthetic 64-bucket: path mode
    // runs CPU diagonal solves, so no artifact execution is needed
    let coord = synthetic_coordinator();
    let g = generators::erdos_renyi(100, 0.1, 505); // pads to 128, 2×2 grid
    let resp = coord
        .solve(&coordinator::Request {
            id: 11,
            graph: g.clone(),
            variant: "superblock".into(),
            no_cache: false,
            want_paths: true,
            objective: "shortest".into(),
            trace: false,
        })
        .expect("superblock paths solve");
    assert_eq!(resp.source, Source::SuperBlock);
    assert_eq!(resp.bucket, 64);
    let r = PathsResult::from_parts(resp.dist.clone(), resp.succ.clone().expect("succ"));
    assert_paths_valid(&g, &r, "superblock-coordinator").expect("valid paths");
    // distances bitwise vs the CPU superblock tier at the same bucket
    let (oracle, _) = superblock::solve_cpu(&g, &sb_cfg(64, 0));
    assert_eq!(r.dist, oracle);
}

// ------------------------------------------------ semiring conformance --

/// The exact phase-3 inner loop the specialized `(min, +)` tiers shipped
/// before the semiring refactor, frozen verbatim (finiteness guard,
/// strict `<` conditional store, i-k-j order).  Deliberately NOT written
/// via `Semiring` — it is the independent record of the pre-refactor
/// arithmetic the generic kernel must reproduce bit for bit.
#[allow(clippy::too_many_arguments)]
fn frozen_minplus_phase3(
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    for r in 0..rows {
        for k in 0..kk {
            let a = col[r * col_stride + k];
            if !a.is_finite() {
                continue;
            }
            for c in 0..cols {
                let cand = a + row[k * row_stride + c];
                if cand < dst[r * dst_stride + c] {
                    dst[r * dst_stride + c] = cand;
                }
            }
        }
    }
}

/// Successor-tracking twin of [`frozen_minplus_phase3`]: the strict accept
/// copies the column-panel successor, exactly as the pre-refactor succ
/// kernels did.
#[allow(clippy::too_many_arguments)]
fn frozen_minplus_phase3_succ(
    dst: &mut [f32],
    dsucc: &mut [usize],
    dst_stride: usize,
    col: &[f32],
    colsucc: &[usize],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    for r in 0..rows {
        for k in 0..kk {
            let a = col[r * col_stride + k];
            if !a.is_finite() {
                continue;
            }
            for c in 0..cols {
                let cand = a + row[k * row_stride + c];
                if cand < dst[r * dst_stride + c] {
                    dst[r * dst_stride + c] = cand;
                    dsucc[r * dst_stride + c] = colsucc[r * col_stride + k];
                }
            }
        }
    }
}

#[test]
fn prop_generic_minplus_kernel_bitwise_equals_frozen_specialized() {
    // THE refactor gate: `panel::<MinPlus>` / `panel_succ::<MinPlus>` (the
    // code every tier now monomorphizes) against the frozen pre-refactor
    // loop — square tiles {8, 16, 32, 33}, packed column panels, ragged
    // remainders, dist AND succ, across inf densities
    let cfg = Config { cases: env_cases(32), max_size: 4, ..Config::default() };
    check("generic (min,+) vs frozen specialized", cfg, |rng, _size| {
        let s = [8usize, 16, 32, 33][rng.range(0, 4)];
        let density = [0.0, 0.4, 1.0][rng.range(0, 3)];
        let stride = s + rng.range(0, 24);
        let base = arb_kernel_panel(rng, s, stride, density);
        let col = arb_kernel_panel(rng, s, stride, density);
        let row = arb_kernel_panel(rng, s, stride, density);

        let mut expect = base.clone();
        frozen_minplus_phase3(&mut expect, stride, &col, stride, &row, stride, s, s, s);

        let mut got = base.clone();
        apsp::kernel::panel::<MinPlus>(&mut got, stride, &col, stride, &row, stride, s, s, s);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("generic panel != frozen (s={s}, density={density})"));
        }

        // packed column panel
        let mut pack = apsp::kernel::PanelBuf::default();
        pack.pack_dist(&col, stride, s, s);
        let mut got = base.clone();
        apsp::kernel::panel::<MinPlus>(&mut got, stride, pack.dist(), s, &row, stride, s, s, s);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("generic packed panel != frozen (s={s})"));
        }

        // succ twin: values AND successors must both match the frozen loop
        let succ0: Vec<usize> = (0..s * stride).collect();
        let colsucc: Vec<usize> = (0..s * stride).map(|v| v + 40_000).collect();
        let (mut edist, mut esucc) = (base.clone(), succ0.clone());
        frozen_minplus_phase3_succ(
            &mut edist, &mut esucc, stride, &col, &colsucc, stride, &row, stride, s, s, s,
        );
        let (mut gdist, mut gsucc) = (base.clone(), succ0);
        apsp::kernel::panel_succ::<MinPlus>(
            &mut gdist, &mut gsucc, stride, &col, &colsucc, stride, &row, stride, s, s, s,
        );
        if gdist.iter().zip(&edist).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("generic succ panel dist != frozen (s={s})"));
        }
        if gsucc != esucc {
            return Err(format!("generic succ panel successors != frozen (s={s})"));
        }

        // ragged remainder blocks
        let rr = 1 + rng.range(0, 7);
        let cc = 1 + rng.range(0, stride.min(11));
        let kk = rng.range(0, stride.min(9));
        let base = arb_kernel_panel(rng, rr, stride, density);
        let col = arb_kernel_panel(rng, rr, stride, density);
        let row = arb_kernel_panel(rng, kk.max(1), stride, density);
        let mut expect = base.clone();
        frozen_minplus_phase3(&mut expect, stride, &col, stride, &row, stride, rr, cc, kk);
        let mut got = base.clone();
        apsp::kernel::panel::<MinPlus>(&mut got, stride, &col, stride, &row, stride, rr, cc, kk);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("generic ragged != frozen ({rr}x{cc}x{kk})"));
        }
        Ok(())
    });
}

/// Every tier solving a *selection-only* semiring must agree with naive
/// generic FW with exact `==` — ⊕/⊗ return an operand, so no order of
/// relaxation can perturb a bit (the module-doc argument).
fn selection_tiers_agree<S: Semiring>(
    rng: &mut Rng,
    size: usize,
    obj: Objective,
) -> Result<(), String> {
    let n = 3 + rng.range(0, 8 * size.max(1));
    let g = generators::erdos_renyi_weighted(n, 0.25, 0.1, 10.0, rng.next_u64());
    let prepared = obj.prepare(&g)?;
    let oracle = apsp::naive::solve_semiring::<S>(&prepared);
    let s = [8usize, 16, 33][rng.range(0, 3)];
    let threads = 1 + rng.range(0, 3);
    if apsp::blocked::solve_semiring::<S>(&prepared, s) != oracle {
        return Err(format!("{}: blocked(s={s}) != naive (n={n})", S::NAME));
    }
    if apsp::parallel::solve_semiring::<S>(&prepared, s, threads) != oracle {
        return Err(format!("{}: parallel(s={s}, t={threads}) != naive (n={n})", S::NAME));
    }
    let bucket = [8, 16][rng.range(0, 2)];
    let (sb, _) = superblock::solve_cpu_semiring::<S>(&prepared, &sb_cfg(bucket, 2));
    if sb != oracle {
        return Err(format!("{}: superblock(b={bucket}) != naive (n={n})", S::NAME));
    }
    // the coordinator's dispatch entry points route to the same code
    if semiring::blocked_solve(obj, &prepared, s) != oracle {
        return Err(format!("{}: blocked_solve dispatcher != naive (n={n})", S::NAME));
    }
    if semiring::naive_solve(obj, &prepared) != oracle {
        return Err(format!("{}: naive_solve dispatcher != naive (n={n})", S::NAME));
    }
    Ok(())
}

#[test]
fn prop_selection_semirings_exact_across_tiers() {
    let cfg = Config { cases: env_cases(18), max_size: 5, ..Config::default() };
    check("selection semirings exact across tiers", cfg, |rng, size| {
        selection_tiers_agree::<MaxMin>(rng, size, Objective::Bottleneck)?;
        selection_tiers_agree::<MinMax>(rng, size, Objective::Minimax)?;
        selection_tiers_agree::<BoolOrAnd>(rng, size, Objective::Reachability)
    });
}

/// Independent reachability oracle: per-source DFS over the *raw* graph's
/// finite-edge adjacency.
fn dfs_closure(g: &DistMatrix) -> Vec<bool> {
    let n = g.n();
    let mut reach = vec![false; n * n];
    for s in 0..n {
        let mut stack = vec![s];
        reach[s * n + s] = true;
        while let Some(u) = stack.pop() {
            for v in 0..n {
                if v != u && g.get(u, v).is_finite() && !reach[s * n + v] {
                    reach[s * n + v] = true;
                    stack.push(v);
                }
            }
        }
    }
    reach
}

#[test]
fn prop_reachability_closure_matches_dfs() {
    // (or, and) on the {0.0, 1.0} carrier vs graph search — a genuinely
    // different algorithm; cells must be *exactly* 1.0 or 0.0, nothing in
    // between ever leaks out of the f32 kernels
    let cfg = Config { cases: env_cases(18), max_size: 5, ..Config::default() };
    check("reachability vs DFS closure", cfg, |rng, size| {
        let n = 3 + rng.range(0, 8 * size.max(1));
        let g = arb_graph(rng, n);
        let prepared = Objective::Reachability.prepare(&g)?;
        let closure = semiring::blocked_solve(Objective::Reachability, &prepared, 16);
        let want = dfs_closure(&g);
        for i in 0..n {
            for j in 0..n {
                let v = closure.get(i, j);
                if v != 0.0 && v != 1.0 {
                    return Err(format!("non-boolean cell {v} at ({i},{j})"));
                }
                if (v == 1.0) != want[i * n + j] {
                    return Err(format!("closure[{i}][{j}]={v}, DFS says {}", want[i * n + j]));
                }
            }
        }
        Ok(())
    });
}

/// Semantic path witness for a selection semiring: fold `S::extend` along
/// the reconstructed walk from `S::ONE`; the fold must reproduce the
/// reported value *bit for bit* (every op selects an operand, so there is
/// no tolerance to hide behind).  Reachability of succ vs value must agree
/// exactly.
fn assert_semiring_walks_exact<S: Semiring>(
    prepared: &DistMatrix,
    r: &PathsResult,
) -> Result<(), String> {
    let n = prepared.n();
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let d = r.dist.get(i, j);
            match r.path(i, j) {
                None => {
                    if !S::is_zero(d) {
                        return Err(format!("{}: value {d} but no path at ({i},{j})", S::NAME));
                    }
                }
                Some(p) => {
                    if S::is_zero(d) {
                        return Err(format!("{}: path but ZERO value at ({i},{j})", S::NAME));
                    }
                    if p[0] != i || *p.last().unwrap() != j {
                        return Err(format!("{}: bad endpoints {p:?} for ({i},{j})", S::NAME));
                    }
                    let mut acc = S::ONE;
                    for hop in p.windows(2) {
                        let w = prepared.get(hop[0], hop[1]);
                        if S::is_zero(w) {
                            return Err(format!(
                                "{}: ({i},{j}) walks non-edge {}->{}",
                                S::NAME,
                                hop[0],
                                hop[1]
                            ));
                        }
                        acc = S::extend(acc, w);
                    }
                    if acc.to_bits() != d.to_bits() {
                        return Err(format!(
                            "{}: ({i},{j}) walk folds to {acc}, value {d}",
                            S::NAME
                        ));
                    }
                }
            }
        }
    }
    Ok(())
}

fn selection_paths_witness<S: Semiring>(
    rng: &mut Rng,
    size: usize,
    obj: Objective,
) -> Result<(), String> {
    let n = 3 + rng.range(0, 8 * size.max(1));
    let g = generators::erdos_renyi_weighted(n, 0.3, 0.1, 10.0, rng.next_u64());
    let prepared = obj.prepare(&g)?;
    let s = [8, 16][rng.range(0, 2)];
    let r = semiring::blocked_solve_paths(obj, &prepared, s);
    if r.dist != apsp::blocked::solve_semiring::<S>(&prepared, s) {
        return Err(format!("{}: paths dist != dist-only twin (n={n}, s={s})", S::NAME));
    }
    assert_semiring_walks_exact::<S>(&prepared, &r)
}

#[test]
fn prop_selection_semiring_paths_reconstruct_exact_values() {
    let cfg = Config { cases: env_cases(12), max_size: 4, ..Config::default() };
    check("selection semiring path witnesses", cfg, |rng, size| {
        selection_paths_witness::<MaxMin>(rng, size, Objective::Bottleneck)?;
        selection_paths_witness::<MinMax>(rng, size, Objective::Minimax)?;
        selection_paths_witness::<BoolOrAnd>(rng, size, Objective::Reachability)
    });
}

// ------------------------------------ objective serving + typed errors --

#[test]
fn handle_line_objective_error_shapes() {
    let coord = synthetic_coordinator();
    // unknown objective: the typed code, id echoed, rejected pre-solve
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":21,"n":4,"objective":"widest","edges":[[0,1,1.0]]}"#,
    );
    assert_error_shape(&reply, "widest");
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("code").as_str(), Some(types::CODE_OBJECTIVE_UNSUPPORTED));
    assert_eq!(v.get("id").as_f64(), Some(21.0));

    // johnson serves the shortest objective only
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":22,"n":4,"variant":"johnson","objective":"bottleneck","edges":[[0,1,1.0]]}"#,
    );
    assert_error_shape(&reply, "johnson");
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("code").as_str(), Some(types::CODE_OBJECTIVE_UNSUPPORTED));

    // the dynamic tier serves the shortest objective only
    let reply = server::handle_line(
        &coord,
        r#"{"type":"update","id":23,"n":8,"objective":"reachability","base":"00ff","updates":[[0,1,2.0]]}"#,
    );
    assert_error_shape(&reply, "shortest");
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("code").as_str(), Some(types::CODE_OBJECTIVE_UNSUPPORTED));
    assert_eq!(v.get("id").as_f64(), Some(23.0));

    // an explicit default objective is NOT an error (wire compatibility)
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":24,"n":3,"objective":"shortest","edges":[[0,1,2.0]]}"#,
    );
    assert_eq!(Json::parse(&reply).unwrap().get("type").as_str(), Some("result"));
}

#[test]
fn objective_end_to_end_and_cache_isolation() {
    // acceptance: all four objectives served client → server → router →
    // cache, with per-objective cache keys — a closure cached under one
    // objective is never returned for another
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn(coord.clone(), "127.0.0.1:0").expect("server");
    let mut client =
        coordinator::client::Client::connect(&srv.addr().to_string()).expect("connect");
    let g = generators::erdos_renyi(24, 0.3, 909); // n ≤ cpu_threshold → CPU tier

    let shortest = client.solve(&g, "staged").expect("shortest");
    assert_ne!(shortest.source, Source::Cache);

    // same graph, same fingerprint base, different objective: MUST miss
    let bottleneck = client.solve_objective(&g, "staged", "bottleneck").expect("bottleneck");
    assert_ne!(bottleneck.source, Source::Cache, "objective leaked across cache keys");
    let prepared = Objective::Bottleneck.prepare(&g).expect("capacities valid");
    assert_eq!(bottleneck.dist, semiring::blocked_solve(Objective::Bottleneck, &prepared, 32));
    assert_ne!(bottleneck.dist, shortest.dist);

    // repeats hit each objective's own entry, values intact
    assert_eq!(client.solve(&g, "staged").unwrap().source, Source::Cache);
    let again = client.solve_objective(&g, "staged", "bottleneck").unwrap();
    assert_eq!(again.source, Source::Cache);
    assert_eq!(again.dist, bottleneck.dist);

    // minimax and reachability round-trip over the wire too
    let minimax = client.solve_objective(&g, "staged", "minimax").expect("minimax");
    assert_ne!(minimax.source, Source::Cache);
    assert_eq!(minimax.dist, semiring::blocked_solve(Objective::Minimax, &g, 32));
    let reach = client.solve_objective(&g, "staged", "reachability").expect("reachability");
    assert_ne!(reach.source, Source::Cache);
    assert!(
        reach.dist.as_slice().iter().all(|&v| v == 0.0 || v == 1.0),
        "reachability closure must stay boolean"
    );

    // paths under a non-shortest objective: cached (dist, succ) pair stays
    // under its objective and reconstructs exact semiring values
    let bpaths =
        client.solve_paths_objective(&g, "staged", "bottleneck").expect("bottleneck paths");
    let r = PathsResult::from_parts(
        bpaths.dist.clone(),
        bpaths.succ.clone().expect("successors present"),
    );
    assert_semiring_walks_exact::<MaxMin>(&prepared, &r).expect("bottleneck walks");
    let spaths = client.solve_paths(&g, "staged").expect("shortest paths");
    assert_eq!(spaths.dist, shortest.dist, "shortest paths request serves the (min,+) closure");
    // the bottleneck closure has an inf diagonal (ONE = +inf), the shortest
    // one a zero diagonal — served pairs can never be confused
    assert_ne!(spaths.dist, bpaths.dist, "bottleneck pair leaked into a shortest request");
}

// ------------------------------------------------- observability regime --

/// Tracing must never change solver outputs: traced requests run the
/// profiled solver twins, whose timing reads sit between phases, so the
/// distances are bitwise identical to an obs-disabled coordinator across
/// every objective — and the assembled span tree always carries the route
/// decision (with reason) and the tier solve.
#[test]
fn prop_observability_is_bitwise_neutral() {
    let on = synthetic_coordinator();
    let off = synthetic_coordinator_with(|c| c.obs = fw_stage::obs::ObsConfig::disabled());
    let cfg = Config { cases: env_cases(16), max_size: 28, ..Config::default() };
    check("tracing is bitwise neutral", cfg, |rng, size| {
        // n ≤ cpu_threshold keeps the synthetic stack on the CPU tier;
        // positive weights keep every objective's domain valid
        let n = 4 + rng.range(0, size.max(4));
        let g = generators::erdos_renyi_weighted(n, 0.4, 0.1, 10.0, rng.next_u64());
        let objective =
            ["shortest", "bottleneck", "minimax", "reachability"][rng.range(0, 4)];
        let req = coordinator::Request {
            id: rng.next_u64() % 1_000_000,
            graph: g.clone(),
            variant: "staged".into(),
            no_cache: true,
            want_paths: false,
            objective: objective.into(),
            trace: true,
        };
        let (traced, root) = on.solve_spanned(&req).map_err(|e| format!("{e:#}"))?;
        let plain = off.solve(&req).map_err(|e| format!("{e:#}"))?;
        if traced.dist != plain.dist {
            return Err(format!("n={n} {objective}: traced dist diverges from plain"));
        }
        let route = root.find("route").ok_or("trace lacks a route span")?;
        if route.note_value("reason") != Some("n within cpu threshold") {
            return Err(format!("route reason {:?}", route.note_value("reason")));
        }
        let solve = root.find("solve").ok_or("trace lacks a solve span")?;
        if solve.note_value("source") != Some(traced.source.name()) {
            return Err(format!("solve source note {:?}", solve.note_value("source")));
        }
        // the CPU tier's profiled twin feeds the phase/round breakdown
        if solve.note_value("rounds").is_none() {
            return Err("solve span lacks the rounds note".into());
        }
        Ok(())
    });

    // the profiled twins themselves, off the serving stack: profile on vs
    // off is bitwise across the blocked family, and the super-block pool's
    // occupancy accounting is internally consistent
    let mut rng = Rng::new(0x0B5);
    for n in [33usize, 64, 96] {
        let g = arb_graph(&mut rng, n);
        let (bp, prof) = apsp::blocked::solve_profiled(&g, 16);
        assert_eq!(bp, apsp::blocked::solve(&g, 16), "blocked twin diverges at n={n}");
        assert!(prof.rounds > 0 && prof.total_seconds() >= 0.0);
        let (pp, _) = apsp::parallel::solve_profiled(&g, 16, 3);
        assert_eq!(pp, apsp::parallel::solve(&g, 16, 3), "parallel twin diverges at n={n}");
        let profiled_cfg = SuperBlockConfig { bucket: 32, workers: 2, profile: true };
        let (sp, report) = superblock::solve_cpu(&g, &profiled_cfg);
        let (s0, _) = superblock::solve_cpu(&g, &sb_cfg(32, 2));
        assert_eq!(sp, s0, "superblock twin diverges at n={n}");
        assert!(report.busy_seconds() > 0.0, "profiled pool recorded no busy time");
        let occ = report.occupancy();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ} outside [0, 1]");
        assert!(report.max_critical_path() > 0, "profiled pool lost the critical path");
    }
}

/// The wire contract of a traced request: the echo splice keeps the reply
/// canonical JSON, span shapes are pinned for the cache-miss and cache-hit
/// paths, and the journal serves newest-first with source filters.
#[test]
fn traced_request_span_shapes_and_journal_over_the_wire() {
    let coord = synthetic_coordinator();
    let g = generators::erdos_renyi(24, 0.3, 515); // n ≤ cpu_threshold → CPU tier
    let request = |id: u64| {
        types::encode_request(&coordinator::Request {
            id,
            graph: g.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: false,
            objective: "shortest".into(),
            trace: true,
        })
    };
    let span_names = |tree: &Json| -> Vec<String> {
        tree.get("spans")
            .as_arr()
            .expect("trace has child spans")
            .iter()
            .filter_map(|s| s.get("name").as_str().map(str::to_string))
            .collect()
    };

    // first request: cache miss — the full decode → route → solve →
    // cache_put → encode shape, with the router's reason and the profiled
    // twin's phase breakdown riding as notes
    let reply = server::handle_line(&coord, &request(21));
    let v = Json::parse(&reply).expect("traced reply is valid JSON");
    assert_eq!(v.get("type").as_str(), Some("result"), "reply: {reply}");
    assert_eq!(v.to_string(), reply, "trace splice broke canonical key order");
    let tree = v.get("trace");
    assert_eq!(tree.get("name").as_str(), Some("request"));
    assert_eq!(span_names(tree), ["decode", "route", "solve", "cache_put", "encode"]);
    let spans = tree.get("spans").as_arr().unwrap();
    assert_eq!(spans[1].get("notes").get("decision").as_str(), Some("cpu"));
    assert_eq!(
        spans[1].get("notes").get("reason").as_str(),
        Some("n within cpu threshold"),
        "route reason is part of the trace contract"
    );
    let solve_notes = spans[2].get("notes");
    assert_eq!(solve_notes.get("source").as_str(), Some("cpu"));
    for key in ["phase1_s", "phase2_s", "phase3_s", "rounds"] {
        assert!(solve_notes.get(key).as_str().is_some(), "solve span lacks {key}: {reply}");
    }

    // repeat: cache hit — a different, shorter pinned shape
    let v2 = Json::parse(&server::handle_line(&coord, &request(22))).unwrap();
    assert_eq!(v2.get("source").as_str(), Some("cache"));
    assert_eq!(span_names(v2.get("trace")), ["decode", "cache_get", "encode"]);

    // the journal holds both, newest first, and filters by tier source
    let listing = Json::parse(&server::handle_line(&coord, r#"{"type":"trace","k":8}"#)).unwrap();
    assert_eq!(listing.get("type").as_str(), Some("trace"));
    assert_eq!(listing.get("count").as_f64(), Some(2.0));
    let traces = listing.get("traces").as_arr().unwrap();
    assert_eq!(traces[0].get("id").as_f64(), Some(22.0), "newest first");
    assert_eq!(traces[0].get("source").as_str(), Some("cache"));
    assert_eq!(traces[1].get("source").as_str(), Some("cpu"));
    assert_eq!(traces[1].get("root").get("name").as_str(), Some("request"));
    let cpu_only =
        Json::parse(&server::handle_line(&coord, r#"{"type":"trace","k":8,"source":"cpu"}"#))
            .unwrap();
    assert_eq!(cpu_only.get("count").as_f64(), Some(1.0), "source filter leaked");
    assert_eq!(cpu_only.get("traces").as_arr().unwrap()[0].get("id").as_f64(), Some(21.0));

    // untraced requests are journaled too (the journal is the server's
    // memory, not the client's), but their replies carry no echo
    let plain = server::handle_line(
        &coord,
        &types::encode_request(&coordinator::Request {
            id: 23,
            graph: g.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: false,
            objective: "shortest".into(),
            trace: false,
        }),
    );
    assert!(Json::parse(&plain).unwrap().get("trace").is_null(), "unasked echo: {plain}");
    assert_eq!(coord.journal().len(), 3);
}

/// `solve_traced` over TCP: the inline echo round-trips, results match the
/// local tier bitwise — and against an obs-disabled server the client gets
/// a clean error (no echo to return) while the journal stays empty.
#[test]
fn solve_traced_roundtrip_and_disabled_server() {
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn(coord.clone(), "127.0.0.1:0").expect("server");
    let mut client =
        coordinator::client::Client::connect(&srv.addr().to_string()).expect("connect");
    let g = generators::erdos_renyi(28, 0.25, 616);
    let (resp, tree) = client.solve_traced(&g, "staged").expect("traced solve");
    assert_eq!(resp.dist, apsp::blocked::solve(&g, 32), "traced result diverges from tier");
    assert_eq!(tree.get("name").as_str(), Some("request"));
    assert!(!tree.get("spans").as_arr().unwrap().is_empty());
    let listing = client.trace(4, None, None).expect("journal listing");
    assert_eq!(listing.get("count").as_f64(), Some(1.0));

    let off = Arc::new(synthetic_coordinator_with(|c| {
        c.obs = fw_stage::obs::ObsConfig::disabled();
    }));
    let srv_off = server::Server::spawn(off.clone(), "127.0.0.1:0").expect("server");
    let mut client_off =
        coordinator::client::Client::connect(&srv_off.addr().to_string()).expect("connect");
    // plain solves still serve; traced ones fail loudly instead of
    // silently dropping the echo
    assert_eq!(client_off.solve(&g, "staged").unwrap().dist, resp.dist);
    let err = client_off.solve_traced(&g, "staged").unwrap_err();
    assert!(err.to_string().contains("trace"), "{err}");
    assert!(off.journal().is_empty(), "disabled journal retained records");
}

/// Per-code error counters and the Prometheus exposition: typed failures
/// land under their wire code, histograms key by `(source, objective)`,
/// and the rendered text round-trips through `parse_exposition`.
#[test]
fn error_codes_and_exposition_round_trip() {
    let coord = synthetic_coordinator();
    let ok = server::handle_line(
        &coord,
        r#"{"type":"solve","id":1,"n":3,"edges":[[0,1,2.0],[1,2,3.0]]}"#,
    );
    assert_eq!(Json::parse(&ok).unwrap().get("type").as_str(), Some("result"));
    assert_error_shape(&server::handle_line(&coord, "{not json"), "");
    assert_error_shape(
        &server::handle_line(
            &coord,
            r#"{"type":"solve","id":2,"n":3,"variant":"johnson","objective":"minimax","edges":[]}"#,
        ),
        "johnson",
    );

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.get("errors").as_f64(), Some(2.0), "{snap}");
    let codes = snap.get("errors_by_code").as_obj().expect("errors_by_code object");
    assert_eq!(codes.get("malformed").and_then(Json::as_f64), Some(1.0), "{snap}");
    assert_eq!(
        codes.get(types::CODE_OBJECTIVE_UNSUPPORTED).and_then(Json::as_f64),
        Some(1.0),
        "{snap}"
    );
    let hists = snap.get("latency_hist").as_obj().expect("latency_hist object");
    assert!(hists.contains_key("cpu/shortest"), "{snap}");

    // the wire exposition parses back into the histogram it rendered
    let reply = Json::parse(&server::handle_line(&coord, r#"{"type":"exposition"}"#)).unwrap();
    assert_eq!(reply.get("type").as_str(), Some("exposition"));
    let text = reply.get("text").as_str().expect("exposition text");
    assert!(text.contains("fw_requests_total"), "{text}");
    assert!(text.contains("fw_errors_total 2"), "{text}");
    let series = fw_stage::obs::hist::parse_exposition(text).expect("exposition parses");
    let h = &series["fw_request_seconds{objective=\"shortest\",source=\"cpu\"}"];
    assert_eq!(h.count(), 1, "one CPU solve observed");
    assert!(h.sum() >= 0.0);
}

// ----------------------------------------------- SIMD kernel dispatch --

/// In-domain random panel for semiring `S`: `density` of the cells hold
/// `S::ZERO` (the annihilator the kernels' skip guards key on), the rest
/// hold values from the semiring's legal domain — shortest allows
/// negatives, the capacity semirings are non-negative, reachability is
/// strictly {0, 1}.  Staying in-domain matters: the bitwise contract
/// between the scalar per-`k` skip and the SIMD per-block skip relies on
/// `combine(extend(ZERO, x), acc) == acc` holding bit-for-bit, which the
/// domain guarantees and arbitrary floats do not.
fn arb_semiring_panel<S: Semiring>(
    rng: &mut Rng,
    rows: usize,
    stride: usize,
    density: f64,
) -> Vec<f32> {
    let mut out = vec![S::ZERO; rows * stride];
    for v in out.iter_mut() {
        if rng.next_f64() >= density {
            *v = match S::NAME {
                "shortest" => (rng.next_f64() * 20.0 - 5.0) as f32,
                "reachability" => {
                    if rng.next_f64() < 0.5 {
                        S::ZERO
                    } else {
                        S::ONE
                    }
                }
                _ => (rng.next_f64() * 10.0 + 0.1) as f32,
            };
        }
    }
    out
}

/// One random panel case at `isa` vs the scalar kernel, generic over the
/// semiring: square tiles {8, 16, 32, 33}, strided and packed operands,
/// ragged `cols % lanes` remainders, dist and succ twins.
fn simd_panel_case<S: Semiring>(rng: &mut Rng, isa: apsp::simd::Isa) -> Result<(), String> {
    let s = [8usize, 16, 32, 33][rng.range(0, 4)];
    let density = [0.0, 0.3, 1.0][rng.range(0, 3)];
    let stride = s + rng.range(0, 16);
    let base = arb_semiring_panel::<S>(rng, s, stride, density);
    let col = arb_semiring_panel::<S>(rng, s, stride, density);
    let row = arb_semiring_panel::<S>(rng, s, stride, density);
    let ctx = format!("{}/{} (s={s}, density={density})", S::NAME, isa.name());

    let mut expect = base.clone();
    apsp::kernel::panel_scalar::<S>(&mut expect, stride, &col, stride, &row, stride, s, s, s);
    let mut got = base.clone();
    apsp::kernel::panel_with::<S>(isa, &mut got, stride, &col, stride, &row, stride, s, s, s);
    if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
        return Err(format!("{ctx}: panel != scalar"));
    }

    // packed column panel (the phase-2 operand layout)
    let mut pack = apsp::kernel::PanelBuf::default();
    pack.pack_dist(&col, stride, s, s);
    let mut got = base.clone();
    apsp::kernel::panel_with::<S>(isa, &mut got, stride, pack.dist(), s, &row, stride, s, s, s);
    if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
        return Err(format!("{ctx}: packed panel != scalar"));
    }

    // succ twin: compare-mask select must replay the scalar strict-accept
    // sequence exactly — values bitwise, successors ==
    let succ0: Vec<usize> = (0..s * stride).collect();
    let colsucc: Vec<usize> = (0..s * stride).map(|v| v + 70_000).collect();
    let (mut edist, mut esucc) = (base.clone(), succ0.clone());
    apsp::kernel::panel_succ_scalar::<S>(
        &mut edist, &mut esucc, stride, &col, &colsucc, stride, &row, stride, s, s, s,
    );
    let (mut gdist, mut gsucc) = (base.clone(), succ0);
    apsp::kernel::panel_succ_with::<S>(
        isa, &mut gdist, &mut gsucc, stride, &col, &colsucc, stride, &row, stride, s, s, s,
    );
    if gdist.iter().zip(&edist).any(|(a, b)| a.to_bits() != b.to_bits()) {
        return Err(format!("{ctx}: succ panel dist != scalar"));
    }
    if gsucc != esucc {
        return Err(format!("{ctx}: succ panel successors != scalar"));
    }

    // ragged remainder: every cols % lanes residue class for the widest
    // vector (16) plus a few below one vector width
    let rr = 1 + rng.range(0, 6);
    let cc = 1 + rng.range(0, 17);
    let kk = 1 + rng.range(0, 9);
    let base = arb_semiring_panel::<S>(rng, rr, stride, density);
    let col = arb_semiring_panel::<S>(rng, rr, stride, density);
    let row = arb_semiring_panel::<S>(rng, kk, stride, density);
    let mut expect = base.clone();
    apsp::kernel::panel_scalar::<S>(&mut expect, stride, &col, stride, &row, stride, rr, cc, kk);
    let mut got = base.clone();
    apsp::kernel::panel_with::<S>(isa, &mut got, stride, &col, stride, &row, stride, rr, cc, kk);
    if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
        return Err(format!("{ctx}: ragged {rr}x{cc}x{kk} != scalar"));
    }

    // row sweep (phases 1–2's vectorized inner loop); equal-length slices
    // keep the dispatcher's geometry debug-assert honest
    let len = base.len().min(row.len());
    let mut erow = base[..len].to_vec();
    apsp::kernel::relax_row_scalar::<S>(&mut erow, &row[..len], col[0]);
    let mut grow = base[..len].to_vec();
    apsp::kernel::relax_row_with::<S>(isa, &mut grow, &row[..len], col[0]);
    if grow.iter().zip(&erow).any(|(a, b)| a.to_bits() != b.to_bits()) {
        return Err(format!("{ctx}: relax_row != scalar"));
    }
    Ok(())
}

#[test]
fn prop_every_isa_bitwise_equals_scalar_for_every_semiring() {
    // the tentpole gate: every SIMD lane width this host can execute is a
    // bit-for-bit drop-in for the scalar kernel, on all four semirings.
    // On a scalar-only host this degenerates to scalar-vs-scalar (and the
    // CI matrix runs the whole suite under FW_KERNEL=scalar besides).
    let isas = apsp::simd::available_isas();
    assert!(isas.contains(&apsp::simd::Isa::Scalar), "scalar is always available");
    let cfg = Config { cases: env_cases(24), max_size: 4, ..Config::default() };
    check("SIMD ISAs vs scalar kernel", cfg, |rng, _size| {
        for &isa in &isas {
            simd_panel_case::<MinPlus>(rng, isa)?;
            simd_panel_case::<MaxMin>(rng, isa)?;
            simd_panel_case::<MinMax>(rng, isa)?;
            simd_panel_case::<BoolOrAnd>(rng, isa)?;
        }
        Ok(())
    });
}

#[test]
fn kernel_isa_resolution_rejects_unavailable_cleanly() {
    // unknown name: typed error naming the env var, not a fault
    let err = apsp::simd::resolve(Some("sse9")).unwrap_err();
    assert!(err.contains("not a known kernel ISA"), "{err}");
    assert!(err.contains("FW_KERNEL"), "{err}");
    // an ISA compiled for a different CPU family (or not detected on this
    // host) must be refused up front — the illegal-instruction bugfix
    if let Some(foreign) = apsp::simd::Isa::ALL.iter().find(|i| !i.available()) {
        let err = apsp::simd::resolve(Some(foreign.name())).unwrap_err();
        assert!(err.contains("cannot execute"), "{err}");
        assert!(err.contains("scalar"), "{err} should list available ISAs");
    }
    // auto and every available name resolve to a runnable ISA
    assert!(apsp::simd::resolve(None).unwrap().available());
    assert!(apsp::simd::resolve(Some("")).unwrap().available());
    for isa in apsp::simd::available_isas() {
        assert_eq!(apsp::simd::resolve(Some(isa.name())).unwrap(), isa);
    }
}

#[test]
fn info_reports_active_kernel() {
    let coord = synthetic_coordinator();
    let reply = Json::parse(&server::handle_line(&coord, r#"{"type":"info"}"#)).unwrap();
    let kernel = reply.get("kernel").as_str().expect("info carries kernel field");
    assert_eq!(kernel, apsp::simd::active().name());
}

// ------------------------------------------------- connection shedding --

/// Admission control: past `max_connections`, a connection gets exactly one
/// typed `shed` error line and a close — never an unbounded handler thread,
/// never a silent hang.  Slots free on disconnect, and sheds count in their
/// own metric, *not* as request errors.
#[test]
fn server_sheds_connections_past_cap_with_typed_error() {
    use std::io::BufRead;
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn_with(
        coord.clone(),
        "127.0.0.1:0",
        server::ServerConfig {
            max_connections: 1,
            ..server::ServerConfig::default()
        },
    )
    .expect("server");
    let addr = srv.addr().to_string();

    // conn 1 claims the only slot; the ping round-trip proves its handler
    // is live (the slot is claimed at accept time, before any read)
    let mut first = coordinator::client::Client::connect(&addr).expect("conn 1");
    first.ping().expect("conn 1 live");

    // conn 2 is over cap: one shed line, then EOF
    let over = std::net::TcpStream::connect(&addr).expect("conn 2");
    over.set_read_timeout(Some(std::time::Duration::from_secs(10))).ok();
    let mut reader = std::io::BufReader::new(over);
    let mut line = String::new();
    reader.read_line(&mut line).expect("shed line");
    let v = Json::parse(line.trim()).expect("shed line is JSON");
    assert_eq!(v.get("type").as_str(), Some("error"), "{line}");
    assert_eq!(v.get("code").as_str(), Some(types::CODE_SHED), "{line}");
    let msg = v.get("message").as_str().expect("shed message");
    assert!(msg.contains("capacity"), "{msg}");
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).expect("post-shed read"), 0, "socket open after shed");

    // dropping conn 1 frees the slot; a retry is eventually admitted
    drop(first);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let mut retry = coordinator::client::Client::connect(&addr).expect("retry connect");
        if retry.ping().is_ok() {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "shed slot never freed");
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    let snap = coord.metrics().snapshot();
    assert!(snap.get("connections_shed").as_f64().unwrap_or(0.0) >= 1.0, "{snap}");
    // backpressure is not a request failure: the error counters stay clean
    assert_eq!(snap.get("errors").as_f64(), Some(0.0), "{snap}");
}

// --------------------------------------------- front-end admission control --

/// Raw line-protocol probe with split read/write halves, so a test can
/// hold many in-flight requests across connections and collect the
/// replies later.
struct RawConn {
    reader: std::io::BufReader<std::net::TcpStream>,
    writer: std::net::TcpStream,
}

impl RawConn {
    fn connect(addr: &str) -> RawConn {
        let stream = std::net::TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(60)))
            .ok();
        RawConn {
            reader: std::io::BufReader::new(stream.try_clone().expect("clone")),
            writer: stream,
        }
    }

    fn send(&mut self, line: &str) {
        use std::io::Write;
        self.writer.write_all(line.as_bytes()).expect("send");
        self.writer.write_all(b"\n").expect("send");
    }

    fn recv(&mut self) -> String {
        use std::io::BufRead;
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("recv");
        line.trim().to_string()
    }
}

/// A cache-skipping CPU-tier solve line (the CPU route keeps these tests
/// artifact-free; `no_cache` keeps them about admission, not caching).
fn cpu_solve_line(id: u64, n: usize, seed: u64, deadline_ms: Option<u64>) -> String {
    let req = coordinator::Request {
        id,
        graph: generators::erdos_renyi(n, 0.3, seed),
        variant: "cpu".into(),
        no_cache: true,
        want_paths: false,
        objective: "shortest".into(),
        trace: false,
    };
    types::encode_request_opts(&req, &types::WireOptions { deadline_ms, binary: false })
}

/// Park the pool's only worker on a solve big enough to outlast the rest
/// of the test's traffic, and return once it has *dequeued* the job
/// (`requests` ticks at solve start) — from then on, arriving requests
/// contend for the queue alone.
fn occupy_worker(addr: &str) -> RawConn {
    let mut busy = RawConn::connect(addr);
    busy.send(&cpu_solve_line(1, 512, 31, None));
    let mut stats = coordinator::client::Client::connect(addr).expect("stats conn");
    let t0 = std::time::Instant::now();
    loop {
        let snap = stats.stats().expect("stats");
        if snap.get("requests").as_usize().unwrap_or(0) >= 1 {
            return busy;
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "worker never dequeued the parked solve"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// The bounded queue admits exactly `queue_depth` requests past the busy
/// workers; the rest come back as typed `shed` errors, every shed
/// connection stays open, and the metrics agree with what the clients
/// observed (sheds are backpressure, not request errors).
#[test]
fn request_queue_admits_exactly_depth_and_sheds_the_rest() {
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn_with(
        coord.clone(),
        "127.0.0.1:0",
        server::ServerConfig {
            workers: 1,
            queue_depth: 2,
            deadline_ms: 0, // nothing may expire: this test is about admission
            ..server::ServerConfig::default()
        },
    )
    .expect("server");
    let addr = srv.addr().to_string();
    let mut busy = occupy_worker(&addr);

    // burst 6 small solves on 6 fresh connections: with the worker parked,
    // exactly 2 fit the queue and 4 must shed
    let mut conns: Vec<RawConn> = (0..6).map(|_| RawConn::connect(&addr)).collect();
    for (i, c) in conns.iter_mut().enumerate() {
        c.send(&cpu_solve_line(10 + i as u64, 16, 100 + i as u64, None));
    }
    let mut results = 0;
    let mut sheds = 0;
    for c in conns.iter_mut() {
        let v = Json::parse(&c.recv()).expect("reply is JSON");
        match v.get("type").as_str() {
            Some("result") => results += 1,
            Some("error") => {
                assert_eq!(v.get("code").as_str(), Some(types::CODE_SHED), "{v}");
                assert!(v.get("message").as_str().unwrap_or("").contains("queue"), "{v}");
                sheds += 1;
            }
            other => panic!("unexpected reply type {other:?}"),
        }
    }
    assert_eq!((results, sheds), (2, 4), "admission bound is exact");

    // a shed *request* never costs the connection: every socket in the
    // burst — shed or served — still answers a ping
    for c in conns.iter_mut() {
        c.send(r#"{"type":"ping"}"#);
        let v = Json::parse(&c.recv()).expect("ping reply");
        assert_eq!(v.get("type").as_str(), Some("pong"));
    }
    let v = Json::parse(&busy.recv()).expect("parked solve reply");
    assert_eq!(v.get("type").as_str(), Some("result"), "{v}");

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.get("requests_shed").as_usize(), Some(4), "{snap}");
    assert_eq!(snap.get("connections_shed").as_usize(), Some(0), "{snap}");
    assert_eq!(snap.get("errors").as_usize(), Some(0), "sheds are not errors: {snap}");
    assert_eq!(snap.get("requests").as_usize(), Some(3), "parked + 2 admitted: {snap}");
}

/// A request whose deadline expires while it sits in the queue comes back
/// as the typed `deadline_exceeded` error without a solver ever running
/// for it — and unlike a shed, expiry *is* a request error: the server
/// accepted the work and failed to deliver it.
#[test]
fn queued_request_past_its_deadline_is_refused_without_solving() {
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn_with(
        coord.clone(),
        "127.0.0.1:0",
        server::ServerConfig {
            workers: 1,
            queue_depth: 2,
            deadline_ms: 0, // the doomed request carries its own deadline
            ..server::ServerConfig::default()
        },
    )
    .expect("server");
    let addr = srv.addr().to_string();
    let mut busy = occupy_worker(&addr);

    // 1 ms against a worker parked for tens of milliseconds: guaranteed
    // to expire while queued
    let mut doomed = RawConn::connect(&addr);
    doomed.send(&cpu_solve_line(2, 16, 5, Some(1)));
    let v = Json::parse(&doomed.recv()).expect("reply is JSON");
    assert_eq!(v.get("type").as_str(), Some("error"), "{v}");
    assert_eq!(v.get("code").as_str(), Some(types::CODE_DEADLINE_EXCEEDED), "{v}");
    assert_eq!(v.get("id").as_f64(), Some(2.0), "{v}");
    assert!(v.get("message").as_str().unwrap_or("").contains("queued"), "{v}");

    let v = Json::parse(&busy.recv()).expect("parked solve reply");
    assert_eq!(v.get("type").as_str(), Some("result"), "{v}");

    let snap = coord.metrics().snapshot();
    // the expired request never reached a solver…
    assert_eq!(snap.get("requests").as_usize(), Some(1), "{snap}");
    assert_eq!(snap.get("cpu_solves").as_usize(), Some(1), "{snap}");
    // …but it counts as a request error, under its typed code
    assert_eq!(snap.get("errors").as_usize(), Some(1), "{snap}");
    assert_eq!(
        snap.get("errors_by_code").get(types::CODE_DEADLINE_EXCEEDED).as_usize(),
        Some(1),
        "{snap}"
    );
}

/// An idle connection gets one typed `idle_timeout` line, then EOF — and
/// its admission slot is actually reclaimed (before this existed, an idle
/// client under `max_connections: 1` wedged the server forever).
#[test]
fn idle_connection_gets_typed_timeout_and_frees_its_slot() {
    use std::io::BufRead;
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn_with(
        coord.clone(),
        "127.0.0.1:0",
        server::ServerConfig {
            max_connections: 1,
            idle_timeout_ms: 150,
            ..server::ServerConfig::default()
        },
    )
    .expect("server");
    let addr = srv.addr().to_string();

    // claim the only slot and go silent: the server must evict us
    let mut idle = RawConn::connect(&addr);
    let line = idle.recv();
    let v = Json::parse(&line).expect("timeout line is JSON");
    assert_eq!(v.get("type").as_str(), Some("error"), "{line}");
    assert_eq!(v.get("code").as_str(), Some(types::CODE_IDLE_TIMEOUT), "{line}");
    assert!(v.get("message").as_str().unwrap_or("").contains("idle"), "{line}");
    let mut rest = String::new();
    assert_eq!(idle.reader.read_line(&mut rest).expect("post-timeout read"), 0, "not closed");

    // the slot frees asynchronously as the handler thread unwinds; a
    // retry loop absorbs the race (over-cap attempts shed and close)
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let mut retry = coordinator::client::Client::connect(&addr).expect("retry connect");
        if retry.ping().is_ok() {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "idle slot never freed");
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.get("idle_timeouts").as_usize(), Some(1), "{snap}");
    assert_eq!(snap.get("errors").as_usize(), Some(0), "timeouts are not errors: {snap}");
}

/// The binary matrix frame round-trips distances bitwise and successors
/// exactly against the JSON rendering of the same solve — and framing is
/// negotiated per *request*, so binary and JSON replies interleave freely
/// on one connection.
#[test]
fn binary_frame_roundtrips_bitwise_and_interleaves_with_json() {
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn(coord, "127.0.0.1:0").expect("server");
    let addr = srv.addr().to_string();
    let g = generators::erdos_renyi(24, 0.25, 515); // n ≤ cpu_threshold → CPU tier

    let mut json_client = coordinator::client::Client::connect(&addr).expect("json client");
    let via_json = json_client.solve_paths(&g, "staged").expect("json paths solve");
    let mut bin_client = coordinator::client::Client::connect(&addr).expect("binary client");
    let via_frame = bin_client.solve_paths_binary(&g, "staged").expect("binary paths solve");

    assert_eq!(via_json.dist.n(), via_frame.dist.n());
    assert!(
        via_json
            .dist
            .as_slice()
            .iter()
            .zip(via_frame.dist.as_slice())
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "frame and JSON renderings of one closure must agree bitwise"
    );
    assert_eq!(via_json.succ, via_frame.succ, "successors must survive the frame exactly");

    // same connection, JSON again, then control plane: per-request framing
    let plain = bin_client.solve(&g, "staged").expect("json solve after a frame");
    assert!(
        plain
            .dist
            .as_slice()
            .iter()
            .zip(via_frame.dist.as_slice())
            .all(|(a, b)| a.to_bits() == b.to_bits())
    );
    bin_client.ping().expect("control plane after a frame");

    // distance-only frame: no successor payload rides along
    let dist_only = bin_client.solve_binary(&g, "staged").expect("binary dist-only");
    assert!(dist_only.succ.is_none());
}

//! Cross-tier differential conformance suite.
//!
//! Every serving tier must be indistinguishable to a caller.  This suite
//! drives random graphs — with unreachable pairs, negative edges (no
//! negative cycles), and sizes that are *not* multiples of the tile or
//! bucket — through the naive, blocked, parallel, johnson, and superblock
//! solvers and pins two levels of agreement:
//!
//! * **bitwise** within the blocked family: `blocked(s)`, `parallel(s, t)`,
//!   and `superblock(bucket = s)` share relaxation order, so their
//!   distances must be identical to the last bit — including each tier's
//!   successor-tracking variant against its distance-only twin.  All three
//!   route phase 3 through the shared register-tiled microkernel
//!   (`apsp::kernel`), whose own bitwise contract against a scalar
//!   reference is pinned here too (phase 3 is a pure min-reduction over
//!   NaN-free, `-0.0`-free candidates, so register blocking cannot perturb
//!   a bit — the property that makes one kernel serve every tier);
//! * **tolerance** across algorithm families: naive FW and Johnson
//!   associate float additions differently, so they agree within
//!   `allclose` bounds, never bitwise.
//!
//! Successor agreement against the reference (`paths::solve`) is semantic,
//! not literal: float rounding can tie two distinct shortest paths, so each
//! tier's successor matrix must *reconstruct a valid walk of the reference
//! distance* (and agree exactly on reachability), not hop through the same
//! vertices.
//!
//! The suite also covers the serving surface: wire-protocol robustness for
//! `server::handle_line` (via a synthetic manifest, so it runs without
//! `make artifacts`), a client → server → cache paths round-trip, and
//! batch-plan determinism (the cache-key contract).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use fw_stage::apsp::{self, paths::PathsResult, paths::NO_PATH};
use fw_stage::coordinator::batcher::{plan, BatchPolicy, Item};
use fw_stage::coordinator::{self, server, Coordinator, Source};
use fw_stage::graph::{generators, DistMatrix};
use fw_stage::superblock::{self, SuperBlockConfig};
use fw_stage::util::json::Json;
use fw_stage::util::prng::Rng;
use fw_stage::util::proptest::{check, Config};

// ------------------------------------------------------------ generators --

/// Random graph mixing the shapes the tiers must agree on: sparse digraphs
/// (unreachable pairs), dense digraphs, and layered DAGs with negative
/// edges but no negative cycles.
fn arb_graph(rng: &mut Rng, n: usize) -> DistMatrix {
    match rng.range(0, 3) {
        0 => generators::erdos_renyi_weighted(n, 0.08, 0.1, 10.0, rng.next_u64()),
        1 => generators::erdos_renyi_weighted(n, rng.next_f64(), 0.1, 10.0, rng.next_u64()),
        _ => {
            // layered DAG with negative edges, sized *exactly* n (the
            // bitwise test needs n to stay a multiple of the tile): use
            // the largest width in {4, 2, 1} that divides n
            let width = [4usize, 2, 1].into_iter().find(|w| n % w == 0).unwrap();
            generators::layered_dag(n / width, width, rng.next_u64())
        }
    }
}

/// Path-validity property: every reconstructed path is a real edge walk in
/// the *original* graph whose weight sum matches the reported distance,
/// endpoints are correct, and `NO_PATH` appears iff the distance is `+inf`.
fn assert_paths_valid(g: &DistMatrix, r: &PathsResult, label: &str) -> Result<(), String> {
    let n = g.n();
    if r.n() != n {
        return Err(format!("{label}: result size {} != {n}", r.n()));
    }
    for i in 0..n {
        for j in 0..n {
            let d = r.dist.get(i, j);
            if i == j {
                continue;
            }
            if (r.succ_at(i, j) == NO_PATH) != !d.is_finite() {
                return Err(format!("{label}: succ/dist reachability differs at ({i},{j})"));
            }
            match r.path(i, j) {
                Some(p) => {
                    if p[0] != i || *p.last().unwrap() != j {
                        return Err(format!("{label}: bad endpoints {p:?} for ({i},{j})"));
                    }
                    for hop in p.windows(2) {
                        if !g.get(hop[0], hop[1]).is_finite() {
                            return Err(format!(
                                "{label}: path ({i},{j}) uses non-edge {}->{}",
                                hop[0], hop[1]
                            ));
                        }
                    }
                    let w = r
                        .path_weight(g, i, j)
                        .ok_or_else(|| format!("{label}: corrupt path at ({i},{j})"))?;
                    let d = d as f64;
                    if (w - d).abs() > 1e-3 + 1e-4 * d.abs() {
                        return Err(format!("{label}: ({i},{j}) walk weight {w} != dist {d}"));
                    }
                }
                None => {
                    if d.is_finite() {
                        return Err(format!("{label}: dist finite but no path at ({i},{j})"));
                    }
                }
            }
        }
    }
    Ok(())
}

// -------------------------------------------- distance conformance (all) --

#[test]
fn prop_blocked_family_distances_bitwise_equal() {
    let cfg = Config { cases: 24, max_size: 4, ..Config::default() };
    check("blocked-family bitwise distances", cfg, |rng, size| {
        let s = [8, 16][rng.range(0, 2)];
        let n = s * (1 + rng.range(0, size.max(1))); // multiple of the tile
        let g = arb_graph(rng, n);
        let threads = 1 + rng.range(0, 4);
        let workers = 1 + rng.range(0, 4);

        let blocked = apsp::blocked::solve(&g, s);
        let parallel = apsp::parallel::solve(&g, s, threads);
        let (sb, _) = superblock::solve_cpu(&g, &SuperBlockConfig { bucket: s, workers });
        let blocked_p = apsp::blocked::solve_paths(&g, s);
        let parallel_p = apsp::parallel::solve_paths(&g, s, threads);
        let (sb_p, _) = superblock::solve_paths(&g, &SuperBlockConfig { bucket: s, workers });

        for (name, dist) in [
            ("parallel", &parallel),
            ("superblock", &sb),
            ("blocked_paths", &blocked_p.dist),
            ("parallel_paths", &parallel_p.dist),
            ("superblock_paths", &sb_p.dist),
        ] {
            if *dist != blocked {
                return Err(format!("{name} != blocked (n={n}, s={s}, t={threads})"));
            }
        }
        Ok(())
    });
}

// ------------------------------------------ microkernel bitwise contract --

// The scalar oracle is `apsp::kernel::minplus_panel_reference` — the one
// exported source of truth the register path is pinned against (the kernel
// unit tests use the same function).
use fw_stage::apsp::kernel::minplus_panel_reference as scalar_phase3;

/// `rows × stride` buffer with a `density` fraction of `+inf` entries —
/// the finiteness-guard stressor the kernel property sweeps over.
fn arb_kernel_panel(rng: &mut Rng, rows: usize, stride: usize, density: f64) -> Vec<f32> {
    let mut out = vec![f32::INFINITY; rows * stride];
    for v in out.iter_mut() {
        if rng.next_f64() >= density {
            *v = (rng.next_f64() * 20.0 - 5.0) as f32;
        }
    }
    out
}

#[test]
fn prop_microkernel_bitwise_vs_scalar_reference() {
    // the contract every tier's phase 3 now rests on: packed and unpacked,
    // succ and dist-only register tiling is bitwise equal to the scalar
    // loop across tile sizes (33 = ragged in both register dimensions) and
    // infinite-weight densities
    let cfg = Config { cases: 48, max_size: 4, ..Config::default() };
    check("microkernel vs scalar phase-3", cfg, |rng, _size| {
        let s = [8usize, 16, 32, 33][rng.range(0, 4)];
        let density = [0.0, 0.3, 0.9, 1.0][rng.range(0, 4)];
        let stride = s + rng.range(0, 40);
        let base = arb_kernel_panel(rng, s, stride, density);
        let col = arb_kernel_panel(rng, s, stride, density);
        let row = arb_kernel_panel(rng, s, stride, density);

        let mut expect = base.clone();
        scalar_phase3(&mut expect, stride, &col, stride, &row, stride, s, s, s);

        // unpacked (strided column panel)
        let mut got = base.clone();
        apsp::kernel::minplus_panel(&mut got, stride, &col, stride, &row, stride, s, s, s);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("strided kernel != scalar (s={s}, density={density})"));
        }

        // packed column panel (the §4.3 coalescing analog)
        let mut pack = apsp::kernel::PanelBuf::default();
        pack.pack_dist(&col, stride, s, s);
        let mut got = base.clone();
        apsp::kernel::minplus_panel(&mut got, stride, pack.dist(), s, &row, stride, s, s, s);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("packed kernel != scalar (s={s}, density={density})"));
        }

        // succ twin: distances must stay bitwise identical to the
        // distance-only kernel (accept order is the scalar order)
        let mut got = base.clone();
        let mut dsucc: Vec<usize> = (0..s * stride).collect();
        let colsucc: Vec<usize> = (0..s * stride).map(|v| v + 10_000).collect();
        apsp::kernel::minplus_panel_succ(
            &mut got, &mut dsucc, stride, &col, &colsucc, stride, &row, stride, s, s, s,
        );
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("succ kernel dist != scalar (s={s}, density={density})"));
        }

        // ragged remainder blocks (rows/cols/k straddling the register
        // tile; all bounded by the panel stride so views stay in range)
        let rr = 1 + rng.range(0, 9);
        let cc = 1 + rng.range(0, stride.min(17));
        let kk = rng.range(0, stride.min(13));
        let base = arb_kernel_panel(rng, rr, stride, density);
        let col = arb_kernel_panel(rng, rr, stride, density);
        let row = arb_kernel_panel(rng, kk.max(1), stride, density);
        let mut expect = base.clone();
        scalar_phase3(&mut expect, stride, &col, stride, &row, stride, rr, cc, kk);
        let mut got = base.clone();
        apsp::kernel::minplus_panel(&mut got, stride, &col, stride, &row, stride, rr, cc, kk);
        if got.iter().zip(&expect).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("ragged kernel != scalar ({rr}x{cc}x{kk}, stride={stride})"));
        }
        Ok(())
    });
}

#[test]
fn prop_algorithm_families_distances_close() {
    let cfg = Config { cases: 24, max_size: 48, ..Config::default() };
    check("naive/johnson/blocked tolerance distances", cfg, |rng, size| {
        let n = 2 + rng.range(0, size.max(2));
        let g = arb_graph(rng, n);
        let s = 1 + rng.range(0, 24); // any tile: non-multiples pad + truncate
        let naive = apsp::naive::solve(&g);
        let blocked = apsp::blocked::solve(&g, s);
        if !blocked.allclose(&naive, 1e-4, 1e-4) {
            return Err(format!("blocked(s={s}) vs naive, n={n}"));
        }
        let johnson = apsp::johnson::solve(&g).map_err(|e| format!("johnson: {e}"))?;
        if !johnson.allclose(&naive, 1e-4, 1e-4) {
            return Err(format!("johnson vs naive, n={n}"));
        }
        // superblock pads non-multiple n internally
        let bucket = [8, 16][rng.range(0, 2)];
        let (sb, _) = superblock::solve_cpu(&g, &SuperBlockConfig { bucket, workers: 2 });
        if !sb.allclose(&naive, 1e-4, 1e-4) {
            return Err(format!("superblock(b={bucket}) vs naive, n={n}"));
        }
        Ok(())
    });
}

// ----------------------------------------------- successor conformance --

#[test]
fn prop_every_path_tier_reconstructs_reference_distances() {
    let cfg = Config { cases: 16, max_size: 40, ..Config::default() };
    check("successor agreement vs paths::solve", cfg, |rng, size| {
        let n = 2 + rng.range(0, size.max(2));
        let g = arb_graph(rng, n);
        let s = [8, 16][rng.range(0, 2)]; // multiples and non-multiples both occur
        let reference = apsp::paths::solve(&g);

        let tiers: [(&str, PathsResult); 3] = [
            ("blocked", apsp::blocked::solve_paths(&g, s)),
            ("parallel", apsp::parallel::solve_paths(&g, s, 3)),
            (
                "superblock",
                superblock::solve_paths(&g, &SuperBlockConfig { bucket: s, workers: 2 }).0,
            ),
        ];
        for (name, r) in &tiers {
            // validity of the tier's own reconstruction
            assert_paths_valid(&g, r, name)?;
            // exact reachability agreement with the reference
            for i in 0..n {
                for j in 0..n {
                    if (r.succ_at(i, j) == NO_PATH) != (reference.succ_at(i, j) == NO_PATH) {
                        return Err(format!("{name}: reachability differs at ({i},{j})"));
                    }
                }
            }
            // the tier's walk must cost the *reference* distance too
            // (ties may pick different hops; the total cannot differ)
            for i in 0..n {
                for j in 0..n {
                    if i == j {
                        continue;
                    }
                    if let Some(w) = r.path_weight(&g, i, j) {
                        let d = reference.dist.get(i, j) as f64;
                        if (w - d).abs() > 1e-3 + 1e-4 * d.abs() {
                            return Err(format!(
                                "{name}: walk ({i},{j}) costs {w}, reference dist {d}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_path_validity_holds_for_reference_solver() {
    // the reference itself must satisfy the validity property the tiers
    // are measured against
    let cfg = Config { cases: 16, max_size: 40, ..Config::default() };
    check("path validity (reference)", cfg, |rng, size| {
        let n = 2 + rng.range(0, size.max(2));
        let g = arb_graph(rng, n);
        assert_paths_valid(&g, &apsp::paths::solve(&g), "reference")
    });
}

// --------------------------------------------------- batcher determinism --

#[test]
fn batcher_plan_is_deterministic_for_identical_inputs() {
    // the plan feeds the engine's packing (and through it which graphs
    // share a device call), so identical inputs must yield identical
    // layouts run after run — the cache-key contract depends on it
    let buckets = [64, 128, 256, 512];
    let policy = BatchPolicy::default();
    let mut rng = Rng::new(0xD37E_0001);
    for round in 0..32 {
        let items: Vec<Item> = (0..rng.range(1, 40))
            .map(|i| Item { ticket: i as u64, n: 1 + rng.range(0, 700) })
            .collect();
        let first = format!("{:?}", plan(&items, &buckets, &policy));
        for repeat in 0..5 {
            let again = format!("{:?}", plan(&items, &buckets, &policy));
            assert_eq!(first, again, "round {round} repeat {repeat} diverged");
        }
    }
}

#[test]
fn batcher_plan_pinned_layout() {
    // freeze one concrete layout: a change here silently re-shuffles which
    // graphs get co-packed and invalidates recorded batching behavior
    let items: Vec<Item> = [30usize, 100, 30, 300, 16, 16]
        .iter()
        .enumerate()
        .map(|(i, &n)| Item { ticket: i as u64, n })
        .collect();
    let batches = plan(&items, &[64, 128, 256, 512], &BatchPolicy::default());
    let layout: Vec<(usize, Vec<(u64, usize)>)> = batches
        .iter()
        .map(|b| (b.bucket, b.placements.iter().map(|p| (p.ticket, p.offset)).collect()))
        .collect();
    assert_eq!(
        layout,
        vec![
            // 64-bucket, first-fit-decreasing: 30+30 fill one call (60/64);
            // 16+16 open a second (16+16 would overflow the first)
            (64, vec![(0, 0), (2, 30)]),
            (64, vec![(4, 0), (5, 16)]),
            (128, vec![(1, 0)]),
            (512, vec![(3, 0)]),
        ]
    );
}

// ------------------------------------------- wire-protocol robustness --

static SYNTH_DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Start a coordinator against a synthetic single-artifact manifest, so the
/// serving surface is testable without `make artifacts`.  The fake HLO file
/// is never compiled (warm-up is disabled and the tests below never route
/// to the device tier).
fn synthetic_coordinator() -> Coordinator {
    let dir = std::env::temp_dir().join(format!(
        "fw-stage-conformance-{}-{}",
        std::process::id(),
        SYNTH_DIR_SEQ.fetch_add(1, Ordering::SeqCst)
    ));
    std::fs::create_dir_all(&dir).expect("create synthetic artifact dir");
    let hlo = "HLO placeholder (never compiled by these tests)\n";
    std::fs::write(dir.join("apsp_staged_n64.hlo.txt"), hlo).expect("write fake artifact");
    let manifest = format!(
        r#"{{"version": 2, "tile": 32, "artifacts": [
            {{"name": "apsp_staged_n64.hlo.txt", "variant": "staged", "n": 64,
              "tile": 32, "dtype": "f32", "input_shape": [64, 64],
              "output_shape": [64, 64], "bytes": {}}}]}}"#,
        hlo.len()
    );
    std::fs::write(dir.join("manifest.json"), manifest).expect("write manifest");
    let mut config = coordinator::Config::new(&dir);
    config.engine.warm_variants = Vec::new();
    Coordinator::start(config).expect("synthetic coordinator")
}

/// Every failure mode must come back as the pinned error shape — a JSON
/// object with `type: "error"`, a numeric `id`, and a `message` — never a
/// panic or a dropped line.
fn assert_error_shape(reply: &str, expect_in_message: &str) {
    let v = Json::parse(reply).expect("error reply is valid JSON");
    assert_eq!(v.get("type").as_str(), Some("error"), "reply: {reply}");
    assert!(v.get("id").as_f64().is_some(), "error lacks id: {reply}");
    let msg = v.get("message").as_str().expect("error lacks message");
    assert!(
        msg.to_lowercase().contains(&expect_in_message.to_lowercase()),
        "message {msg:?} does not mention {expect_in_message:?}"
    );
}

#[test]
fn handle_line_malformed_json_returns_error_shape() {
    let coord = synthetic_coordinator();
    for line in ["{not json", "", "42", "\"solve\"", "{\"type\":\"solve\",\"n\":"] {
        let reply = server::handle_line(&coord, line);
        assert_error_shape(&reply, "");
    }
}

#[test]
fn handle_line_unknown_variant_returns_error_shape() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":7,"n":8,"variant":"warp9","edges":[]}"#,
    );
    assert_error_shape(&reply, "warp9");
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("id").as_f64(), Some(7.0), "id echoed for routable errors");
}

#[test]
fn handle_line_zero_size_graph_returns_error_shape() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(&coord, r#"{"type":"solve","n":0,"edges":[]}"#);
    assert_error_shape(&reply, "empty graph");
}

#[test]
fn handle_line_oversized_n_returns_error_shape() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(&coord, r#"{"type":"solve","n":999999,"edges":[]}"#);
    assert_error_shape(&reply, "exceeds server limit");
}

#[test]
fn handle_line_unknown_request_type_returns_error_shape() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(&coord, r#"{"type":"frobnicate"}"#);
    assert_error_shape(&reply, "unknown request type");
}

#[test]
fn handle_line_johnson_paths_rejected_cleanly() {
    let coord = synthetic_coordinator();
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":3,"n":8,"variant":"johnson","paths":true,"edges":[[0,1,1.0]]}"#,
    );
    assert_error_shape(&reply, "johnson");
}

#[test]
fn handle_line_cpu_solve_works_without_artifacts() {
    // the synthetic stack must still *serve* (CPU tier), proving the
    // robustness tests exercise a live coordinator, not a stub
    let coord = synthetic_coordinator();
    let reply = server::handle_line(
        &coord,
        r#"{"type":"solve","id":5,"n":3,"edges":[[0,1,2.0],[1,2,3.0]]}"#,
    );
    let v = Json::parse(&reply).expect("valid JSON");
    assert_eq!(v.get("type").as_str(), Some("result"), "reply: {reply}");
    assert_eq!(v.get("source").as_str(), Some("cpu"));
}

// --------------------------------------- end-to-end paths over the wire --

#[test]
fn paths_roundtrip_client_server_cache() {
    // acceptance: a path-carrying request served through the coordinator
    // (client → server → cache hit on repeat) round-trips successors
    let coord = Arc::new(synthetic_coordinator());
    let srv = server::Server::spawn(coord.clone(), "127.0.0.1:0").expect("server");
    let mut client =
        coordinator::client::Client::connect(&srv.addr().to_string()).expect("connect");

    let g = generators::erdos_renyi(24, 0.25, 404); // n ≤ cpu_threshold → CPU tier
    let first = client.solve_paths(&g, "staged").expect("paths solve");
    assert_ne!(first.source, Source::Cache);
    let succ = first.succ.clone().expect("successors present");
    let r = PathsResult::from_parts(first.dist.clone(), succ);
    assert_paths_valid(&g, &r, "wire").expect("wire paths valid");
    // the wire result must reconstruct exactly what the local tier computes
    let local = apsp::blocked::solve_paths(&g, 32);
    assert_eq!(r.dist, local.dist);
    assert_eq!(r.succ(), local.succ());

    // repeat: served from the cache, successors intact
    let second = client.solve_paths(&g, "staged").expect("cached paths solve");
    assert_eq!(second.source, Source::Cache);
    assert_eq!(second.dist, first.dist);
    assert_eq!(second.succ, first.succ);

    // a distance-only request for the same graph shares the cache entry
    let dist_only = client.solve(&g, "staged").expect("distance solve");
    assert_eq!(dist_only.source, Source::Cache);
    assert!(dist_only.succ.is_none(), "distance responses carry no succ");
    assert_eq!(dist_only.dist, first.dist);
}

#[test]
fn paths_through_coordinator_superblock_tier() {
    // explicit superblock variant with the synthetic 64-bucket: path mode
    // runs CPU diagonal solves, so no artifact execution is needed
    let coord = synthetic_coordinator();
    let g = generators::erdos_renyi(100, 0.1, 505); // pads to 128, 2×2 grid
    let resp = coord
        .solve(&coordinator::Request {
            id: 11,
            graph: g.clone(),
            variant: "superblock".into(),
            no_cache: false,
            want_paths: true,
        })
        .expect("superblock paths solve");
    assert_eq!(resp.source, Source::SuperBlock);
    assert_eq!(resp.bucket, 64);
    let r = PathsResult::from_parts(resp.dist.clone(), resp.succ.clone().expect("succ"));
    assert_paths_valid(&g, &r, "superblock-coordinator").expect("valid paths");
    // distances bitwise vs the CPU superblock tier at the same bucket
    let (oracle, _) = superblock::solve_cpu(&g, &SuperBlockConfig { bucket: 64, workers: 0 });
    assert_eq!(r.dist, oracle);
}

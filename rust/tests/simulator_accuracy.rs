//! Simulator-vs-paper accuracy gates (experiments E1–E7 of DESIGN.md).
//!
//! These run without artifacts (pure analytical model) and lock in the
//! reproduction quality: if a refactor degrades the model's agreement with
//! the paper's published numbers, these tests fail.

use fw_stage::simulator::table::{accuracy_report, fig7_csv, table1, PAPER_TABLE1};
use fw_stage::simulator::{simulate, Variant};

#[test]
fn e1_every_populated_cell_within_factor_2() {
    for (n, name, sim, paper, _) in accuracy_report() {
        let ratio = sim / paper;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "E1: {name} at n={n}: sim {sim:.3} vs paper {paper:.3}"
        );
    }
}

#[test]
fn e1_large_n_within_15pct() {
    for (n, name, sim, paper, err) in accuracy_report() {
        if n >= 8192 {
            assert!(
                err.abs() <= 0.15,
                "E1: {name} at n={n}: sim {sim:.2} vs paper {paper:.2} ({:+.1}%)",
                err * 100.0
            );
        }
    }
}

#[test]
fn e1_headline_cell() {
    // "solve APSP for any graph ... containing 16,384 vertices in 53.06 s"
    let t = simulate(Variant::StagedLoad, 16384).seconds;
    assert!((t - 53.06).abs() / 53.06 < 0.10, "headline: {t:.2}s");
}

#[test]
fn e2_fig7_series_ordering_everywhere() {
    // Figure 7's visual claim: the five curves never cross
    for row in table1() {
        for pair in row.simulated.windows(2) {
            assert!(pair[1] < pair[0], "curves cross at n={}", row.n);
        }
    }
}

#[test]
fn e3_tasks_per_second_analysis() {
    let hn = simulate(Variant::HarishNarayanan, 8192).tasks_per_sec;
    let kk = simulate(Variant::KatzKider, 16384).tasks_per_sec;
    let staged = simulate(Variant::StagedLoad, 16384).tasks_per_sec;
    assert!((2.3e9..3.0e9).contains(&hn), "H&N {hn:.2e} (paper ~2.6e9)");
    assert!((13.5e9..17.0e9).contains(&kk), "K&K {kk:.2e} (paper 14.9e9)");
    assert!(
        (70.0e9..90.0e9).contains(&staged),
        "staged {staged:.2e} (paper 73.6e9)"
    );
}

#[test]
fn e4_hn_is_bandwidth_bound_others_not() {
    assert!(simulate(Variant::HarishNarayanan, 8192).memory_bound);
    assert!(!simulate(Variant::KatzKider, 16384).memory_bound);
    assert!(!simulate(Variant::StagedLoad, 16384).memory_bound);
}

#[test]
fn e5_speedup_decomposition() {
    let kk = simulate(Variant::KatzKider, 16384).seconds;
    let opt = simulate(Variant::OptimizedBlocked, 16384).seconds;
    let staged = simulate(Variant::StagedLoad, 16384).seconds;
    let instr = kk / opt;
    let sched = opt / staged;
    let total = kk / staged;
    assert!((2.0..2.4).contains(&instr), "instr {instr:.2} (paper 2.1–2.3)");
    assert!((2.2..2.6).contains(&sched), "sched {sched:.2} (paper 2.3–2.4)");
    assert!((4.8..5.7).contains(&total), "total {total:.2} (paper ≈5.2)");
}

#[test]
fn e5_cyclic_k_ablation_matters() {
    let cyclic = simulate(Variant::StagedLoad, 8192).seconds;
    let simple = simulate(Variant::StagedSimpleK, 8192).seconds;
    assert!(simple / cyclic > 1.8, "bank conflicts: {:.2}×", simple / cyclic);
}

#[test]
fn e7_cpu_time_constant() {
    // footnote-adjacent: the CPU column's n³ constant (≈2.2e-9 s/task)
    for (n, cells) in PAPER_TABLE1.iter().take(4) {
        let paper = cells[0].unwrap();
        let sim = simulate(Variant::Cpu, *n).seconds;
        assert!((sim - paper).abs() / paper < 0.08, "CPU n={n}: {sim} vs {paper}");
    }
    // and the abstract's implied GPU constant 1.2e-11 s/task at 16384
    let staged = simulate(Variant::StagedLoad, 16384);
    let const_per_task = staged.seconds / (16384f64).powi(3);
    assert!(
        (1.0e-11..1.4e-11).contains(&const_per_task),
        "staged constant {const_per_task:.3e}"
    );
}

#[test]
fn csv_matches_table() {
    let csv = fig7_csv();
    let rows = table1();
    let second_line = csv.lines().nth(1).unwrap();
    let first_cell: f64 = second_line.split(',').nth(1).unwrap().parse().unwrap();
    // CSV renders %.5f — compare at that precision
    assert!((first_cell - rows[0].simulated[0]).abs() < 1e-4);
}

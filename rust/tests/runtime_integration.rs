//! Integration: AOT artifacts → PJRT runtime → results vs the CPU oracle.
//!
//! These tests require `make artifacts` to have run (they are the proof
//! that the three layers compose).  They are skipped with a notice when
//! artifacts/ is missing so `cargo test` works in a fresh checkout.

use std::cell::OnceCell;
use std::path::PathBuf;

use fw_stage::apsp::{self, check_invariants};
use fw_stage::graph::{generators, DistMatrix};
use fw_stage::runtime::ExecutorPool;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

thread_local! {
    // The xla crate's PJRT client is Rc-based (not Send): one pool per test
    // thread.  The channel-fed multi-thread path is covered by the
    // coordinator integration tests.
    static POOL: OnceCell<Option<ExecutorPool>> = const { OnceCell::new() };
}

/// Run `f` with the shared pool, or print a skip notice without artifacts.
fn with_pool(f: impl FnOnce(&ExecutorPool)) {
    POOL.with(|cell| {
        let pool = cell.get_or_init(|| {
            let dir = artifact_dir()?;
            Some(ExecutorPool::open(&dir).expect("opening executor pool"))
        });
        match pool {
            Some(p) => f(p),
            None => eprintln!("SKIP: artifacts/ not built (run `make artifacts`)"),
        }
    });
}

#[test]
fn staged_matches_cpu_oracle_exact_size() {
    with_pool(|pool| {
        let g = generators::erdos_renyi(128, 0.3, 101);
        let (dev, bucket) = pool.solve("staged", &g).unwrap();
        assert_eq!(bucket, 128);
        let cpu = apsp::naive::solve(&g);
        assert!(
            dev.allclose(&cpu, 1e-5, 1e-5),
            "device vs cpu max diff {}",
            dev.max_abs_diff(&cpu)
        );
    });
}

#[test]
fn all_variants_agree_with_oracle() {
    with_pool(|pool| {
        let g = generators::erdos_renyi(64, 0.4, 103);
        let cpu = apsp::naive::solve(&g);
        for variant in pool.manifest().variants() {
            let (dev, _) = pool.solve(&variant, &g).unwrap();
            assert!(
                dev.allclose(&cpu, 1e-5, 1e-5),
                "{variant}: max diff {}",
                dev.max_abs_diff(&cpu)
            );
        }
    });
}

#[test]
fn padding_preserves_distances() {
    with_pool(|pool| {
        // 50 is not a lowered size: must pad to 64 and truncate back
        let g = generators::scale_free(50, 2, 107);
        let (dev, bucket) = pool.solve("staged", &g).unwrap();
        assert_eq!(bucket, 64);
        assert_eq!(dev.n(), 50);
        let cpu = apsp::naive::solve(&g);
        assert!(dev.allclose(&cpu, 1e-5, 1e-5));
    });
}

#[test]
fn device_results_pass_invariants() {
    with_pool(|pool| {
        let cases: Vec<(DistMatrix, &str)> = vec![
            (generators::ring(96), "ring"),
            (generators::grid(10, 5), "grid"),
            (generators::geometric(120, 0.3, 7), "geometric"),
        ];
        for (g, name) in cases {
            let (dev, _) = pool.solve("staged", &g).unwrap();
            check_invariants(&g, &dev).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    });
}

#[test]
fn negative_weights_through_device() {
    with_pool(|pool| {
        let g = generators::layered_dag(8, 8, 109); // negative edges, no cycles
        let (dev, _) = pool.solve("staged", &g).unwrap();
        let cpu = apsp::naive::solve(&g);
        assert!(dev.allclose(&cpu, 1e-5, 1e-5));
    });
}

#[test]
fn disconnected_components_stay_inf() {
    with_pool(|pool| {
        let mut g = generators::erdos_renyi(64, 0.5, 113);
        for i in 0..32 {
            for j in 32..64 {
                g.set(i, j, f32::INFINITY);
                g.set(j, i, f32::INFINITY);
            }
        }
        let (dev, _) = pool.solve("staged", &g).unwrap();
        for i in 0..32 {
            for j in 32..64 {
                assert!(dev.get(i, j).is_infinite());
                assert!(dev.get(j, i).is_infinite());
            }
        }
    });
}

#[test]
fn executor_pool_caches_compiles() {
    with_pool(|pool| {
        let before = pool.compiled_count();
        let g = generators::ring(64);
        pool.solve("staged", &g).unwrap();
        let mid = pool.compiled_count();
        pool.solve("staged", &g).unwrap();
        pool.solve("staged", &g).unwrap();
        assert_eq!(pool.compiled_count(), mid);
        assert!(mid >= before);
    });
}

#[test]
fn repeated_execution_is_deterministic() {
    with_pool(|pool| {
        let g = generators::erdos_renyi(64, 0.3, 211);
        let a = pool.solve("staged", &g).unwrap().0;
        let b = pool.solve("staged", &g).unwrap().0;
        assert_eq!(a, b);
    });
}

#[test]
fn blocked_and_staged_artifacts_agree_bitwise() {
    with_pool(|pool| {
        // same (min,+) sums, different k-grouping: exact equality expected
        let g = generators::erdos_renyi(128, 0.35, 223);
        let blocked = pool.solve("blocked", &g).unwrap().0;
        let staged = pool.solve("staged", &g).unwrap().0;
        assert_eq!(blocked, staged);
    });
}

#[test]
fn warm_compiles_all_sizes() {
    with_pool(|pool| {
        let count = pool.warm("staged").unwrap();
        assert!(count >= 3, "expected ≥3 staged sizes, got {count}");
        assert!(pool.compiled_count() >= count);
    });
}

#[test]
fn rejects_unknown_variant_and_oversize() {
    with_pool(|pool| {
        let g = generators::ring(16);
        assert!(pool.solve("no-such-variant", &g).is_err());
        let huge = DistMatrix::unconnected(4096);
        assert!(pool.solve("staged", &huge).is_err());
    });
}

#[test]
fn runtime_reports_platform() {
    with_pool(|pool| {
        assert_eq!(pool.runtime().platform(), "cpu");
        assert!(pool.runtime().device_count() >= 1);
    });
}

//! CLI integration: drive the built `fw-stage` binary end to end.

use std::path::PathBuf;
use std::process::Command;

fn binary() -> PathBuf {
    // target dir is a sibling of the test executable's parent (deps/)
    let mut path = std::env::current_exe().unwrap();
    path.pop(); // strip test binary name
    if path.ends_with("deps") {
        path.pop();
    }
    path.join("fw-stage")
}

fn artifacts_available() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(binary())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("running fw-stage");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    for cmd in ["solve", "serve", "gen", "simulate", "bench-tasks", "info"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn no_args_prints_usage_ok() {
    let (ok, stdout, _) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn simulate_table1_reproduces_shape() {
    let (ok, stdout, _) = run(&["simulate", "--table1"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("Table 1"));
    assert!(stdout.contains("16384"));
    assert!(stdout.contains("53.02") || stdout.contains("(53.02"));
}

#[test]
fn simulate_fig7_csv() {
    let (ok, stdout, _) = run(&["simulate", "--fig7", "--csv"]);
    assert!(ok);
    let lines: Vec<&str> = stdout.trim().lines().collect();
    assert_eq!(lines.len(), 18);
    assert!(lines[0].starts_with("n,cpu"));
}

#[test]
fn simulate_analysis_and_ablation() {
    let (ok, stdout, _) = run(&["simulate", "--analysis", "--ablation", "--n", "8192"]);
    assert!(ok);
    assert!(stdout.contains("tasks/s"));
    assert!(stdout.contains("Speedup decomposition"));
}

#[test]
fn gen_writes_all_models() {
    let dir = std::env::temp_dir().join(format!("fw_cli_gen_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for model in ["er", "grid", "scale-free", "geometric", "ring", "dag"] {
        let out = dir.join(format!("{model}.edges"));
        let (ok, _, stderr) = run(&[
            "gen",
            "--model",
            model,
            "--n",
            "64",
            "--out",
            out.to_str().unwrap(),
        ]);
        assert!(ok, "{model}: {stderr}");
        assert!(out.exists());
        let g = fw_stage::graph::io::load(&out).unwrap();
        assert!(g.n() >= 16, "{model} produced n={}", g.n());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gen_rejects_unknown_model_and_flags() {
    let (ok, _, stderr) = run(&["gen", "--model", "mystery"]);
    assert!(!ok);
    assert!(stderr.contains("unknown model"));
    let (ok, _, stderr) = run(&["gen", "--frobnicate", "1"]);
    assert!(!ok);
    assert!(stderr.contains("unknown flag"));
}

#[test]
fn solve_file_end_to_end() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let dir = std::env::temp_dir().join(format!("fw_cli_solve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.edges");
    let out_path = dir.join("d.dist");
    let (ok, _, stderr) = run(&[
        "gen", "--model", "er", "--n", "80", "--seed", "9",
        "--out", graph_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--output", out_path.to_str().unwrap(),
        "--variant", "staged",
    ]);
    assert!(ok, "{stderr}");
    assert!(stderr.contains("via device"), "{stderr}");
    // verify against the CPU oracle
    let g = fw_stage::graph::io::load(&graph_path).unwrap();
    let d = fw_stage::graph::io::load(&out_path).unwrap();
    let cpu = fw_stage::apsp::naive::solve(&g);
    assert!(d.allclose(&cpu, 1e-5, 1e-5));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn solve_paths_flag_prints_reconstructed_path() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let dir = std::env::temp_dir().join(format!("fw_cli_paths_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.edges");
    let (ok, _, stderr) = run(&[
        "gen", "--model", "ring", "--n", "12",
        "--out", graph_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    // a ring's 0 → 5 path is forced through every intermediate vertex
    let (ok, stdout, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--paths", "--src", "0", "--dst", "5",
    ]);
    assert!(ok, "{stderr}");
    assert!(
        stdout.contains("path 0 -> 5: 0 -> 1 -> 2 -> 3 -> 4 -> 5"),
        "unexpected path output: {stdout}"
    );
    assert!(stdout.contains("cost"), "{stdout}");
    // unreachable src/dst out of range is a clean error
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--paths", "--src", "0", "--dst", "99",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--src/--dst"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn solve_update_applies_edge_deltas_incrementally() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let dir = std::env::temp_dir().join(format!("fw_cli_update_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.edges");
    let (ok, _, stderr) = run(&[
        "gen", "--model", "ring", "--n", "12",
        "--out", graph_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    // insert a shortcut 0 → 5: the updated closure must route through it
    let (ok, stdout, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--update", "0,5,0.5",
        "--paths", "--src", "0", "--dst", "5",
    ]);
    assert!(ok, "{stderr}");
    assert!(stderr.contains("via incremental"), "{stderr}");
    assert!(stdout.contains("path 0 -> 5: 0 -> 5"), "{stdout}");
    // delete the ring's only 4 → 5 edge: 0 → 5 becomes unreachable
    // (increase path: successor-forest damage detection)
    let (ok, stdout, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--update", "4,5,inf",
        "--paths", "--src", "0", "--dst", "5",
    ]);
    assert!(ok, "{stderr}");
    assert!(stderr.contains("via incremental"), "{stderr}");
    assert!(stdout.contains("path 0 -> 5: unreachable"), "{stdout}");
    // malformed spec is a clean error
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--update", "nope",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--update"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn info_describes_artifacts() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let (ok, stdout, stderr) = run(&["info"]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("staged"), "{stdout}");
    assert!(stdout.contains("tile: 32"));
}

#[test]
fn solve_missing_input_is_error() {
    let (ok, _, stderr) = run(&["solve"]);
    assert!(!ok);
    assert!(stderr.contains("--input"));
}

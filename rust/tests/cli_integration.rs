//! CLI integration: drive the built `fw-stage` binary end to end.

use std::path::PathBuf;
use std::process::Command;

fn binary() -> PathBuf {
    // target dir is a sibling of the test executable's parent (deps/)
    let mut path = std::env::current_exe().unwrap();
    path.pop(); // strip test binary name
    if path.ends_with("deps") {
        path.pop();
    }
    path.join("fw-stage")
}

fn artifacts_available() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(binary())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("running fw-stage");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    for cmd in ["solve", "serve", "gen", "simulate", "bench-tasks", "info"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn no_args_prints_usage_ok() {
    let (ok, stdout, _) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn simulate_table1_reproduces_shape() {
    let (ok, stdout, _) = run(&["simulate", "--table1"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("Table 1"));
    assert!(stdout.contains("16384"));
    assert!(stdout.contains("53.02") || stdout.contains("(53.02"));
}

#[test]
fn simulate_fig7_csv() {
    let (ok, stdout, _) = run(&["simulate", "--fig7", "--csv"]);
    assert!(ok);
    let lines: Vec<&str> = stdout.trim().lines().collect();
    assert_eq!(lines.len(), 18);
    assert!(lines[0].starts_with("n,cpu"));
}

#[test]
fn simulate_analysis_and_ablation() {
    let (ok, stdout, _) = run(&["simulate", "--analysis", "--ablation", "--n", "8192"]);
    assert!(ok);
    assert!(stdout.contains("tasks/s"));
    assert!(stdout.contains("Speedup decomposition"));
}

#[test]
fn gen_writes_all_models() {
    let dir = std::env::temp_dir().join(format!("fw_cli_gen_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for model in ["er", "grid", "scale-free", "geometric", "ring", "dag"] {
        let out = dir.join(format!("{model}.edges"));
        let (ok, _, stderr) = run(&[
            "gen",
            "--model",
            model,
            "--n",
            "64",
            "--out",
            out.to_str().unwrap(),
        ]);
        assert!(ok, "{model}: {stderr}");
        assert!(out.exists());
        let g = fw_stage::graph::io::load(&out).unwrap();
        assert!(g.n() >= 16, "{model} produced n={}", g.n());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gen_rejects_unknown_model_and_flags() {
    let (ok, _, stderr) = run(&["gen", "--model", "mystery"]);
    assert!(!ok);
    assert!(stderr.contains("unknown model"));
    let (ok, _, stderr) = run(&["gen", "--frobnicate", "1"]);
    assert!(!ok);
    assert!(stderr.contains("unknown flag"));
}

#[test]
fn solve_file_end_to_end() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let dir = std::env::temp_dir().join(format!("fw_cli_solve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.edges");
    let out_path = dir.join("d.dist");
    let (ok, _, stderr) = run(&[
        "gen", "--model", "er", "--n", "80", "--seed", "9",
        "--out", graph_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--output", out_path.to_str().unwrap(),
        "--variant", "staged",
    ]);
    assert!(ok, "{stderr}");
    assert!(stderr.contains("via device"), "{stderr}");
    // verify against the CPU oracle
    let g = fw_stage::graph::io::load(&graph_path).unwrap();
    let d = fw_stage::graph::io::load(&out_path).unwrap();
    let cpu = fw_stage::apsp::naive::solve(&g);
    assert!(d.allclose(&cpu, 1e-5, 1e-5));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn solve_paths_flag_prints_reconstructed_path() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let dir = std::env::temp_dir().join(format!("fw_cli_paths_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.edges");
    let (ok, _, stderr) = run(&[
        "gen", "--model", "ring", "--n", "12",
        "--out", graph_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    // a ring's 0 → 5 path is forced through every intermediate vertex
    let (ok, stdout, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--paths", "--src", "0", "--dst", "5",
    ]);
    assert!(ok, "{stderr}");
    assert!(
        stdout.contains("path 0 -> 5: 0 -> 1 -> 2 -> 3 -> 4 -> 5"),
        "unexpected path output: {stdout}"
    );
    assert!(stdout.contains("cost"), "{stdout}");
    // unreachable src/dst out of range is a clean error
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--paths", "--src", "0", "--dst", "99",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--src/--dst"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn solve_update_applies_edge_deltas_incrementally() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let dir = std::env::temp_dir().join(format!("fw_cli_update_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.edges");
    let (ok, _, stderr) = run(&[
        "gen", "--model", "ring", "--n", "12",
        "--out", graph_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    // insert a shortcut 0 → 5: the updated closure must route through it
    let (ok, stdout, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--update", "0,5,0.5",
        "--paths", "--src", "0", "--dst", "5",
    ]);
    assert!(ok, "{stderr}");
    assert!(stderr.contains("via incremental"), "{stderr}");
    assert!(stdout.contains("path 0 -> 5: 0 -> 5"), "{stdout}");
    // delete the ring's only 4 → 5 edge: 0 → 5 becomes unreachable
    // (increase path: successor-forest damage detection)
    let (ok, stdout, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--update", "4,5,inf",
        "--paths", "--src", "0", "--dst", "5",
    ]);
    assert!(ok, "{stderr}");
    assert!(stderr.contains("via incremental"), "{stderr}");
    assert!(stdout.contains("path 0 -> 5: unreachable"), "{stdout}");
    // malformed spec is a clean error
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--update", "nope",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--update"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn solve_objective_round_trips() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let dir = std::env::temp_dir().join(format!("fw_cli_obj_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.edges");
    let (ok, _, stderr) = run(&[
        "gen", "--model", "er", "--n", "40", "--seed", "17",
        "--out", graph_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    let g = fw_stage::graph::io::load(&graph_path).unwrap();

    // bottleneck: the served closure matches the in-process semiring
    // oracle exactly (non-shortest objectives are CPU-routed at tile 32)
    use fw_stage::apsp::semiring::{self, Objective};
    let out_path = dir.join("bottleneck.dist");
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--output", out_path.to_str().unwrap(),
        "--objective", "bottleneck",
    ]);
    assert!(ok, "{stderr}");
    let served = fw_stage::graph::io::load(&out_path).unwrap();
    let prepared = Objective::Bottleneck.prepare(&g).unwrap();
    assert_eq!(served, semiring::blocked_solve(Objective::Bottleneck, &prepared, 32));

    // reachability: the dumped closure is exactly boolean
    let out_path = dir.join("reach.dist");
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--output", out_path.to_str().unwrap(),
        "--objective", "reachability",
    ]);
    assert!(ok, "{stderr}");
    let reach = fw_stage::graph::io::load(&out_path).unwrap();
    assert!(reach.as_slice().iter().all(|&v| v == 0.0 || v == 1.0), "non-boolean closure");

    // unknown objective is a clean typed rejection
    let (ok, _, stderr) = run(&[
        "solve",
        "--input", graph_path.to_str().unwrap(),
        "--objective", "widest",
    ]);
    assert!(!ok);
    assert!(stderr.contains("widest"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn solve_update_rejects_non_shortest_objective() {
    // rejected at flag validation, before any artifact or file I/O
    let (ok, _, stderr) = run(&[
        "solve", "--input", "nonexistent.edges",
        "--update", "0,1,2.0", "--objective", "bottleneck",
    ]);
    assert!(!ok);
    assert!(stderr.contains("shortest objective only"), "{stderr}");
}

#[test]
fn client_objective_round_trips_over_tcp() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    use std::io::{BufRead, BufReader};
    let dir = std::env::temp_dir().join(format!("fw_cli_obj_tcp_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.edges");
    let (ok, _, stderr) = run(&[
        "gen", "--model", "er", "--n", "24", "--seed", "23",
        "--out", graph_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");

    let mut server = Command::new(binary())
        .args(["serve", "--addr", "127.0.0.1:0"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut reader = BufReader::new(server.stderr.take().unwrap());
    let mut addr = String::new();
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap_or(0) > 0 {
        if let Some(rest) = line.strip_prefix("fw-stage serving on ") {
            addr = rest.split_whitespace().next().unwrap_or("").to_string();
            break;
        }
        line.clear();
    }

    let out_path = dir.join("bottleneck.dist");
    let solve = run(&[
        "client", "--addr", &addr,
        "--input", graph_path.to_str().unwrap(),
        "--output", out_path.to_str().unwrap(),
        "--objective", "bottleneck",
    ]);
    let bad = run(&[
        "client", "--addr", &addr,
        "--input", graph_path.to_str().unwrap(),
        "--objective", "widest",
    ]);
    let _ = server.kill();
    let _ = server.wait();

    assert!(!addr.is_empty(), "server never announced its address");
    assert!(solve.0, "{}", solve.2);
    let g = fw_stage::graph::io::load(&graph_path).unwrap();
    let served = fw_stage::graph::io::load(&out_path).unwrap();
    use fw_stage::apsp::semiring::{self, Objective};
    let prepared = Objective::Bottleneck.prepare(&g).unwrap();
    assert_eq!(served, semiring::blocked_solve(Objective::Bottleneck, &prepared, 32));
    // unknown objective comes back as the server's typed error
    assert!(!bad.0);
    assert!(bad.2.contains("widest"), "{}", bad.2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn info_describes_artifacts() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let (ok, stdout, stderr) = run(&["info"]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("staged"), "{stdout}");
    assert!(stdout.contains("tile: 32"));
}

#[test]
fn solve_missing_input_is_error() {
    let (ok, _, stderr) = run(&["solve"]);
    assert!(!ok);
    assert!(stderr.contains("--input"));
}

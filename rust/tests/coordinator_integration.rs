//! End-to-end coordinator tests: TCP server ↔ client ↔ engine ↔ PJRT.
//!
//! Skipped (with a notice) when artifacts/ has not been built.

use std::path::PathBuf;
use std::sync::Arc;

use fw_stage::apsp;
use fw_stage::coordinator::{self, client::Client, server::Server, Coordinator};
use fw_stage::graph::{generators, DistMatrix};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn start() -> Option<(Arc<Coordinator>, Server)> {
    let dir = artifact_dir()?;
    let mut config = coordinator::Config::new(&dir);
    config.engine.batch_window = std::time::Duration::from_millis(1);
    let coord = Arc::new(Coordinator::start(config).expect("coordinator"));
    let server = Server::spawn(coord.clone(), "127.0.0.1:0").expect("server");
    Some((coord, server))
}

macro_rules! with_server {
    (|$coord:ident, $server:ident| $body:block) => {
        match start() {
            Some(($coord, $server)) => $body,
            None => eprintln!("SKIP: artifacts/ not built (run `make artifacts`)"),
        }
    };
}

#[test]
fn tcp_solve_matches_oracle() {
    with_server!(|coord, server| {
        let addr = server.addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client.ping().unwrap();
        let g = generators::erdos_renyi(100, 0.3, 301);
        let resp = client.solve(&g, "staged").unwrap();
        assert_eq!(resp.dist.n(), 100);
        assert_eq!(resp.bucket, 128); // padded up
        let cpu = apsp::naive::solve(&g);
        assert!(resp.dist.allclose(&cpu, 1e-5, 1e-5));
        let _ = coord;
    });
}

#[test]
fn small_graphs_served_by_cpu_route() {
    with_server!(|coord, server| {
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        let g = generators::ring(16); // ≤ cpu_threshold
        let resp = client.solve(&g, "staged").unwrap();
        assert_eq!(resp.source, coordinator::Source::Cpu);
        assert!(resp.dist.allclose(&apsp::naive::solve(&g), 1e-5, 1e-6));
        let _ = coord;
    });
}

#[test]
fn cache_hit_on_repeat() {
    with_server!(|coord, server| {
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        let g = generators::erdos_renyi(96, 0.4, 303);
        let first = client.solve(&g, "staged").unwrap();
        assert_ne!(first.source, coordinator::Source::Cache);
        let second = client.solve(&g, "staged").unwrap();
        assert_eq!(second.source, coordinator::Source::Cache);
        assert_eq!(first.dist, second.dist);
        let _ = coord;
    });
}

#[test]
fn concurrent_clients_batched() {
    with_server!(|coord, server| {
        let addr = server.addr().to_string();
        // many small same-size requests arriving together: the engine packs
        // them into block-diagonal batches
        let handles: Vec<_> = (0..8u64)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let g = generators::erdos_renyi(60, 0.35, 400 + i);
                    let resp = client.solve(&g, "staged").unwrap();
                    (g, resp)
                })
            })
            .collect();
        for h in handles {
            let (g, resp) = h.join().unwrap();
            let cpu = apsp::naive::solve(&g);
            assert!(
                resp.dist.allclose(&cpu, 1e-5, 1e-5),
                "batched result diverges from oracle"
            );
        }
        let snap = coord.metrics().snapshot();
        let batches = snap.get("batches").as_f64().unwrap_or(0.0);
        let items = snap.get("batched_items").as_f64().unwrap_or(0.0);
        assert!(items >= batches, "{snap}");
        let _ = server;
    });
}

#[test]
fn stats_and_info_endpoints() {
    with_server!(|coord, server| {
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        let g = generators::erdos_renyi(64, 0.3, 305);
        client.solve(&g, "staged").unwrap();
        let stats = client.stats().unwrap();
        assert!(stats.get("requests").as_f64().unwrap() >= 1.0);
        // latency percentiles and superblock counters are part of the wire
        // contract of the stats endpoint
        for key in [
            "latency_p50_s",
            "latency_p95_s",
            "latency_p99_s",
            "superblock_solves",
            "superblock_rounds",
            "superblock_tiles",
        ] {
            assert!(stats.get(key).as_f64().is_some(), "missing {key}: {stats}");
        }
        let p50 = stats.get("latency_p50_s").as_f64().unwrap();
        let p99 = stats.get("latency_p99_s").as_f64().unwrap();
        assert!(p50 <= p99);
        let info = client.info().unwrap();
        let variants: Vec<&str> = info
            .get("variants")
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|v| v.as_str())
            .collect();
        assert!(variants.contains(&"staged"));
        assert!(!info.get("buckets").as_arr().unwrap().is_empty());
        let _ = coord;
    });
}

#[test]
fn malformed_requests_get_errors_and_connection_survives() {
    with_server!(|coord, server| {
        use std::io::{BufRead, BufReader, Write};
        let stream = std::net::TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        for bad in [
            "this is not json",
            r#"{"type":"solve"}"#,
            r#"{"type":"unknown-op"}"#,
            r#"{"type":"solve","n":4,"edges":[[0,99,1.0]]}"#,
        ] {
            writer.write_all(bad.as_bytes()).unwrap();
            writer.write_all(b"\n").unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            assert!(reply.contains("\"error\""), "for {bad}: {reply}");
        }
        // connection still works after errors
        writer.write_all(b"{\"type\":\"ping\"}\n").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("pong"));
        let _ = coord;
    });
}

#[test]
fn unknown_variant_is_client_error() {
    with_server!(|coord, server| {
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        let g = generators::erdos_renyi(64, 0.3, 311);
        let err = client.solve(&g, "warp-drive").unwrap_err();
        assert!(err.to_string().contains("warp-drive"), "{err}");
        let _ = coord;
    });
}

#[test]
fn solve_graph_convenience_and_all_variants() {
    with_server!(|coord, server| {
        let g = generators::grid(9, 17); // 81 vertices → device route
        let cpu = apsp::naive::solve(&g);
        for variant in coord.manifest_summary().variants.clone() {
            let dist = coord.solve_graph(&g, &variant).unwrap();
            assert!(dist.allclose(&cpu, 1e-5, 1e-5), "variant {variant}");
        }
        let dist = coord.solve_graph(&g, "cpu").unwrap();
        assert!(dist.allclose(&cpu, 1e-5, 1e-5));
        let _ = server;
    });
}

#[test]
fn oversized_graph_served_by_superblock_tier() {
    with_server!(|coord, server| {
        // larger than the largest artifact bucket (512 in the default
        // build): pre-superblock this was a hard batcher error, now it is
        // served (an edgeless graph keeps the test cheap; the full closure
        // check lives in tests/superblock_integration.rs)
        let g = DistMatrix::unconnected(520);
        let resp = coord
            .solve(&coordinator::Request {
                id: 9,
                graph: g,
                variant: "staged".into(),
                no_cache: true,
                want_paths: false,
                objective: "shortest".into(),
                trace: false,
            })
            .expect("oversized graphs are served by the superblock tier");
        assert_eq!(resp.source, coordinator::Source::SuperBlock);
        assert_eq!(resp.dist.n(), 520);
        // edgeless in, edgeless closure out
        assert!(resp.dist.get(0, 519).is_infinite());
        assert_eq!(resp.dist.get(519, 519), 0.0);
        let _ = server;
    });
}

#[test]
fn device_scale_paths_request_falls_back_to_cpu() {
    with_server!(|coord, server| {
        // device-routed size, but want_paths: the artifacts compute
        // distances only, so the engine's CPU path fallback serves it.
        // n=100 is NOT a multiple of the tile — the fallback must pad to
        // 128 and truncate (banded fast path), never degrade to the
        // single-threaded reference solver
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        let g = generators::erdos_renyi(100, 0.3, 317);
        let resp = client.solve_paths(&g, "staged").unwrap();
        assert_eq!(resp.source, coordinator::Source::Cpu);
        let succ = resp.succ.clone().expect("successors present");
        let r = fw_stage::apsp::paths::PathsResult::from_parts(resp.dist.clone(), succ);
        // distances bitwise-equal to the padded CPU blocked tier (the
        // fallback's documented padding trick)
        assert_eq!(r.dist, apsp::blocked::solve(&g.padded(128), 32).truncated(100));
        // every reconstructed path is a real walk of the reported length
        for (i, j) in [(0, 99), (17, 4), (50, 50)] {
            match r.path(i, j) {
                Some(_) => {
                    let w = r.path_weight(&g, i, j).expect("valid edge walk");
                    let d = r.dist.get(i, j) as f64;
                    assert!((w - d).abs() < 1e-3, "({i},{j}): {w} vs {d}");
                }
                None => assert!(!r.dist.get(i, j).is_finite() || i == j),
            }
        }
        // and a device-routed *distance* request for the same graph still
        // uses the device, sharing the cache entry without clobbering succ
        let dist_resp = client.solve(&g, "staged").unwrap();
        assert_eq!(dist_resp.source, coordinator::Source::Cache);
        let again = client.solve_paths(&g, "staged").unwrap();
        assert_eq!(again.source, coordinator::Source::Cache);
        assert_eq!(again.succ, resp.succ);
        let _ = coord;
    });
}

#[test]
fn invalid_superblock_bucket_override_is_clean_error() {
    match artifact_dir() {
        None => eprintln!("SKIP: artifacts/ not built (run `make artifacts`)"),
        Some(dir) => {
            let mut config = coordinator::Config::new(&dir);
            config.router.superblock_bucket = Some(100); // not a lowered size
            let coord = Coordinator::start(config).expect("coordinator");
            let err = coord
                .solve(&coordinator::Request {
                    id: 1,
                    graph: DistMatrix::unconnected(600),
                    variant: "staged".into(),
                    no_cache: true,
                    want_paths: false,
                    objective: "shortest".into(),
                    trace: false,
                })
                .unwrap_err();
            assert!(
                err.to_string().contains("not a lowered artifact size"),
                "{err}"
            );
        }
    }
}

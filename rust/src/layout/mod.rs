//! The paper's data-layout contribution (§4.3): the doubly-tiled row-major
//! order, plus analytic models of the two access-pattern problems it solves
//! (global-memory coalescing, Fig. 5; shared-memory bank conflicts, Fig. 6).

mod banks;
mod tiled;

pub use banks::{bank_conflict_degree, AccessPattern, KSchedule, BANKS, HALF_WARP};
pub use tiled::{coalesced_run_length, from_doubly_tiled, tiled_index, to_doubly_tiled, Axis};

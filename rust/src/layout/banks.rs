//! Shared-memory bank-conflict model (paper §4.3, Figure 6).
//!
//! CUDA compute-1.x shared memory has 16 banks and one broadcast slot: a
//! half-warp's load completes in one cycle iff the 16 threads hit 16
//! distinct banks, *or* all 16 read the very same word.  Partial same-word
//! reads (4 threads on one word) serialize — this is exactly the paper's
//! observation that the 4×4 tiled layout creates "4-way data conflicts"
//! even though the colliding threads want the *same* element.
//!
//! The staged kernel stores the panel slices k-minor (`c[i][k]`, `r[j][k]`
//! with stride m), so with the natural k order all threads sharing an i (or
//! j) hit one word.  The paper's fix — start each thread's k loop at
//! `(i + j) mod 4` (the *cyclic* schedule) — spreads the 16 accesses over
//! 16 distinct words in 16 distinct banks.
//!
//! This module reproduces Figure 6's analysis exactly: conflict degree 1
//! (row-major + simple), 4 (tiled + simple), 1 (tiled + cyclic).  The C1060
//! simulator consumes the resulting cycles-per-access factor.

/// Number of shared-memory banks (compute capability 1.x).
pub const BANKS: usize = 16;
/// Threads per half-warp (the shared-memory transaction unit).
pub const HALF_WARP: usize = 16;
/// k-steps resident per stage in the staged kernel (m = t/4 with the
/// paper's 4-stage split; the cyclic offset is mod this).
const M: usize = 4;
/// Tile size.
const T: usize = 32;

/// How tile data is arranged and how a half-warp's threads map to elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessPattern {
    /// Row-major 32×32 tiles in shared memory; half-warp = 16 consecutive
    /// elements of one row (Katz–Kider / Fig. 6 top).
    RowMajor,
    /// 4×4 sub-tiled data; half-warp = one 4×4 element block, panel slices
    /// stored k-minor with stride m (staged kernel / Fig. 6 middle+bottom).
    Tiled4x4,
}

/// The k-iteration schedule within a stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KSchedule {
    /// Every thread starts at k = 0 (natural order; Fig. 6 middle).
    Simple,
    /// Thread at in-tile (i, j) starts at k = (i + j) mod m (Fig. 6 bottom).
    Cyclic,
}

/// Worst conflict degree (cycles per shared-memory access) across the two
/// panel reads over a full m-step stage.
pub fn bank_conflict_degree(pattern: AccessPattern, schedule: KSchedule) -> usize {
    let coords: Vec<(usize, usize)> = match pattern {
        AccessPattern::RowMajor => (0..HALF_WARP).map(|t| (0, t)).collect(),
        AccessPattern::Tiled4x4 => (0..HALF_WARP).map(|t| (t / 4, t % 4)).collect(),
    };
    let mut worst = 1usize;
    for step in 0..M {
        let mut row_words = Vec::with_capacity(HALF_WARP); // j-aligned read
        let mut col_words = Vec::with_capacity(HALF_WARP); // i-aligned read
        for &(i, j) in &coords {
            let k = match schedule {
                KSchedule::Simple => step,
                KSchedule::Cyclic => (i + j + step) % M,
            };
            match pattern {
                AccessPattern::RowMajor => {
                    // full 32×32 tiles resident: r[k][j], c[i][k], stride T
                    row_words.push(k * T + j);
                    col_words.push(i * T + k);
                }
                AccessPattern::Tiled4x4 => {
                    // staged t×m slices, k-minor: r[j][k], c[i][k], stride M
                    row_words.push(j * M + k);
                    col_words.push(i * M + k);
                }
            }
        }
        worst = worst
            .max(conflict_degree(&row_words))
            .max(conflict_degree(&col_words));
    }
    worst
}

/// Conflict degree of one half-warp access under CC 1.x rules:
/// full-half-warp same-word reads broadcast in 1 cycle; otherwise the
/// access serializes to the max number of threads landing on one bank
/// (same-word collisions included — only the single broadcast word is free,
/// and only when *all* threads use it).
fn conflict_degree(words: &[usize]) -> usize {
    debug_assert_eq!(words.len(), HALF_WARP);
    if words.iter().all(|&w| w == words[0]) {
        return 1; // broadcast
    }
    let mut per_bank = [0usize; BANKS];
    for &w in words {
        per_bank[w % BANKS] += 1;
    }
    per_bank.iter().copied().max().unwrap_or(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_simple_is_conflict_free() {
        // Fig. 6 top: j-panel hits 16 distinct banks; i-panel broadcasts
        assert_eq!(
            bank_conflict_degree(AccessPattern::RowMajor, KSchedule::Simple),
            1
        );
    }

    #[test]
    fn tiled_simple_has_4way_conflicts() {
        // Fig. 6 middle: "threads 0, 4, 8, and 12 all access the same data
        // element in the j-aligned tile ... resulting in 4-way conflicts"
        assert_eq!(
            bank_conflict_degree(AccessPattern::Tiled4x4, KSchedule::Simple),
            4
        );
    }

    #[test]
    fn tiled_cyclic_is_conflict_free() {
        // Fig. 6 bottom: the cyclic k-offset spreads the half-warp over 16
        // distinct banks — "conflict free shared memory data access"
        assert_eq!(
            bank_conflict_degree(AccessPattern::Tiled4x4, KSchedule::Cyclic),
            1
        );
    }

    #[test]
    fn full_broadcast_is_one_cycle() {
        assert_eq!(conflict_degree(&[7; HALF_WARP]), 1);
    }

    #[test]
    fn partial_same_word_serializes() {
        // 4 groups of 4 threads, each group on its own word; words 0,4,8,12
        // share no banks → degree = threads per word = 4
        let words: Vec<usize> = (0..HALF_WARP).map(|t| (t / 4) * 4).collect();
        assert_eq!(conflict_degree(&words), 4);
    }

    #[test]
    fn distinct_banks_one_cycle() {
        let words: Vec<usize> = (0..HALF_WARP).collect();
        assert_eq!(conflict_degree(&words), 1);
    }

    #[test]
    fn stride_16_worst_case() {
        // all threads in bank 0 with distinct words: fully serialized
        let words: Vec<usize> = (0..HALF_WARP).map(|t| t * BANKS).collect();
        assert_eq!(conflict_degree(&words), 16);
    }
}

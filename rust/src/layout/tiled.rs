//! Doubly-tiled row-major data order (paper §4.3, after Han, Franchetti &
//! Püschel [7]).
//!
//! The staged kernel must read both a *row slice* and a *column slice* of a
//! 32×32 tile as contiguous 16-word transactions (a CUDA half-warp).  In
//! plain row-major order a column slice touches 1 word per row — 16
//! transactions for 16 words (Fig. 5, top).  The paper's fix: tile the
//! matrix twice — 32×32 tiles in row-major order, and *within* each tile,
//! 4×4 sub-tiles in row-major order.  Then any 4 rows or 4 columns of a
//! tile are made of whole 4×4 sub-tiles, i.e. contiguous 16-word blocks in
//! either direction (Fig. 5, bottom).
//!
//! On the TPU the analogous constraint is the (sublane, lane) = (8, 128)
//! native layout; the transform is kept here both as the faithful
//! reproduction of §4.3 and as the layout the C1060 simulator's bandwidth
//! model consumes.

/// Matrix access direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    /// Walk along a row (j varies).
    Row,
    /// Walk along a column (i varies).
    Col,
}

/// Linear index of element `(i, j)` of an `n × n` matrix stored doubly
/// tiled: `s × s` tiles row-major, `t × t` sub-tiles row-major within each
/// tile, elements row-major within each sub-tile.
///
/// Requires `n % s == 0 && s % t == 0`.
#[inline]
pub fn tiled_index(i: usize, j: usize, n: usize, s: usize, t: usize) -> usize {
    debug_assert!(n % s == 0 && s % t == 0, "n={n}, s={s}, t={t}");
    debug_assert!(i < n && j < n);
    let (tile_i, in_tile_i) = (i / s, i % s);
    let (tile_j, in_tile_j) = (j / s, j % s);
    let (sub_i, in_sub_i) = (in_tile_i / t, in_tile_i % t);
    let (sub_j, in_sub_j) = (in_tile_j / t, in_tile_j % t);
    let tiles_per_row = n / s;
    let subs_per_row = s / t;
    let tile_base = (tile_i * tiles_per_row + tile_j) * s * s;
    let sub_base = (sub_i * subs_per_row + sub_j) * t * t;
    tile_base + sub_base + in_sub_i * t + in_sub_j
}

/// Convert a row-major buffer to doubly-tiled order.
pub fn to_doubly_tiled(data: &[f32], n: usize, s: usize, t: usize) -> Vec<f32> {
    assert_eq!(data.len(), n * n);
    assert!(n % s == 0 && s % t == 0, "n={n} s={s} t={t}");
    let mut out = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            out[tiled_index(i, j, n, s, t)] = data[i * n + j];
        }
    }
    out
}

/// Convert a doubly-tiled buffer back to row-major order.
pub fn from_doubly_tiled(data: &[f32], n: usize, s: usize, t: usize) -> Vec<f32> {
    assert_eq!(data.len(), n * n);
    assert!(n % s == 0 && s % t == 0, "n={n} s={s} t={t}");
    let mut out = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            out[i * n + j] = data[tiled_index(i, j, n, s, t)];
        }
    }
    out
}

/// Minimum contiguous run length (in elements) when reading a `t`-thick
/// slice of a tile along `axis` — the quantity Fig. 5 argues about.
///
/// * Row-major (`t = 0` sentinel not used; pass `t = 1` for plain
///   row-major): a `Row` walk is fully contiguous, a `Col` walk has run
///   length 1.
/// * Doubly tiled with `t × t` sub-tiles: both directions come in whole
///   sub-tiles ⇒ run length `t·t`.
pub fn coalesced_run_length(axis: Axis, n: usize, s: usize, t: usize) -> usize {
    assert!(n % s == 0 && s % t == 0);
    if t == 1 {
        // plain row-major
        return match axis {
            Axis::Row => n, // whole row contiguous
            Axis::Col => 1, // stride n between consecutive elements
        };
    }
    // doubly tiled: a t-thick slice in either direction is whole t×t
    // sub-tiles, each contiguous
    t * t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Rng::new(1);
        for (n, s, t) in [(32, 32, 4), (64, 32, 4), (128, 32, 4), (64, 16, 4), (64, 32, 8)] {
            let data: Vec<f32> = (0..n * n).map(|_| rng.next_f32()).collect();
            let tiled = to_doubly_tiled(&data, n, s, t);
            assert_eq!(from_doubly_tiled(&tiled, n, s, t), data, "n={n} s={s} t={t}");
        }
    }

    #[test]
    fn index_is_bijection() {
        let (n, s, t) = (64, 32, 4);
        let mut seen = vec![false; n * n];
        for i in 0..n {
            for j in 0..n {
                let idx = tiled_index(i, j, n, s, t);
                assert!(!seen[idx], "collision at ({i},{j})");
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn tiles_are_contiguous() {
        // each 32×32 tile occupies one contiguous s·s range (paper §4.3:
        // "each 32 by 32 tile and each 4 by 4 tile is contiguous in memory")
        let (n, s, t) = (64, 32, 4);
        for tile_i in 0..n / s {
            for tile_j in 0..n / s {
                let base = (tile_i * (n / s) + tile_j) * s * s;
                for i in 0..s {
                    for j in 0..s {
                        let idx = tiled_index(tile_i * s + i, tile_j * s + j, n, s, t);
                        assert!(
                            (base..base + s * s).contains(&idx),
                            "tile ({tile_i},{tile_j}) element ({i},{j}) leaked to {idx}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn subtiles_are_contiguous() {
        let (n, s, t) = (32, 32, 4);
        // the 4 rows × 4 cols at (8..12, 16..20) must be 16 consecutive words
        let idxs: Vec<usize> = (8..12)
            .flat_map(|i| (16..20).map(move |j| tiled_index(i, j, n, s, t)))
            .collect();
        let base = idxs[0];
        assert_eq!(idxs, (base..base + 16).collect::<Vec<_>>());
    }

    #[test]
    fn four_columns_are_whole_subtiles() {
        // Fig. 5's claim: 4 adjacent columns of a tile = contiguous 16-word
        // blocks. Verify columns 4..8 of a tile decompose into t*t runs.
        let (n, s, t) = (32, 32, 4);
        for sub_row in 0..s / t {
            let idxs: Vec<usize> = (sub_row * t..sub_row * t + t)
                .flat_map(|i| (4..8).map(move |j| tiled_index(i, j, n, s, t)))
                .collect();
            let min = *idxs.iter().min().unwrap();
            let mut sorted = idxs.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (min..min + 16).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_lengths_match_figure5() {
        // row-major: columns stride by n
        assert_eq!(coalesced_run_length(Axis::Row, 64, 32, 1), 64);
        assert_eq!(coalesced_run_length(Axis::Col, 64, 32, 1), 1);
        // doubly tiled 4×4: both directions in 16-word blocks
        assert_eq!(coalesced_run_length(Axis::Row, 64, 32, 4), 16);
        assert_eq!(coalesced_run_length(Axis::Col, 64, 32, 4), 16);
    }

    #[test]
    #[should_panic]
    fn rejects_non_dividing_tile() {
        to_doubly_tiled(&vec![0.0; 36 * 36], 36, 32, 4);
    }
}

//! Graph substrate: dense distance matrices, generators, and I/O.
//!
//! The whole stack works on dense `f32` adjacency/distance matrices
//! ([`DistMatrix`]) — Floyd-Warshall "doesn't suffer performance degradation
//! for dense graphs, and has predictable execution regardless of the
//! underlying data" (paper §1), so dense is the natural representation.
//! `+inf` encodes "no edge"; diagonals are 0.

pub mod generators;
pub mod io;
mod matrix;

pub use matrix::DistMatrix;

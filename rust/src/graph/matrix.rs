//! Dense row-major distance matrix — the shared currency of the stack.

use crate::{Dist, INF};

/// A dense `n × n` matrix of path lengths in row-major order.
///
/// Invariants maintained by constructors (and checked by
/// [`DistMatrix::validate`]):
/// * square, row-major, `f32`
/// * `get(i, i) == 0` for graphs produced by generators/IO (APSP *outputs*
///   keep whatever the solver computed — 0 unless a negative cycle exists)
/// * missing edges are `+inf`, never NaN
/// * no `-0.0` (a `-0.0`/`+0.0` tie is the one case where a branchless
///   `f32::min` may pick a different bit pattern than the branchy accept,
///   and the blocked tiers' bitwise-equality contracts assume it cannot
///   happen; FW sums never *create* `-0.0` from clean inputs, so rejecting
///   it at the boundary — the coordinator validates every request — keeps
///   the whole stack clean)
#[derive(Clone, Debug, PartialEq)]
pub struct DistMatrix {
    n: usize,
    data: Vec<Dist>,
}

impl DistMatrix {
    /// A graph with no edges: all `+inf`, zero diagonal.
    pub fn unconnected(n: usize) -> Self {
        let mut m = Self {
            n,
            data: vec![INF; n * n],
        };
        for i in 0..n {
            m.set(i, i, 0.0);
        }
        m
    }

    /// Build from a row-major buffer (must be `n*n` long).
    pub fn from_vec(n: usize, data: Vec<Dist>) -> Self {
        assert_eq!(data.len(), n * n, "buffer length {} != {n}²", data.len());
        Self { n, data }
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> Dist {
        debug_assert!(i < self.n && j < self.n);
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, w: Dist) {
        debug_assert!(i < self.n && j < self.n);
        self.data[i * self.n + j] = w;
    }

    /// Row-major backing slice.
    #[inline]
    pub fn as_slice(&self) -> &[Dist] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [Dist] {
        &mut self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[Dist] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<Dist> {
        self.data
    }

    /// Number of finite off-diagonal edges.
    pub fn edge_count(&self) -> usize {
        let mut count = 0;
        for i in 0..self.n {
            for j in 0..self.n {
                if i != j && self.get(i, j).is_finite() {
                    count += 1;
                }
            }
        }
        count
    }

    /// Pad to `m ≥ n` with unreachable vertices (inf rows/cols, 0 diagonal).
    /// Padding never changes distances among the original vertices — padded
    /// vertices have no edges, so no path can route through them.
    pub fn padded(&self, m: usize) -> DistMatrix {
        assert!(m >= self.n, "cannot pad {} down to {m}", self.n);
        let mut out = DistMatrix::unconnected(m);
        for i in 0..self.n {
            out.data[i * m..i * m + self.n].copy_from_slice(self.row(i));
        }
        out
    }

    /// Take the top-left `m × m` corner (inverse of [`DistMatrix::padded`]).
    pub fn truncated(&self, m: usize) -> DistMatrix {
        assert!(m <= self.n, "cannot truncate {} up to {m}", self.n);
        let mut out = DistMatrix::unconnected(m);
        for i in 0..m {
            out.data[i * m..(i + 1) * m].copy_from_slice(&self.row(i)[..m]);
        }
        out
    }

    /// Structural validation; returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        if self.data.len() != self.n * self.n {
            return Err(format!(
                "backing length {} != n²={}",
                self.data.len(),
                self.n * self.n
            ));
        }
        for i in 0..self.n {
            for j in 0..self.n {
                let w = self.get(i, j);
                if w.is_nan() {
                    return Err(format!("NaN at ({i}, {j})"));
                }
                if w == f32::NEG_INFINITY {
                    return Err(format!("-inf at ({i}, {j})"));
                }
                if w == 0.0 && w.is_sign_negative() {
                    return Err(format!("-0.0 at ({i}, {j})"));
                }
            }
        }
        Ok(())
    }

    /// Max |a - b| over all finite pairs; `inf` if finiteness patterns differ.
    pub fn max_abs_diff(&self, other: &DistMatrix) -> f64 {
        assert_eq!(self.n, other.n, "size mismatch");
        let mut worst = 0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            match (a.is_finite(), b.is_finite()) {
                (true, true) => worst = worst.max((*a as f64 - *b as f64).abs()),
                (false, false) => {}
                _ => return f64::INFINITY,
            }
        }
        worst
    }

    /// Approximate equality with absolute + relative tolerance (f32 APSP
    /// results differ across solvers by rounding association).
    pub fn allclose(&self, other: &DistMatrix, rtol: f64, atol: f64) -> bool {
        if self.n != other.n {
            return false;
        }
        self.data.iter().zip(&other.data).all(|(a, b)| {
            match (a.is_finite(), b.is_finite()) {
                (true, true) => {
                    let (a, b) = (*a as f64, *b as f64);
                    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
                }
                (false, false) => a == b, // both +inf (NaN rejected by validate)
                _ => false,
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconnected_shape() {
        let m = DistMatrix::unconnected(4);
        assert_eq!(m.n(), 4);
        for i in 0..4 {
            for j in 0..4 {
                if i == j {
                    assert_eq!(m.get(i, j), 0.0);
                } else {
                    assert!(m.get(i, j).is_infinite());
                }
            }
        }
        assert_eq!(m.edge_count(), 0);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut m = DistMatrix::unconnected(3);
        m.set(0, 2, 5.5);
        assert_eq!(m.get(0, 2), 5.5);
        assert_eq!(m.edge_count(), 1);
        assert_eq!(m.row(0), &[0.0, INF, 5.5]);
    }

    #[test]
    #[should_panic(expected = "buffer length")]
    fn from_vec_wrong_len_panics() {
        DistMatrix::from_vec(3, vec![0.0; 8]);
    }

    #[test]
    fn pad_truncate_roundtrip() {
        let mut m = DistMatrix::unconnected(3);
        m.set(0, 1, 1.0);
        m.set(2, 0, 2.0);
        let padded = m.padded(8);
        assert_eq!(padded.n(), 8);
        assert_eq!(padded.get(0, 1), 1.0);
        assert_eq!(padded.get(2, 0), 2.0);
        assert_eq!(padded.get(5, 5), 0.0);
        assert!(padded.get(0, 5).is_infinite());
        assert_eq!(padded.truncated(3), m);
    }

    #[test]
    fn validate_catches_nan_neg_inf_and_neg_zero() {
        let mut m = DistMatrix::unconnected(2);
        assert!(m.validate().is_ok());
        m.set(0, 1, f32::NAN);
        assert!(m.validate().unwrap_err().contains("NaN"));
        m.set(0, 1, f32::NEG_INFINITY);
        assert!(m.validate().unwrap_err().contains("-inf"));
        // -0.0 would let min-based (branchless) and compare-based (branchy)
        // relaxations pick different zero bit patterns on a tie; the blocked
        // tiers' bitwise contracts assume it never enters the stack
        m.set(0, 1, -0.0);
        assert!(m.validate().unwrap_err().contains("-0.0"));
        m.set(0, 1, 0.0);
        assert!(m.validate().is_ok());
    }

    #[test]
    fn allclose_tolerates_rounding() {
        let mut a = DistMatrix::unconnected(2);
        let mut b = a.clone();
        a.set(0, 1, 1.0);
        b.set(0, 1, 1.0 + 1e-7);
        assert!(a.allclose(&b, 1e-5, 1e-6));
        b.set(0, 1, 1.1);
        assert!(!a.allclose(&b, 1e-5, 1e-6));
    }

    #[test]
    fn allclose_requires_matching_inf_pattern() {
        let a = DistMatrix::unconnected(2);
        let mut b = a.clone();
        b.set(0, 1, 7.0);
        assert!(!a.allclose(&b, 1e-3, 1e-3));
        assert_eq!(a.max_abs_diff(&b), f64::INFINITY);
    }

    #[test]
    fn max_abs_diff_finite() {
        let mut a = DistMatrix::unconnected(2);
        let mut b = a.clone();
        a.set(0, 1, 1.0);
        b.set(0, 1, 1.5);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-12);
    }
}

//! Graph I/O: DIMACS shortest-path format, simple edge lists, and a raw
//! matrix dump for artifact-sized instances.
//!
//! Supported formats:
//!
//! * **DIMACS** (`.gr`, the 9th DIMACS Implementation Challenge format):
//!   `p sp <n> <m>` header, `a <u> <v> <w>` arc lines, `c` comments.
//!   1-based vertex ids, as in the published benchmark instances.
//! * **Edge list** (`.edges`): whitespace-separated `u v w` per line,
//!   0-based; `#` comments.  The format the examples write.
//! * **Matrix dump** (`.dist`): `n` on the first line then `n` rows of `n`
//!   whitespace-separated floats, `inf` for no-edge.  Round-trips APSP
//!   results exactly enough for golden files (17 significant digits).

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::DistMatrix;
use crate::INF;

// ---------------------------------------------------------------- DIMACS --

/// Parse DIMACS `.gr` text into a distance matrix.
pub fn parse_dimacs(text: &str) -> Result<DistMatrix> {
    let mut m: Option<DistMatrix> = None;
    let mut declared_arcs = 0usize;
    let mut seen_arcs = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('c') {
            continue;
        }
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("p") => {
                if m.is_some() {
                    bail!("line {}: duplicate problem line", lineno + 1);
                }
                let kind = parts.next().unwrap_or("");
                if kind != "sp" {
                    bail!("line {}: expected 'p sp', got 'p {kind}'", lineno + 1);
                }
                let n: usize = parts
                    .next()
                    .context("missing vertex count")?
                    .parse()
                    .context("bad vertex count")?;
                declared_arcs = parts
                    .next()
                    .context("missing arc count")?
                    .parse()
                    .context("bad arc count")?;
                m = Some(DistMatrix::unconnected(n));
            }
            Some("a") => {
                let m = m
                    .as_mut()
                    .with_context(|| format!("line {}: arc before problem line", lineno + 1))?;
                let u: usize = parts.next().context("missing tail")?.parse()?;
                let v: usize = parts.next().context("missing head")?.parse()?;
                let w: f32 = parts.next().context("missing weight")?.parse()?;
                if u == 0 || v == 0 || u > m.n() || v > m.n() {
                    bail!("line {}: vertex id out of range (1-based)", lineno + 1);
                }
                if u != v {
                    // parallel arcs: keep the lightest (standard convention)
                    let cur = m.get(u - 1, v - 1);
                    if w < cur {
                        m.set(u - 1, v - 1, w);
                    }
                }
                seen_arcs += 1;
            }
            Some(other) => bail!("line {}: unknown record '{other}'", lineno + 1),
            None => {}
        }
    }
    let m = m.context("no problem line found")?;
    if declared_arcs != seen_arcs {
        bail!("problem line declared {declared_arcs} arcs, file has {seen_arcs}");
    }
    Ok(m)
}

/// Serialize to DIMACS `.gr` text.
pub fn to_dimacs(m: &DistMatrix, comment: &str) -> String {
    let mut out = String::new();
    if !comment.is_empty() {
        for line in comment.lines() {
            out.push_str(&format!("c {line}\n"));
        }
    }
    out.push_str(&format!("p sp {} {}\n", m.n(), m.edge_count()));
    for i in 0..m.n() {
        for j in 0..m.n() {
            let w = m.get(i, j);
            if i != j && w.is_finite() {
                out.push_str(&format!("a {} {} {}\n", i + 1, j + 1, w));
            }
        }
    }
    out
}

// ------------------------------------------------------------- edge list --

/// Parse a `u v w` edge list (0-based). `n` is inferred as max id + 1 unless
/// a `# n <count>` header is present.
pub fn parse_edge_list(text: &str) -> Result<DistMatrix> {
    let mut edges: Vec<(usize, usize, f32)> = Vec::new();
    let mut declared_n: Option<usize> = None;
    let mut max_id = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let mut parts = rest.split_whitespace();
            if parts.next() == Some("n") {
                declared_n = Some(parts.next().context("bad '# n' header")?.parse()?);
            }
            continue;
        }
        let mut parts = line.split_whitespace();
        let u: usize = parts
            .next()
            .with_context(|| format!("line {}: missing tail", lineno + 1))?
            .parse()?;
        let v: usize = parts
            .next()
            .with_context(|| format!("line {}: missing head", lineno + 1))?
            .parse()?;
        let w: f32 = parts
            .next()
            .with_context(|| format!("line {}: missing weight", lineno + 1))?
            .parse()?;
        if w.is_nan() {
            bail!("line {}: NaN weight", lineno + 1);
        }
        max_id = max_id.max(u).max(v);
        edges.push((u, v, w));
    }
    let n = declared_n.unwrap_or(if edges.is_empty() { 0 } else { max_id + 1 });
    if max_id >= n && !edges.is_empty() {
        bail!("vertex id {max_id} exceeds declared n={n}");
    }
    let mut m = DistMatrix::unconnected(n);
    for (u, v, w) in edges {
        if u != v && w < m.get(u, v) {
            m.set(u, v, w);
        }
    }
    Ok(m)
}

/// Serialize to an edge list with a `# n` header.
pub fn to_edge_list(m: &DistMatrix) -> String {
    let mut out = format!("# n {}\n", m.n());
    for i in 0..m.n() {
        for j in 0..m.n() {
            let w = m.get(i, j);
            if i != j && w.is_finite() {
                out.push_str(&format!("{i} {j} {w}\n"));
            }
        }
    }
    out
}

// ------------------------------------------------------------ matrix dump --

/// Serialize the full matrix (`inf` for no edge) — used for golden results.
pub fn to_matrix_text(m: &DistMatrix) -> String {
    let mut out = format!("{}\n", m.n());
    for i in 0..m.n() {
        let row: Vec<String> = m
            .row(i)
            .iter()
            .map(|w| {
                if w.is_finite() {
                    format!("{w:.9e}")
                } else {
                    "inf".to_string()
                }
            })
            .collect();
        out.push_str(&row.join(" "));
        out.push('\n');
    }
    out
}

/// Parse a matrix dump.
pub fn parse_matrix_text(text: &str) -> Result<DistMatrix> {
    let mut lines = text.lines();
    let n: usize = lines
        .next()
        .context("empty matrix file")?
        .trim()
        .parse()
        .context("bad n header")?;
    let mut data = Vec::with_capacity(n * n);
    for i in 0..n {
        let line = lines.next().with_context(|| format!("missing row {i}"))?;
        for tok in line.split_whitespace() {
            let w = if tok == "inf" {
                INF
            } else {
                tok.parse::<f32>().with_context(|| format!("bad value {tok:?}"))?
            };
            data.push(w);
        }
        if data.len() != (i + 1) * n {
            bail!("row {i} has {} values, expected {n}", data.len() - i * n);
        }
    }
    Ok(DistMatrix::from_vec(n, data))
}

// ------------------------------------------------------------------ files --

/// Load a graph by extension: `.gr`/`.dimacs` → DIMACS, `.dist` → matrix,
/// anything else → edge list.
pub fn load(path: &Path) -> Result<DistMatrix> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    match path.extension().and_then(|e| e.to_str()) {
        Some("gr") | Some("dimacs") => parse_dimacs(&text),
        Some("dist") => parse_matrix_text(&text),
        _ => parse_edge_list(&text),
    }
}

/// Save a graph by extension (same mapping as [`load`]).
pub fn save(m: &DistMatrix, path: &Path) -> Result<()> {
    let text = match path.extension().and_then(|e| e.to_str()) {
        Some("gr") | Some("dimacs") => to_dimacs(m, "written by fw-stage"),
        Some("dist") => to_matrix_text(m),
        _ => to_edge_list(m),
    };
    let mut f = fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(text.as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn dimacs_roundtrip() {
        let g = generators::erdos_renyi(24, 0.3, 5);
        let text = to_dimacs(&g, "test graph");
        let back = parse_dimacs(&text).unwrap();
        assert_eq!(g, back);
    }

    #[test]
    fn dimacs_rejects_malformed() {
        assert!(parse_dimacs("a 1 2 3.0\n").is_err()); // arc before header
        assert!(parse_dimacs("p sp 2 1\na 1 3 1.0\n").is_err()); // id range
        assert!(parse_dimacs("p sp 2 2\na 1 2 1.0\n").is_err()); // arc count
        assert!(parse_dimacs("p xx 2 0\n").is_err()); // wrong kind
        assert!(parse_dimacs("").is_err());
    }

    #[test]
    fn dimacs_keeps_lightest_parallel_arc() {
        let g = parse_dimacs("p sp 2 2\na 1 2 5.0\na 1 2 3.0\n").unwrap();
        assert_eq!(g.get(0, 1), 3.0);
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = generators::scale_free(20, 2, 6);
        let back = parse_edge_list(&to_edge_list(&g)).unwrap();
        assert_eq!(g, back);
    }

    #[test]
    fn edge_list_infers_n() {
        let g = parse_edge_list("0 5 1.5\n5 0 2.5\n").unwrap();
        assert_eq!(g.n(), 6);
        assert_eq!(g.get(0, 5), 1.5);
    }

    #[test]
    fn edge_list_header_pads_isolated_vertices() {
        let g = parse_edge_list("# n 9\n0 1 1.0\n").unwrap();
        assert_eq!(g.n(), 9);
    }

    #[test]
    fn matrix_text_roundtrip_exact() {
        let g = generators::geometric(16, 0.5, 2);
        let back = parse_matrix_text(&to_matrix_text(&g)).unwrap();
        assert_eq!(g, back); // bit-exact through %.9e
    }

    #[test]
    fn file_roundtrip_by_extension() {
        let dir = std::env::temp_dir().join("fw_stage_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let g = generators::grid(4, 3);
        for name in ["g.gr", "g.edges", "g.dist"] {
            let path = dir.join(name);
            save(&g, &path).unwrap();
            assert_eq!(load(&path).unwrap(), g, "{name}");
        }
    }
}

//! Workload graph generators.
//!
//! The paper's introduction motivates APSP with "bioinformatics, routing,
//! and network analysis"; the generators here cover those shapes and are
//! what the examples, benches, and tests consume.  All are deterministic in
//! the seed (first-party Xoshiro PRNG) so every EXPERIMENTS.md number is
//! reproducible.

use crate::graph::DistMatrix;
use crate::util::prng::Rng;

/// G(n, p) Erdős–Rényi digraph with uniform weights in `[0.1, 10)`.
///
/// This matches the random dense instances used for the paper's Table 1
/// ("any graph with single precision edge weights" — FW's runtime is
/// data-independent, so the distribution only matters for validation).
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> DistMatrix {
    erdos_renyi_weighted(n, p, 0.1, 10.0, seed)
}

/// G(n, p) with uniform weights in `[lo, hi)`.
pub fn erdos_renyi_weighted(n: usize, p: f64, lo: f32, hi: f32, seed: u64) -> DistMatrix {
    assert!((0.0..=1.0).contains(&p), "p={p} out of range");
    let mut rng = Rng::new(seed);
    let mut m = DistMatrix::unconnected(n);
    for i in 0..n {
        for j in 0..n {
            if i != j && rng.chance(p) {
                m.set(i, j, rng.uniform(lo, hi));
            }
        }
    }
    m
}

/// 2-D grid (lattice) with 4-neighbourhood and unit-ish weights — the
/// classic "routing on a road network" shape.  `side × side` vertices,
/// bidirectional edges with independent weights per direction.
pub fn grid(side: usize, seed: u64) -> DistMatrix {
    let n = side * side;
    let mut rng = Rng::new(seed);
    let mut m = DistMatrix::unconnected(n);
    let idx = |r: usize, c: usize| r * side + c;
    for r in 0..side {
        for c in 0..side {
            if c + 1 < side {
                m.set(idx(r, c), idx(r, c + 1), rng.uniform(0.5, 1.5));
                m.set(idx(r, c + 1), idx(r, c), rng.uniform(0.5, 1.5));
            }
            if r + 1 < side {
                m.set(idx(r, c), idx(r + 1, c), rng.uniform(0.5, 1.5));
                m.set(idx(r + 1, c), idx(r, c), rng.uniform(0.5, 1.5));
            }
        }
    }
    m
}

/// Barabási–Albert-style preferential attachment (scale-free), symmetric
/// weights — the "network analysis" shape (hubs + long tails).  Each new
/// vertex attaches to `m_edges` existing vertices with probability
/// proportional to current degree.
pub fn scale_free(n: usize, m_edges: usize, seed: u64) -> DistMatrix {
    assert!(m_edges >= 1 && n > m_edges, "need n > m_edges >= 1");
    let mut rng = Rng::new(seed);
    let mut m = DistMatrix::unconnected(n);
    // repeated-endpoint list: attachment ∝ degree
    let mut endpoints: Vec<usize> = Vec::with_capacity(2 * n * m_edges);
    // seed clique over the first m_edges+1 vertices
    for i in 0..=m_edges {
        for j in 0..i {
            let w = rng.uniform(0.5, 5.0);
            m.set(i, j, w);
            m.set(j, i, w);
            endpoints.push(i);
            endpoints.push(j);
        }
    }
    for v in (m_edges + 1)..n {
        let mut chosen = Vec::with_capacity(m_edges);
        let mut guard = 0;
        while chosen.len() < m_edges {
            let t = endpoints[rng.range(0, endpoints.len())];
            if t != v && !chosen.contains(&t) {
                chosen.push(t);
            }
            guard += 1;
            if guard > 10_000 {
                // pathological only for tiny graphs; fall back to any vertex
                let t = rng.range(0, v);
                if !chosen.contains(&t) {
                    chosen.push(t);
                }
            }
        }
        for &t in &chosen {
            let w = rng.uniform(0.5, 5.0);
            m.set(v, t, w);
            m.set(t, v, w);
            endpoints.push(v);
            endpoints.push(t);
        }
    }
    m
}

/// Random geometric graph on the unit square: vertices connect when within
/// `radius`, weight = Euclidean distance (bioinformatics / sensor-net shape;
/// also gives metrically-consistent instances useful for sanity checks).
pub fn geometric(n: usize, radius: f64, seed: u64) -> DistMatrix {
    let mut rng = Rng::new(seed);
    let pts: Vec<(f64, f64)> = (0..n)
        .map(|_| (rng.next_f64(), rng.next_f64()))
        .collect();
    let mut m = DistMatrix::unconnected(n);
    for i in 0..n {
        for j in (i + 1)..n {
            let dx = pts[i].0 - pts[j].0;
            let dy = pts[i].1 - pts[j].1;
            let d = (dx * dx + dy * dy).sqrt();
            if d <= radius {
                m.set(i, j, d as f32);
                m.set(j, i, d as f32);
            }
        }
    }
    m
}

/// Directed ring with unit weights — worst-case diameter, used by tests
/// (every shortest path is forced through n-1 relaxation levels).
pub fn ring(n: usize) -> DistMatrix {
    let mut m = DistMatrix::unconnected(n);
    for i in 0..n {
        m.set(i, (i + 1) % n, 1.0);
    }
    m
}

/// Layered DAG with negative weights allowed on forward edges (no cycles ⇒
/// no negative cycles) — exercises FW's negative-edge support (paper §1).
pub fn layered_dag(layers: usize, width: usize, seed: u64) -> DistMatrix {
    let n = layers * width;
    let mut rng = Rng::new(seed);
    let mut m = DistMatrix::unconnected(n);
    for l in 0..layers.saturating_sub(1) {
        for a in 0..width {
            for b in 0..width {
                if rng.chance(0.5) {
                    let u = l * width + a;
                    let v = (l + 1) * width + b;
                    m.set(u, v, rng.uniform(-2.0, 8.0));
                }
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_density_scales_with_p() {
        let dense = erdos_renyi(64, 0.8, 1);
        let sparse = erdos_renyi(64, 0.1, 1);
        assert!(dense.edge_count() > sparse.edge_count() * 3);
    }

    #[test]
    fn er_deterministic() {
        assert_eq!(erdos_renyi(32, 0.3, 7), erdos_renyi(32, 0.3, 7));
        assert_ne!(erdos_renyi(32, 0.3, 7), erdos_renyi(32, 0.3, 8));
    }

    #[test]
    fn er_extremes() {
        assert_eq!(erdos_renyi(16, 0.0, 1).edge_count(), 0);
        assert_eq!(erdos_renyi(16, 1.0, 1).edge_count(), 16 * 15);
    }

    #[test]
    fn grid_edge_count() {
        // side*side grid: 2*side*(side-1) undirected edges, ×2 directions
        let side = 5;
        let g = grid(side, 3);
        assert_eq!(g.n(), side * side);
        assert_eq!(g.edge_count(), 2 * 2 * side * (side - 1));
    }

    #[test]
    fn scale_free_has_hubs() {
        let g = scale_free(128, 2, 9);
        let mut degrees: Vec<usize> = (0..g.n())
            .map(|i| (0..g.n()).filter(|&j| g.get(i, j).is_finite() && i != j).count())
            .collect();
        degrees.sort_unstable_by(|a, b| b.cmp(a));
        // preferential attachment: the top hub should far exceed the median
        assert!(degrees[0] >= 3 * degrees[g.n() / 2].max(1));
    }

    #[test]
    fn scale_free_symmetric() {
        let g = scale_free(48, 2, 4);
        for i in 0..g.n() {
            for j in 0..g.n() {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
        }
    }

    #[test]
    fn geometric_weights_are_distances() {
        let g = geometric(64, 0.4, 5);
        for i in 0..g.n() {
            for j in 0..g.n() {
                let w = g.get(i, j);
                if i != j && w.is_finite() {
                    assert!(w <= 0.4 + 1e-6, "edge weight {w} exceeds radius");
                }
            }
        }
    }

    #[test]
    fn ring_structure() {
        let g = ring(8);
        assert_eq!(g.edge_count(), 8);
        for i in 0..8 {
            assert_eq!(g.get(i, (i + 1) % 8), 1.0);
        }
    }

    #[test]
    fn layered_dag_no_backward_edges() {
        let g = layered_dag(4, 8, 2);
        let width = 8;
        for u in 0..g.n() {
            for v in 0..g.n() {
                if u != v && g.get(u, v).is_finite() {
                    assert_eq!(v / width, u / width + 1, "edge {u}->{v} not forward");
                }
            }
        }
    }

    #[test]
    fn all_generators_validate() {
        for g in [
            erdos_renyi(32, 0.4, 1),
            grid(6, 1),
            scale_free(32, 2, 1),
            geometric(32, 0.3, 1),
            ring(32),
            layered_dag(4, 8, 1),
        ] {
            g.validate().unwrap();
            for i in 0..g.n() {
                assert_eq!(g.get(i, i), 0.0);
            }
        }
    }
}

//! Miniature property-testing driver (the vendored crate set has no
//! `proptest`/`quickcheck`).
//!
//! A property is a closure from a seeded [`super::prng::Rng`] to
//! `Result<(), String>`.  The driver runs `cases` seeds; on failure it
//! *shrinks over the seed's complexity knob* — properties receive a `size`
//! hint that failing runs retry with smaller values, so counterexamples are
//! reported at the smallest size that still fails.  This is deliberately
//! simpler than structural shrinking but covers what the invariant tests
//! here need (sizes, densities, seeds).

use super::prng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u32,
    /// Base seed; each case derives its own stream from it.
    pub seed: u64,
    /// Maximum `size` hint passed to the property.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: DEFAULT_SEED,
            max_size: 128,
        }
    }
}

/// Outcome of a full property run.
#[derive(Debug)]
pub struct Failure {
    pub case: u32,
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop(rng, size)` for `cfg.cases` cases. On failure, retry the same
/// case seed with bisected sizes and report the smallest failing size.
/// Panics with a reproducible report (for use inside `#[test]` functions).
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    if let Some(f) = check_quiet(cfg, &mut prop) {
        panic!(
            "property '{name}' failed: case {case} (seed {seed:#x}, size {size}): {msg}\n\
             reproduce with Config {{ seed: {seed:#x}, .. }}",
            case = f.case,
            seed = f.seed,
            size = f.size,
            msg = f.message,
        );
    }
}

/// Like [`check`] but returns the failure instead of panicking (testable).
pub fn check_quiet<F>(cfg: Config, prop: &mut F) -> Option<Failure>
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        // ramp size up with the case index so early cases are tiny
        let size = 1 + (cfg.max_size.saturating_sub(1)) * case as usize
            / cfg.cases.max(1) as usize;
        let mut rng = Rng::new(case_seed);
        if let Err(message) = prop(&mut rng, size) {
            // shrink: bisect for the smallest failing size.  `lo` is the
            // largest size known to pass (0 passes vacuously — sizes start
            // at 1), `hi` the smallest known to fail; for the monotone
            // properties this driver targets, the reported size is exactly
            // the smallest that fails.
            let mut best = Failure {
                case,
                seed: case_seed,
                size,
                message,
            };
            let mut lo = 0usize;
            let mut hi = size;
            while hi - lo > 1 {
                let mid = lo + (hi - lo) / 2;
                let mut rng = Rng::new(case_seed);
                match prop(&mut rng, mid) {
                    Err(message) => {
                        hi = mid;
                        best = Failure {
                            case,
                            seed: case_seed,
                            size: mid,
                            message,
                        };
                    }
                    Ok(()) => lo = mid,
                }
            }
            return Some(best);
        }
    }
    None
}

/// Default seed (spells approximately "FW STAGE").
pub const DEFAULT_SEED: u64 = 0xF37_57A6E;

impl Config {
    pub fn with_cases(cases: u32) -> Self {
        Self {
            cases,
            ..Self::default()
        }
    }
}

/// `base` scaled by the `FW_PROPTEST_CASES` environment factor.
///
/// The dedicated CI conformance job runs the same property suites with an
/// elevated case count (`FW_PROPTEST_CASES=8` → 8× the in-test default)
/// without forking the test code; unset or unparsable values leave the
/// default untouched, so the fast suite stays fast.
pub fn env_cases(base: u32) -> u32 {
    scale_cases(base, std::env::var("FW_PROPTEST_CASES").ok().as_deref())
}

fn scale_cases(base: u32, factor: Option<&str>) -> u32 {
    match factor.and_then(|f| f.trim().parse::<u32>().ok()) {
        Some(f) if f >= 1 => base.saturating_mul(f),
        _ => base,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_returns_none() {
        let mut prop = |_: &mut Rng, _: usize| Ok(());
        assert!(check_quiet(Config::default(), &mut prop).is_none());
    }

    #[test]
    fn failing_property_shrinks_size() {
        // fails for any size >= 4; the bisecting shrinker lands exactly on 4
        let mut prop = |_: &mut Rng, size: usize| {
            if size >= 4 {
                Err(format!("size {size} too big"))
            } else {
                Ok(())
            }
        };
        let f = check_quiet(Config::default(), &mut prop).expect("must fail");
        assert_eq!(f.size, 4, "shrunk to {}", f.size);
    }

    #[test]
    fn shrinks_to_exact_smallest_failing_size() {
        // for a monotone property failing iff size >= threshold, the driver
        // must report precisely the threshold, whatever size first failed
        for threshold in [1usize, 2, 5, 9, 50] {
            let mut prop = |_: &mut Rng, size: usize| {
                if size >= threshold {
                    Err(format!("size {size} >= {threshold}"))
                } else {
                    Ok(())
                }
            };
            let f = check_quiet(Config::default(), &mut prop).expect("must fail");
            assert_eq!(f.size, threshold, "threshold {threshold}");
            assert!(f.message.contains(&format!("size {threshold}")));
        }
    }

    #[test]
    fn panic_report_contains_reproducing_seed() {
        let result = std::panic::catch_unwind(|| {
            check("seeded", Config::default(), |_, _| Err("boom".into()));
        });
        let payload = result.expect_err("property must panic");
        let msg = payload
            .downcast_ref::<String>()
            .expect("panic payload is a formatted String")
            .clone();
        // case 0 fails first, so the reported seed is the first stream drawn
        // from the default config's meta generator, rendered in hex
        let expected_seed = format!("{:#x}", Rng::new(Config::default().seed).next_u64());
        assert!(
            msg.contains(&expected_seed),
            "report {msg:?} missing seed {expected_seed}"
        );
        assert!(msg.contains("reproduce with Config"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn failure_is_reproducible() {
        let cfg = Config::default();
        let mut prop = |rng: &mut Rng, _: usize| {
            if rng.next_u64() % 7 == 0 {
                Err("hit".into())
            } else {
                Ok(())
            }
        };
        let a = check_quiet(cfg, &mut prop).map(|f| (f.case, f.seed));
        let b = check_quiet(cfg, &mut prop).map(|f| (f.case, f.seed));
        assert_eq!(a, b);
    }

    #[test]
    fn case_scaling_shape() {
        // the env wrapper is a thin shim over this (env vars are global
        // state; the logic is what needs pinning)
        assert_eq!(scale_cases(24, None), 24);
        assert_eq!(scale_cases(24, Some("8")), 192);
        assert_eq!(scale_cases(24, Some(" 2 ")), 48);
        assert_eq!(scale_cases(24, Some("0")), 24);
        assert_eq!(scale_cases(24, Some("lots")), 24);
        assert_eq!(scale_cases(u32::MAX, Some("8")), u32::MAX, "saturates");
    }

    #[test]
    #[should_panic(expected = "property")]
    fn check_panics_with_report() {
        check("always-fails", Config::with_cases(2), |_, _| {
            Err("nope".into())
        });
    }
}

//! Long-lived fixed-width job pool with a bounded submit queue.
//!
//! Generalizes the queue discipline of the superblock dependency pool
//! (`superblock/pool.rs`: a `Mutex<VecDeque>` + `Condvar` hand-off) into a
//! reusable building block for serving.  The superblock pool is scoped to
//! one solve and streams dependency-ready tiles; this pool is
//! process-long and bounds its *queue*, so callers can shed load instead
//! of buffering it unboundedly — the serving front end's admission
//! control.
//!
//! * [`JobPool::try_submit`] never blocks: a full queue is an immediate
//!   [`QueueFull`], the caller's signal to reject with a typed wire error.
//! * A panicking job never shrinks the pool: workers run every job under
//!   `catch_unwind`, so width is a static property of the config.  Nor
//!   does a poisoned queue lock stop admission — every lock site recovers
//!   ([`crate::util::sync`]); jobs run outside the lock, so the queue is
//!   never left half-mutated by a panic.
//! * Drop drains: jobs already admitted still run before the workers
//!   exit.  Graceful shutdown finishes accepted work; shedding happens at
//!   admission time, never at teardown.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Pool shape: how many workers, how deep a queue, and a thread-name
/// prefix for debuggability.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Worker thread count; `0` means one per available core.
    pub workers: usize,
    /// Maximum number of jobs waiting (not yet picked up by a worker);
    /// clamped to at least 1.
    pub queue_depth: usize,
    /// Thread-name prefix; workers are named `{name}-{index}`.
    pub name: String,
}

/// Typed rejection from [`JobPool::try_submit`]: the queue already holds
/// `depth` jobs, so this one was not admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueFull {
    /// The configured queue depth that was hit.
    pub depth: usize,
}

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job queue full (depth {})", self.depth)
    }
}

impl std::error::Error for QueueFull {}

struct State {
    queue: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    ready: Condvar,
}

/// A fixed set of worker threads draining a bounded FIFO of jobs.
pub struct JobPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    queue_depth: usize,
}

impl JobPool {
    /// Spawn the pool.  Worker count 0 resolves to the host's available
    /// parallelism (at least 1); queue depth is clamped to at least 1.
    pub fn new(config: PoolConfig) -> JobPool {
        let workers = if config.workers == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            config.workers
        };
        let queue_depth = config.queue_depth.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { queue: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("{}-{i}", config.name))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        JobPool { shared, workers: handles, queue_depth }
    }

    /// Worker thread count (after the `0 = auto` resolution).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Configured queue depth (after clamping).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Admit a job if the queue has room; never blocks.  `Err(QueueFull)`
    /// means the job was dropped without running — the caller sheds.
    /// A poisoned queue lock is recovered, not propagated: jobs run
    /// *outside* the lock (under `catch_unwind`), so a poisoned mutex
    /// only ever means some thread panicked between push and pop — the
    /// `VecDeque` itself is never left mid-mutation.
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, job: F) -> Result<(), QueueFull> {
        let mut state = crate::recover_lock!(&self.shared.state, "pool.state");
        if state.queue.len() >= self.queue_depth {
            return Err(QueueFull { depth: self.queue_depth });
        }
        state.queue.push_back(Box::new(job));
        drop(state);
        self.shared.ready.notify_one();
        Ok(())
    }
}

impl Drop for JobPool {
    fn drop(&mut self) {
        {
            let mut state = crate::recover_lock!(&self.shared.state, "pool.state");
            state.shutdown = true;
        }
        self.shared.ready.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    // one warn flag for the wait site (recover_lock! declares its own)
    static WAIT_LOGGED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);
    loop {
        let job = {
            let mut state = crate::recover_lock!(&shared.state, "pool.state");
            loop {
                // drain before honoring shutdown: admitted jobs always run
                if let Some(job) = state.queue.pop_front() {
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = crate::util::sync::wait_recover(
                    &shared.ready,
                    state,
                    "pool.state",
                    &WAIT_LOGGED,
                );
            }
        };
        // a panicking job unwinds here, not through the worker: the pool's
        // width stays what the config said it is
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;
    use std::time::Duration;

    /// Reusable open/closed gate so tests can park jobs inside workers.
    #[derive(Default)]
    struct Gate {
        open: Mutex<bool>,
        cv: Condvar,
    }

    impl Gate {
        fn wait(&self) {
            let mut open = self.open.lock().unwrap();
            while !*open {
                open = self.cv.wait(open).unwrap();
            }
        }

        fn open(&self) {
            *self.open.lock().unwrap() = true;
            self.cv.notify_all();
        }
    }

    #[test]
    fn admission_is_exactly_workers_plus_queue_depth() {
        let pool = JobPool::new(PoolConfig {
            workers: 2,
            queue_depth: 3,
            name: "test-admit".into(),
        });
        let gate = Arc::new(Gate::default());
        let started = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        // park a job inside each worker
        for _ in 0..2 {
            let (g, s, d) = (gate.clone(), started.clone(), done.clone());
            pool.try_submit(move || {
                s.fetch_add(1, Ordering::SeqCst);
                g.wait();
                d.fetch_add(1, Ordering::SeqCst);
            })
            .expect("worker-occupying job admitted");
        }
        while started.load(Ordering::SeqCst) < 2 {
            std::thread::yield_now();
        }
        // with both workers parked, exactly queue_depth more jobs fit
        for _ in 0..3 {
            let (g, d) = (gate.clone(), done.clone());
            pool.try_submit(move || {
                g.wait();
                d.fetch_add(1, Ordering::SeqCst);
            })
            .expect("queued job admitted");
        }
        let err = pool.try_submit(|| {}).expect_err("queue full must shed");
        assert_eq!(err, QueueFull { depth: 3 });
        // release and drop: Drop drains the queue, so all 5 admitted jobs ran
        gate.open();
        drop(pool);
        assert_eq!(done.load(Ordering::SeqCst), 5, "admitted jobs all ran by shutdown");
    }

    #[test]
    fn a_panicking_job_does_not_shrink_the_pool() {
        let pool = JobPool::new(PoolConfig {
            workers: 1,
            queue_depth: 4,
            name: "test-panic".into(),
        });
        pool.try_submit(|| panic!("job panic (expected by the pool test)"))
            .expect("panicking job admitted");
        let (tx, rx) = mpsc::channel();
        pool.try_submit(move || {
            tx.send(()).unwrap();
        })
        .expect("follow-up job admitted");
        rx.recv_timeout(Duration::from_secs(30))
            .expect("the single worker survived the panicking job");
    }

    #[test]
    fn a_poisoned_queue_lock_still_admits_and_runs_jobs() {
        // poison the queue mutex directly (white box), then prove the
        // pool keeps admitting, running, and draining — one panic must
        // not turn the persistence/serving lane into a brick
        let pool = JobPool::new(PoolConfig {
            workers: 1,
            queue_depth: 4,
            name: "test-poison".into(),
        });
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = pool.shared.state.lock().unwrap();
            panic!("poisoning the pool lock (expected by this test)");
        }));
        assert!(caught.is_err());
        assert!(pool.shared.state.is_poisoned());
        let (tx, rx) = mpsc::channel();
        pool.try_submit(move || {
            tx.send(()).unwrap();
        })
        .expect("admission survives the poisoned lock");
        rx.recv_timeout(Duration::from_secs(30))
            .expect("worker loop recovered the lock and ran the job");
        drop(pool); // Drop's shutdown path recovers too
    }

    #[test]
    fn zero_workers_resolves_to_available_parallelism() {
        let pool = JobPool::new(PoolConfig {
            workers: 0,
            queue_depth: 0,
            name: "test-auto".into(),
        });
        assert!(pool.workers() >= 1);
        assert_eq!(pool.queue_depth(), 1, "queue depth clamps to at least 1");
    }
}

//! Summary statistics for the first-party benchmark harness (`perf` module
//! and `rust/benches/*`) and the serving metrics: online summaries plus
//! exact percentiles over retained samples.
//!
//! Retention is **bounded**: past [`DEFAULT_CAP`] (or an explicit
//! [`Samples::with_capacity`] cap) the newest sample overwrites the oldest
//! — a sliding window — so a long-running coordinator's latency tracking
//! is O(cap), not O(requests).  Summaries then describe the window;
//! [`Samples::seen`] still counts everything ever pushed.  Benchmarks
//! record a few hundred samples and never hit the cap.

/// Default retention cap: far above any bench run, small enough that a
/// pathological serving workload stays at ~128 KiB per sample set.
pub const DEFAULT_CAP: usize = 16384;

/// A batch of duration/throughput samples with summary accessors.
#[derive(Clone, Debug)]
pub struct Samples {
    /// Retained window (ring order once the cap is reached).
    xs: Vec<f64>,
    /// Sorted copy of `xs`, rebuilt lazily for percentile calls.
    scratch: Vec<f64>,
    sorted: bool,
    cap: usize,
    /// Ring cursor: index of the oldest retained sample once full.
    next: usize,
    seen: u64,
}

impl Default for Samples {
    fn default() -> Self {
        Samples::with_capacity(DEFAULT_CAP)
    }
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sample set retaining at most `cap` most-recent values.
    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "sample capacity must be positive");
        Samples {
            xs: Vec::new(),
            scratch: Vec::new(),
            sorted: false,
            cap,
            next: 0,
            seen: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if self.xs.len() < self.cap {
            self.xs.push(x);
        } else {
            // full: overwrite the oldest (sliding window)
            self.xs[self.next] = x;
            self.next = (self.next + 1) % self.cap;
        }
        self.seen += 1;
        self.sorted = false;
    }

    /// Number of retained samples (≤ the cap).
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Total samples ever pushed, including any that slid out of the
    /// window.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Retention cap.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Retained samples (ring order once the window is full — treat as an
    /// unordered window).
    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    /// Merge another sample set's retained window into this one (subject
    /// to this set's cap).
    pub fn merge(&mut self, other: &Samples) {
        for &x in &other.xs {
            self.push(x);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Sample standard deviation (n-1 denominator; 0 for n<2).
    pub fn stddev(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let ss: f64 = self.xs.iter().map(|x| (x - mean) * (x - mean)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile by nearest-rank on the sorted retained samples;
    /// `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile {p}");
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.scratch.clear();
            self.scratch.extend_from_slice(&self.xs);
            self.scratch
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.scratch.len() - 1) as f64).round() as usize;
        self.scratch[rank]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Several percentiles at once (one sort, amortized over the batch —
    /// the metrics snapshot asks for p50/p95/p99 together).
    ///
    /// The empty sample set is part of the contract, not an accident: each
    /// requested percentile comes back as NaN — never a panic, never a
    /// silent 0 (a latency summary of 0s would read as "instant", which an
    /// idle server is not).  Out-of-range percentiles are still rejected
    /// even when empty.  Callers that show these values render the NaNs
    /// explicitly (the coordinator snapshot prints `-`).
    pub fn percentiles(&mut self, ps: &[f64]) -> Vec<f64> {
        if self.xs.is_empty() {
            return ps
                .iter()
                .map(|&p| {
                    assert!((0.0..=100.0).contains(&p), "percentile {p}");
                    f64::NAN
                })
                .collect();
        }
        ps.iter().map(|&p| self.percentile(p)).collect()
    }

    /// One-line human summary: `mean ± stddev [min … max] (n)`.
    pub fn summary(&mut self, unit: &str) -> String {
        format!(
            "{:.4}{u} ± {:.4}{u} [{:.4}{u} … {:.4}{u}] (n={})",
            self.mean(),
            self.stddev(),
            self.min(),
            self.max(),
            self.len(),
            u = unit,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn of(xs: &[f64]) -> Samples {
        let mut s = Samples::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    #[test]
    fn mean_and_stddev() {
        let s = of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let mut s = of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn batch_percentiles() {
        let mut s = of(&[5.0, 1.0, 4.0, 2.0, 3.0]);
        assert_eq!(s.percentiles(&[0.0, 50.0, 100.0]), vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn single_sample() {
        let mut s = of(&[42.0]);
        assert_eq!(s.mean(), 42.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.median(), 42.0);
        assert_eq!(s.min(), 42.0);
        assert_eq!(s.max(), 42.0);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
    }

    #[test]
    fn empty_percentiles_are_explicit_nans() {
        // pinned: one NaN per requested percentile — no panic, no silent 0
        let mut s = Samples::new();
        let ps = s.percentiles(&[0.0, 50.0, 95.0, 99.0, 100.0]);
        assert_eq!(ps.len(), 5);
        assert!(ps.iter().all(|p| p.is_nan()), "{ps:?}");
        assert!(s.percentiles(&[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "percentile")]
    fn empty_percentiles_still_reject_out_of_range() {
        Samples::new().percentiles(&[101.0]);
    }

    #[test]
    fn push_after_percentile_resorts() {
        let mut s = of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median(), 2.0);
        s.push(0.5);
        s.push(0.6);
        assert_eq!(s.percentile(0.0), 0.5);
    }

    #[test]
    fn capped_retention_is_a_sliding_window() {
        let mut s = Samples::with_capacity(4);
        for x in 0..10 {
            s.push(x as f64);
        }
        assert_eq!(s.len(), 4);
        assert_eq!(s.seen(), 10);
        // the window holds exactly the last four pushes
        let mut window: Vec<f64> = s.values().to_vec();
        window.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(window, vec![6.0, 7.0, 8.0, 9.0]);
        assert_eq!(s.percentile(0.0), 6.0);
        assert_eq!(s.percentile(100.0), 9.0);
        assert!((s.mean() - 7.5).abs() < 1e-12);
        // memory stays put: further pushes never grow the buffer
        for x in 10..1000 {
            s.push(x as f64);
        }
        assert_eq!(s.len(), 4);
        assert_eq!(s.capacity(), 4);
        assert_eq!(s.percentile(100.0), 999.0);
    }

    #[test]
    fn window_interleaves_with_percentile_sorting() {
        // sorting for percentiles must not corrupt eviction order: the
        // sorted copy lives in scratch, the window keeps insertion order
        let mut s = Samples::with_capacity(3);
        for x in [3.0, 1.0, 2.0] {
            s.push(x);
        }
        assert_eq!(s.median(), 2.0);
        s.push(10.0); // evicts 3.0 (the oldest), not a sorted-position victim
        let mut window: Vec<f64> = s.values().to_vec();
        window.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(window, vec![1.0, 2.0, 10.0]);
    }

    #[test]
    fn merge_respects_the_cap() {
        let mut a = Samples::with_capacity(2);
        let b = of(&[1.0, 2.0, 3.0]);
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.seen(), 3);
        let mut window: Vec<f64> = a.values().to_vec();
        window.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(window, vec![2.0, 3.0]);
    }

    #[test]
    fn default_cap_is_generous() {
        let s = Samples::new();
        assert_eq!(s.capacity(), DEFAULT_CAP);
        assert!(DEFAULT_CAP >= 1000, "bench workloads must fit untruncated");
    }
}

//! First-party utility substrate.
//!
//! This build runs fully offline against a vendored crate set that has no
//! `serde`, `rand`, `clap`, or `criterion`, so the pieces a framework would
//! normally pull from crates.io are implemented here:
//!
//! * [`json`] — a small, strict JSON parser/serializer (manifest + wire protocol)
//! * [`pool`] — fixed-width job pool with a bounded, sheddable queue (serving)
//! * [`prng`] — SplitMix64 / Xoshiro256++ deterministic PRNG (generators, tests)
//! * [`stats`] — streaming summary statistics used by the bench harness
//! * [`proptest`] — a miniature property-testing driver with shrinking
//! * [`checksum`] — streaming FNV-1a 64 (the closure store's integrity seal)
//! * [`sync`] — poison-recovering mutex helpers (one panic must not poison serving)

pub mod checksum;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod prng;
pub mod stats;
pub mod sync;

//! Deterministic PRNG: SplitMix64 (seeding) + Xoshiro256++ (stream).
//!
//! The vendored crate set has `rand_core` but no generator implementation,
//! so the graph generators, workload traces, and property tests use this
//! first-party implementation.  Both algorithms are the public-domain
//! reference constructions (Blackman & Vigna), chosen for reproducibility:
//! a given seed yields the same graphs on every platform, which the test
//! suite and EXPERIMENTS.md rely on.

/// SplitMix64: used to expand a 64-bit seed into Xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++: the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // all-zero state is a fixed point; SplitMix64 cannot produce four
        // zeros from any seed, but guard anyway
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [lo, hi) — panics if the range is empty.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-thread / per-request use).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // reference values for seed 1234567 from the public-domain C code
        let mut sm = SplitMix64::new(1234567);
        let got: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(got[0], 6457827717110365317);
        assert_eq!(got[1], 3203168211198807973);
        assert_eq!(got[2], 9817491932198370423);
    }

    #[test]
    fn deterministic_by_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn next_below_unbiased_smoke() {
        let mut r = Rng::new(99);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.next_below(10) as usize] += 1;
        }
        for &c in &counts {
            // each bucket should get ~10k; allow generous slack
            assert!((8_500..11_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(11);
        let mut a = r.fork();
        let mut b = r.fork();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(1);
        assert!((0..100).all(|_| !r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}

//! Incremental FNV-1a 64 checksum for on-disk integrity.
//!
//! The closure store (`coordinator/store.rs`) seals every entry with a
//! trailing checksum so a torn write, a bad sector, or a truncated file is
//! *detected* at load time instead of served as a valid closure.  This is
//! the textbook byte-at-a-time FNV-1a — deliberately distinct from the
//! cache's chunked [`crate::coordinator::cache::graph_fingerprint`] fold:
//! the fingerprint is a content-addressing key optimized for the request
//! hot path, while this is a whole-file integrity seal computed once per
//! disk write/read, where the standard construction (with its published
//! test vectors, pinned below) is worth the extra multiplies.
//!
//! FNV-1a is not cryptographic and is not meant to be: the store defends
//! against *corruption* (bit rot, truncation, crashes mid-write), not
//! adversaries with filesystem access — an attacker who can write the
//! store file can write a matching checksum too.

const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const PRIME: u64 = 0x0000_0100_0000_01B3;

/// Streaming FNV-1a 64 state: feed bytes with [`Fnv64::update`], seal with
/// [`Fnv64::finish`].  Byte-at-a-time, so the digest is independent of how
/// the input was chunked across `update` calls.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64 { state: OFFSET }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot digest of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_vectors_pinned() {
        // the standard FNV-1a 64 test vectors: this function is part of
        // the store's on-disk format contract — changing it invalidates
        // every persisted entry, so the exact values are frozen here
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn digest_is_chunking_independent() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let whole = fnv64(data);
        let mut h = Fnv64::new();
        for chunk in data.chunks(7) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), whole);
        let mut h = Fnv64::new();
        for &b in data.iter() {
            h.update(&[b]);
        }
        assert_eq!(h.finish(), whole);
    }

    #[test]
    fn single_bit_flip_changes_digest() {
        let mut data = vec![0u8; 4096];
        data.iter_mut().enumerate().for_each(|(i, b)| *b = (i % 251) as u8);
        let clean = fnv64(&data);
        for pos in [0, 1, 2047, 4095] {
            let mut bad = data.clone();
            bad[pos] ^= 0x10;
            assert_ne!(fnv64(&bad), clean, "flip at byte {pos} went undetected");
        }
        // truncation changes it too (the store also checks lengths, but
        // the seal alone must catch a shorter body)
        assert_ne!(fnv64(&data[..data.len() - 1]), clean);
    }
}

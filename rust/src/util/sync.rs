//! Poison-recovering mutex helpers for the serving stack.
//!
//! `Mutex::lock().unwrap()` turns one panic while holding the lock into a
//! *permanent* denial of service: every later `lock()` sees the poison
//! flag and the `unwrap` panics too, so a single buggy request kills all
//! subsequent requests.  That trade is wrong for every lock in this crate
//! — the guarded state is a plain map, ring, or queue whose invariants
//! hold after any prefix of operations (no multi-step critical sections
//! that a mid-flight panic could leave half-applied), so the data behind a
//! poisoned lock is still valid.  [`lock_recover`] takes the guard out of
//! the poison wrapper, emits one `warn` log event per call site (not per
//! call — a poisoned hot-path lock must not turn the log into a firehose),
//! and serving continues.
//!
//! The panic that poisoned the lock is still loud: it unwound its own
//! thread (or was caught by the pool's `catch_unwind`, which reports it);
//! recovery here only stops it from cascading.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

use crate::obs::log::{log, Level};
use crate::util::json::Json;

/// One warn per call site: each site passes its own flag (a `static`), so
/// the first recovery logs and the rest are silent.
fn warn_once(site: &'static str, logged: &AtomicBool) {
    if !logged.swap(true, Ordering::Relaxed) {
        log(
            Level::Warn,
            "lock_poisoned",
            vec![
                ("site", Json::str(site)),
                ("action", Json::str("recovered; state is panic-safe by construction")),
            ],
        );
    }
}

/// Lock `mutex`, recovering from poisoning instead of propagating it.
/// `site` names the lock in the one-time warn event; `logged` is the call
/// site's own once-flag (a `static AtomicBool`).
pub fn lock_recover<'a, T>(
    mutex: &'a Mutex<T>,
    site: &'static str,
    logged: &AtomicBool,
) -> MutexGuard<'a, T> {
    match mutex.lock() {
        Ok(guard) => guard,
        Err(poisoned) => {
            warn_once(site, logged);
            poisoned.into_inner()
        }
    }
}

/// [`Condvar::wait`] with the same recovery policy as [`lock_recover`]:
/// a wait that returns a poisoned guard hands back the inner guard.
pub fn wait_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    site: &'static str,
    logged: &AtomicBool,
) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(guard) => guard,
        Err(poisoned) => {
            warn_once(site, logged);
            poisoned.into_inner()
        }
    }
}

/// Declares the per-site once-flag and locks in one expression:
/// `recover_lock!(&self.inner, "cache.inner")`.
#[macro_export]
macro_rules! recover_lock {
    ($mutex:expr, $site:expr) => {{
        static LOGGED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);
        $crate::util::sync::lock_recover($mutex, $site, &LOGGED)
    }};
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;

    fn poison<T: Send + 'static>(mutex: &Mutex<T>) {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let _guard = mutex.lock().unwrap();
            panic!("poisoning the lock (expected by the sync test)");
        }));
        assert!(caught.is_err());
        assert!(mutex.is_poisoned(), "the panic above must have poisoned the lock");
    }

    #[test]
    fn recovers_a_poisoned_lock_and_state_survives() {
        let mutex = Mutex::new(vec![1, 2, 3]);
        poison(&mutex);
        let mut guard = recover_lock!(&mutex, "test.vec");
        assert_eq!(*guard, vec![1, 2, 3], "state behind the poison is intact");
        guard.push(4);
        drop(guard);
        // a second recovery sees the post-recovery mutation
        assert_eq!(recover_lock!(&mutex, "test.vec").len(), 4);
    }

    #[test]
    fn wait_recovers_when_a_peer_poisons_mid_wait() {
        static LOGGED: AtomicBool = AtomicBool::new(false);
        let shared = Arc::new((Mutex::new(0u32), Condvar::new()));
        let waiter = {
            let shared = shared.clone();
            std::thread::spawn(move || {
                let (m, cv) = &*shared;
                let mut guard = recover_lock!(m, "test.wait");
                while *guard == 0 {
                    guard = wait_recover(cv, guard, "test.wait", &LOGGED);
                }
                *guard
            })
        };
        // poison the lock out from under the waiter, then complete the
        // hand-off anyway: set the condition during recovery's lock
        let (m, cv) = &*shared;
        poison(m);
        *recover_lock!(m, "test.wait") = 7;
        cv.notify_all();
        assert_eq!(waiter.join().expect("waiter survived the poison"), 7);
    }
}

//! Minimal, strict JSON parser and serializer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and the coordinator's line-delimited wire
//! protocol.  Implements all of RFC 8259 except `\u` surrogate pairs are
//! passed through unvalidated; numbers are f64 (adequate: every number we
//! exchange is a shape, count, or f32 weight).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — useful for golden tests and cache keys.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, PartialEq)]
pub enum JsonError {
    Eof(usize),
    Unexpected(usize, char),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
    Expected(usize, &'static str),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof(at) => write!(f, "unexpected end of input at byte {at}"),
            JsonError::Unexpected(at, c) => {
                write!(f, "unexpected character {c:?} at byte {at}")
            }
            JsonError::BadNumber(at) => write!(f, "invalid number at byte {at}"),
            JsonError::BadEscape(at) => write!(f, "invalid escape at byte {at}"),
            JsonError::Trailing(at) => write!(f, "trailing garbage at byte {at}"),
            JsonError::Expected(at, what) => write!(f, "expected {what} at byte {at}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ----- accessors ------------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ----- parse ----------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(JsonError::Trailing(p.pos));
        }
        Ok(v)
    }

    // ----- serialize --------------------------------------------------------

    /// Compact serialization (no whitespace), deterministic key order.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Infinity; degrade to null (matches the wire
        // protocol's "unreachable" convention)
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8, what: &'static str) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(JsonError::Expected(self.pos, what))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(
                self.pos,
                self.bytes[self.pos] as char,
            ))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or(JsonError::Eof(self.pos))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(self.pos, c as char)),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[', "[")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                Some(c) => return Err(JsonError::Unexpected(self.pos, c as char)),
                None => return Err(JsonError::Eof(self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{', "{")?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':', ":")?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                Some(c) => return Err(JsonError::Unexpected(self.pos, c as char)),
                None => return Err(JsonError::Eof(self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"', "string")?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or(JsonError::Eof(self.pos))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().ok_or(JsonError::Eof(self.pos))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(JsonError::Eof(self.pos));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| JsonError::BadEscape(self.pos))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.pos))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(JsonError::BadEscape(self.pos - 1)),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    if start + len > self.bytes.len() {
                        return Err(JsonError::Eof(start));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| JsonError::Unexpected(start, '?'))?;
                    out.push_str(s);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        assert!(v.get("a").as_arr().unwrap()[2].get("b").is_null());
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape_and_raw() {
        let v = Json::parse(r#""é café ☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café ☃");
    }

    #[test]
    fn escapes_serialized() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(v.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::num(512.0).to_string(), "512");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
        assert_eq!(Json::num(-3.0).to_string(), "-3");
    }

    #[test]
    fn as_usize_strict() {
        assert_eq!(Json::num(5.0).as_usize(), Some(5));
        assert_eq!(Json::num(5.5).as_usize(), None);
        assert_eq!(Json::num(-1.0).as_usize(), None);
        assert_eq!(Json::Null.as_usize(), None);
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert!(Json::num(1.0).get("x").is_null());
        assert!(Json::parse("[1]").unwrap().get("x").is_null());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "version": 2, "tile": 32,
          "artifacts": [
            {"name": "apsp_staged_n64.hlo.txt", "variant": "staged", "n": 64,
             "kchunk": 8, "dtype": "f32", "input_shape": [64, 64]}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").as_usize(), Some(2));
        let arts = v.get("artifacts").as_arr().unwrap();
        assert_eq!(arts[0].get("variant").as_str(), Some("staged"));
        assert_eq!(arts[0].get("input_shape").as_arr().unwrap().len(), 2);
    }
}

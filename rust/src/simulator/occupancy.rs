//! CUDA compute-capability-1.3 occupancy calculator (paper §3.3, §4.1,
//! §4.2; CUDA Occupancy Calculator [15]).
//!
//! Given a kernel's per-block resources, compute how many blocks an SM can
//! host.  This is the quantity the paper's whole contribution turns on:
//! 12320 B of shared memory ⇒ 1 block/SM ⇒ 256 resident threads ⇒ exposed
//! latency; 1056 B ⇒ 8 blocks (thread/register-limited) ⇒ 512 resident
//! threads ⇒ latency hidden.

use super::device::DeviceSpec;

/// Per-block resource demands of a kernel.
#[derive(Clone, Copy, Debug)]
pub struct BlockResources {
    /// Threads per block.
    pub threads: usize,
    /// Registers per thread.
    pub regs_per_thread: usize,
    /// Shared memory per block, bytes (including parameter block).
    pub smem_bytes: usize,
}

/// Which resource capped the block count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Limit {
    SharedMemory,
    Registers,
    Threads,
    BlockSlots,
}

/// Occupancy result for one kernel on one device.
#[derive(Clone, Copy, Debug)]
pub struct Occupancy {
    pub blocks_per_sm: usize,
    pub resident_threads: usize,
    pub limited_by: Limit,
}

fn round_up(x: usize, granularity: usize) -> usize {
    x.div_ceil(granularity) * granularity
}

/// CC 1.3 occupancy: blocks/SM = min over the four hardware limits, with
/// register and shared-memory allocations rounded to device granularity.
pub fn occupancy(dev: &DeviceSpec, res: &BlockResources) -> Occupancy {
    assert!(res.threads > 0, "zero-thread block");
    let smem_alloc = round_up(res.smem_bytes.max(1), dev.smem_alloc_granularity);
    let regs_alloc = round_up(
        res.regs_per_thread * res.threads,
        dev.reg_alloc_granularity,
    );
    let by_smem = dev.smem_per_sm / smem_alloc;
    let by_regs = if regs_alloc == 0 {
        dev.max_blocks_per_sm
    } else {
        dev.regs_per_sm / regs_alloc
    };
    let by_threads = dev.max_threads_per_sm / res.threads;
    let by_slots = dev.max_blocks_per_sm;

    let (blocks, limited_by) = [
        (by_smem, Limit::SharedMemory),
        (by_regs, Limit::Registers),
        (by_threads, Limit::Threads),
        (by_slots, Limit::BlockSlots),
    ]
    .into_iter()
    .min_by_key(|&(b, _)| b)
    .unwrap();

    Occupancy {
        blocks_per_sm: blocks,
        resident_threads: blocks * res.threads,
        limited_by,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c1060() -> DeviceSpec {
        DeviceSpec::tesla_c1060()
    }

    // ---- E6: the paper's three occupancy cases, §3.3 / §4.1 / §4.2 ----

    #[test]
    fn katz_kider_one_block_per_sm() {
        // §3.3: 3 tiles × 32² × 4 B + 32 B params = 12320 B > half of 16 KB
        let occ = occupancy(
            &c1060(),
            &BlockResources {
                threads: 256,
                regs_per_thread: 16,
                smem_bytes: 12320,
            },
        );
        assert_eq!(occ.blocks_per_sm, 1);
        assert_eq!(occ.limited_by, Limit::SharedMemory);
        assert_eq!(occ.resident_threads, 256);
    }

    #[test]
    fn registers_only_still_one_block() {
        // §4.1: tile in registers ⇒ 2·32² + 32 = 8224 B — "still more than
        // half of the available 16384", so still one block per SM
        let occ = occupancy(
            &c1060(),
            &BlockResources {
                threads: 256,
                regs_per_thread: 24,
                smem_bytes: 8224,
            },
        );
        assert_eq!(occ.blocks_per_sm, 1);
        assert_eq!(occ.limited_by, Limit::SharedMemory);
    }

    #[test]
    fn staged_kernel_eight_blocks() {
        // §4.2: 2·32·4·4 + 32 = 1056 B ⇒ "as many as 15 blocks could be run
        // ... given the shared memory usage. The limiting factors are now
        // the total threads ... and the registers".
        // 64 threads × 32 regs = 2048 regs/block ⇒ 8 blocks; thread limit
        // 1024/64 = 16; block-slot limit 8.
        let occ = occupancy(
            &c1060(),
            &BlockResources {
                threads: 64,
                regs_per_thread: 32,
                smem_bytes: 1056,
            },
        );
        assert_eq!(occ.blocks_per_sm, 8);
        assert_eq!(occ.resident_threads, 512);
        assert_ne!(occ.limited_by, Limit::SharedMemory);
    }

    #[test]
    fn staged_smem_alone_allows_15_blocks() {
        // the paper's "as many as 15 blocks" figure: 16384 / ⌈1056⌉₅₁₂
        let dev = c1060();
        let smem_alloc = 1056usize.div_ceil(dev.smem_alloc_granularity)
            * dev.smem_alloc_granularity;
        assert_eq!(dev.smem_per_sm / smem_alloc, 10);
        // (with byte-granularity allocation the paper's exact 15:)
        assert_eq!(dev.smem_per_sm / 1056, 15);
    }

    #[test]
    fn thread_limited_case() {
        let occ = occupancy(
            &c1060(),
            &BlockResources {
                threads: 512,
                regs_per_thread: 8,
                smem_bytes: 512,
            },
        );
        assert_eq!(occ.blocks_per_sm, 2);
        assert_eq!(occ.limited_by, Limit::Threads);
    }

    #[test]
    fn block_slot_limited_case() {
        let occ = occupancy(
            &c1060(),
            &BlockResources {
                threads: 32,
                regs_per_thread: 4,
                smem_bytes: 16,
            },
        );
        assert_eq!(occ.blocks_per_sm, 8);
        assert_eq!(occ.limited_by, Limit::BlockSlots);
    }

    #[test]
    fn rounding_granularity_applies() {
        // 513 B of smem rounds to 1024 ⇒ 16 by smem, capped by slots at 8
        let dev = c1060();
        let occ = occupancy(
            &dev,
            &BlockResources {
                threads: 64,
                regs_per_thread: 4,
                smem_bytes: 513,
            },
        );
        assert_eq!(occ.blocks_per_sm, 8);
    }
}

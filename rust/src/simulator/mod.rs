//! Analytical Tesla C1060 performance model.
//!
//! The paper's testbed (NVIDIA Tesla C1060, CUDA 2.3) is unavailable; per
//! DESIGN.md §Substitutions this module regenerates every number in the
//! paper's evaluation analytically, from the same quantities the paper
//! itself argues with:
//!
//! * §3.1: bytes/task over the global bus vs the measured 77 GB/s,
//! * §3.3: shared-memory/register/thread occupancy limits per SM,
//! * §4:   instruction counts per task (div/mod vs shifts, unrolling),
//! * §4.3: shared-memory bank-conflict degree (from [`crate::layout`]),
//! * the scheduler's ability to hide latency as a function of resident
//!   threads (196 to hide register latency, 512 for global memory — §3.3).
//!
//! [`device`] holds the hardware constants, [`occupancy`] the CC 1.3
//! occupancy calculator, [`kernels`] the per-variant kernel resource/cost
//! models, [`model`] the per-phase execution-time composition, and
//! [`table`] the Table 1 / Figure 7 / §5 emitters.

pub mod device;
pub mod kernels;
pub mod model;
pub mod occupancy;
pub mod table;

pub use device::DeviceSpec;
pub use kernels::Variant;
pub use model::{simulate, SimResult};

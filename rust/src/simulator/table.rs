//! Emitters that regenerate the paper's evaluation artifacts from the
//! simulator: Table 1, Figure 7 (as CSV series), the §5 tasks/sec &
//! bandwidth analysis, and the §4 speedup-decomposition ablation (E5).

use super::kernels::Variant;
use super::model::simulate;

/// The problem sizes of Table 1 (vertices).
pub const TABLE1_SIZES: [usize; 17] = [
    1024, 2048, 3072, 4096, 5120, 6144, 7168, 8192, 9216, 10240, 11264, 12288,
    13312, 14336, 15360, 16384, 17408,
];

/// Paper Table 1 (seconds); `None` where the paper leaves a blank cell
/// (runs skipped or too slow).  Order: CPU, H&N, K&K, Opt, Staged.
pub const PAPER_TABLE1: [(usize, [Option<f64>; 5]); 17] = [
    (1024, [Some(2.405), Some(0.408), Some(0.108), Some(0.0428), Some(0.0274)]),
    (2048, [Some(18.38), Some(3.212), Some(0.65), Some(0.282), Some(0.14)]),
    (3072, [Some(62.04), Some(10.99), Some(2.01), Some(0.653), Some(0.401)]),
    (4096, [Some(145.2), Some(26.05), Some(4.62), Some(2.06), Some(0.934)]),
    (5120, [None, Some(50.87), Some(8.84), Some(4.02), Some(1.76)]),
    (6144, [None, Some(87.9), Some(15.09), Some(6.89), Some(2.98)]),
    (7168, [None, None, Some(23.82), Some(10.9), Some(4.65)]),
    (8192, [None, Some(208.6), Some(35.37), Some(16.39), Some(6.88)]),
    (9216, [None, None, Some(50.24), Some(23.05), Some(9.71)]),
    (10240, [None, None, Some(68.67), Some(31.52), Some(13.22)]),
    (11264, [None, None, Some(91.08), Some(41.82), Some(17.48)]),
    (12288, [None, None, None, Some(54.05), Some(22.67)]),
    (13312, [None, None, None, Some(68.56), Some(28.63)]),
    (14336, [None, None, None, Some(85.56), Some(36.7)]),
    (15360, [None, None, None, None, Some(43.74)]),
    (16384, [None, None, Some(277.8), Some(126.9), Some(53.02)]),
    (17408, [None, None, None, None, Some(63.4)]),
];

/// One row of the regenerated Table 1.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub n: usize,
    /// Simulated seconds in Table 1 column order.
    pub simulated: [f64; 5],
    /// The paper's reported seconds (None = blank cell).
    pub paper: [Option<f64>; 5],
}

/// Regenerate all of Table 1 (simulated next to paper numbers).
pub fn table1() -> Vec<Table1Row> {
    PAPER_TABLE1
        .iter()
        .map(|&(n, paper)| {
            let simulated = [
                simulate(Variant::Cpu, n).seconds,
                simulate(Variant::HarishNarayanan, n).seconds,
                simulate(Variant::KatzKider, n).seconds,
                simulate(Variant::OptimizedBlocked, n).seconds,
                simulate(Variant::StagedLoad, n).seconds,
            ];
            Table1Row { n, simulated, paper }
        })
        .collect()
}

/// Render Table 1 as aligned text, paper value in parentheses.
pub fn render_table1() -> String {
    let mut out = String::new();
    out.push_str(
        "Table 1 — Implementation comparison, seconds (simulated C1060; paper value in parens)\n",
    );
    out.push_str(&format!(
        "{:>8} {:>20} {:>20} {:>20} {:>20} {:>20}\n",
        "n", "CPU", "Harish&Narayanan", "Katz&Kider", "Optimized&Blocked", "Staged Load"
    ));
    for row in table1() {
        out.push_str(&format!("{:>8}", row.n));
        for (sim, paper) in row.simulated.iter().zip(row.paper.iter()) {
            let cell = match paper {
                Some(p) => format!("{:.4} ({:.4})", sim, p),
                None => format!("{:.4} (  —  )", sim),
            };
            out.push_str(&format!(" {cell:>20}"));
        }
        out.push('\n');
    }
    out
}

/// Figure 7 as CSV: one series per implementation, log-log friendly.
pub fn fig7_csv() -> String {
    let mut out = String::from("n,cpu,harish_narayanan,katz_kider,optimized_blocked,staged_load\n");
    for row in table1() {
        out.push_str(&format!(
            "{},{:.5},{:.5},{:.5},{:.5},{:.5}\n",
            row.n,
            row.simulated[0],
            row.simulated[1],
            row.simulated[2],
            row.simulated[3],
            row.simulated[4]
        ));
    }
    out
}

/// §5 analysis block: tasks/sec, effective bandwidth / FLOP-equivalents.
pub fn render_analysis() -> String {
    let mut out = String::from("Section 5 analysis (simulated, paper values in parens)\n");
    let hn = simulate(Variant::HarishNarayanan, 8192);
    out.push_str(&format!(
        "Harish & Narayanan: {:.2e} tasks/s (2.6e9), {:.1} GB/s effective (42), memory-bound: {}\n",
        hn.tasks_per_sec,
        hn.tasks_per_sec * 16.0 / 1e9,
        hn.memory_bound,
    ));
    let kk = simulate(Variant::KatzKider, 16384);
    out.push_str(&format!(
        "Katz & Kider:      {:.2e} tasks/s (14.9e9), {:.1} FLOP-equiv/task (62.7), memory-bound: {}\n",
        kk.tasks_per_sec,
        933e9 / kk.tasks_per_sec,
        kk.memory_bound,
    ));
    let staged = simulate(Variant::StagedLoad, 16384);
    out.push_str(&format!(
        "Staged Load:       {:.2e} tasks/s (73.6e9), {:.1} FLOP-equiv/task (12.7), memory-bound: {}\n",
        staged.tasks_per_sec,
        933e9 / staged.tasks_per_sec,
        staged.memory_bound,
    ));
    out.push_str(&format!(
        "Speedups at n=16384: K&K/Opt = {:.2}x (paper 2.1-2.3), Opt/Staged = {:.2}x (2.3-2.4), K&K/Staged = {:.2}x (~5.2), CPU/Staged = {:.0}x (>150)\n",
        kk.seconds / simulate(Variant::OptimizedBlocked, 16384).seconds,
        simulate(Variant::OptimizedBlocked, 16384).seconds / staged.seconds,
        kk.seconds / staged.seconds,
        simulate(Variant::Cpu, 16384).seconds / staged.seconds,
    ));
    out
}

/// E5 ablation: the two §4 optimization rounds toggled independently,
/// plus the §4.3 cyclic-k fix.
pub fn render_ablation(n: usize) -> String {
    let rows = [
        ("blocked baseline (Katz & Kider)", Variant::KatzKider),
        ("+ instruction optimization", Variant::OptimizedBlocked),
        ("+ staging + registers + cyclic k (paper)", Variant::StagedLoad),
        ("staging with simple k (bank conflicts)", Variant::StagedSimpleK),
    ];
    let base = simulate(Variant::KatzKider, n).seconds;
    let mut out = format!("Speedup decomposition at n={n} (E5)\n");
    for (label, v) in rows {
        let r = simulate(v, n);
        out.push_str(&format!(
            "{label:<42} {:>10.3}s  {:>6.2}x  occ {:>3} thr/SM\n",
            r.seconds,
            base / r.seconds,
            r.occupancy.map(|o| o.resident_threads).unwrap_or(0),
        ));
    }
    out
}

/// Accuracy report: relative error of every simulated cell vs the paper.
pub fn accuracy_report() -> Vec<(usize, &'static str, f64, f64, f64)> {
    let names = [
        "CPU",
        "Harish&Narayanan",
        "Katz&Kider",
        "Optimized&Blocked",
        "StagedLoad",
    ];
    let mut out = Vec::new();
    for row in table1() {
        for c in 0..5 {
            if let Some(p) = row.paper[c] {
                let err = (row.simulated[c] - p) / p;
                out.push((row.n, names[c], row.simulated[c], p, err));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_all_17_sizes() {
        let t = table1();
        assert_eq!(t.len(), 17);
        assert_eq!(t[0].n, 1024);
        assert_eq!(t[16].n, 17408);
    }

    #[test]
    fn shape_staged_always_wins() {
        for row in table1() {
            assert!(row.simulated[4] < row.simulated[3]);
            assert!(row.simulated[3] < row.simulated[2]);
            assert!(row.simulated[2] < row.simulated[1]);
            assert!(row.simulated[1] < row.simulated[0]);
        }
    }

    #[test]
    fn large_n_cells_within_15pct() {
        // where the paper's claims live: every populated cell n ≥ 8192
        for (n, name, sim, paper, err) in accuracy_report() {
            if n >= 8192 {
                assert!(
                    err.abs() < 0.15,
                    "{name} at n={n}: simulated {sim:.2} vs paper {paper:.2} ({:+.1}%)",
                    err * 100.0
                );
            }
        }
    }

    #[test]
    fn all_cells_within_2x() {
        // small-n cells are launch/fill dominated; require factor-2 shape
        for (n, name, sim, paper, _) in accuracy_report() {
            let ratio = sim / paper;
            assert!(
                (0.5..=2.0).contains(&ratio),
                "{name} at n={n}: {sim:.3} vs {paper:.3} (×{ratio:.2})"
            );
        }
    }

    #[test]
    fn csv_well_formed() {
        let csv = fig7_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 18); // header + 17 rows
        assert!(lines[0].starts_with("n,cpu"));
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), 6);
        }
    }

    #[test]
    fn renders_are_nonempty() {
        assert!(render_table1().contains("16384"));
        assert!(render_analysis().contains("tasks/s"));
        assert!(render_ablation(16384).contains("cyclic"));
    }
}

//! Hardware constants for the simulated testbed.
//!
//! All values are from the paper (§3, §5) or the CUDA 2.3 documentation it
//! cites [13, 15, 16]; nothing here is fitted to Table 1 except where a
//! constant is explicitly marked *calibrated* (and cross-checked in
//! EXPERIMENTS.md).

/// A CUDA-era GPU, parameterized the way the CC 1.x occupancy rules need.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub sm_count: usize,
    /// Scalar processors per SM.
    pub sp_per_sm: usize,
    /// SP clock in GHz.
    pub clock_ghz: f64,
    /// Shared memory per SM, bytes.
    pub smem_per_sm: usize,
    /// 32-bit registers per SM.
    pub regs_per_sm: usize,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Max resident blocks per SM.
    pub max_blocks_per_sm: usize,
    /// Threads per warp.
    pub warp_size: usize,
    /// Register allocation granularity (CC 1.3: per-block, rounded up).
    pub reg_alloc_granularity: usize,
    /// Shared-memory allocation granularity, bytes.
    pub smem_alloc_granularity: usize,
    /// *Measured* device-to-device bandwidth, GB/s (paper §3.1: 77 GB/s —
    /// deliberately the measured figure, not the 102 GB/s spec sheet).
    pub dtod_bandwidth_gbs: f64,
    /// Effective bus utilization for FW's read-modify-write stream
    /// (*calibrated*: the paper measures H&N achieving 42 of 77 GB/s; the
    /// shortfall is uncoalesced column reads + partial transactions on
    /// CC 1.3's no-cache path).
    pub bus_efficiency: f64,
    /// Kernel launch overhead, seconds (CUDA 2.x era, ~7 µs).
    pub launch_overhead_s: f64,
    /// Resident threads per SM needed to fully hide global-memory latency
    /// (§3.3, citing the CUDA best-practices guide [16]).
    pub latency_hiding_threads: usize,
    /// Minimum issue efficiency when the scheduler is starved (a single
    /// warp still makes progress; the pipeline is ~8 deep per SP).
    pub min_issue_efficiency: f64,
}

impl DeviceSpec {
    /// The paper's GPU: NVIDIA Tesla C1060, compute capability 1.3.
    pub fn tesla_c1060() -> Self {
        DeviceSpec {
            name: "NVIDIA Tesla C1060",
            sm_count: 30,
            sp_per_sm: 8,
            clock_ghz: 1.296,
            smem_per_sm: 16 * 1024,
            regs_per_sm: 16 * 1024,
            max_threads_per_sm: 1024,
            max_blocks_per_sm: 8,
            warp_size: 32,
            reg_alloc_granularity: 512,
            smem_alloc_granularity: 512,
            dtod_bandwidth_gbs: 77.0,
            bus_efficiency: 0.55,
            launch_overhead_s: 7e-6,
            latency_hiding_threads: 512,
            min_issue_efficiency: 0.12,
        }
    }

    /// Scalar instruction issue rate across the device, instructions/s.
    /// (933 GFLOP/s is the MUL+MAD dual-issue marketing peak; FW's add/min
    /// stream issues one instruction per SP per clock: 30·8·1.296 ≈ 311 G/s.)
    pub fn instr_per_sec(&self) -> f64 {
        self.sm_count as f64 * self.sp_per_sm as f64 * self.clock_ghz * 1e9
    }

    /// Issue efficiency as a function of resident threads per SM: the
    /// scheduler hides latency linearly up to `latency_hiding_threads`
    /// (§3.3), with a floor for the starved single-block case.
    pub fn issue_efficiency(&self, resident_threads: usize) -> f64 {
        let frac = resident_threads as f64 / self.latency_hiding_threads as f64;
        frac.min(1.0).max(self.min_issue_efficiency)
    }

    /// Effective bus bandwidth for the FW traffic pattern, bytes/s.
    pub fn effective_bandwidth(&self) -> f64 {
        self.dtod_bandwidth_gbs * 1e9 * self.bus_efficiency
    }
}

/// The paper's CPU baseline: AMD Phenom 9950 running a basic triple loop.
/// Table 1 gives 2.405 s at n=1024 ⇒ 2.24·10⁻⁹ s/task; the constant drifts
/// to ≈2.1·10⁻⁹ at n=4096 (*calibrated* midpoint used).
#[derive(Clone, Debug)]
pub struct CpuSpec {
    pub name: &'static str,
    pub sec_per_task: f64,
}

impl CpuSpec {
    pub fn phenom_9950() -> Self {
        CpuSpec {
            name: "AMD Phenom 9950 (1 core)",
            sec_per_task: 2.17e-9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c1060_instruction_rate() {
        let d = DeviceSpec::tesla_c1060();
        let gips = d.instr_per_sec() / 1e9;
        assert!((gips - 311.0).abs() < 1.0, "{gips}");
    }

    #[test]
    fn issue_efficiency_monotone() {
        let d = DeviceSpec::tesla_c1060();
        assert!(d.issue_efficiency(64) < d.issue_efficiency(256));
        assert!(d.issue_efficiency(256) < d.issue_efficiency(512));
        assert_eq!(d.issue_efficiency(512), 1.0);
        assert_eq!(d.issue_efficiency(1024), 1.0);
    }

    #[test]
    fn issue_efficiency_floor() {
        let d = DeviceSpec::tesla_c1060();
        assert_eq!(d.issue_efficiency(0), d.min_issue_efficiency);
    }

    #[test]
    fn paper_quoted_bandwidths() {
        let d = DeviceSpec::tesla_c1060();
        // §5: H&N achieves 42 GB/s of the 77 GB/s measured bus
        let achieved = d.effective_bandwidth() / 1e9;
        assert!((achieved - 42.35).abs() < 1.0, "{achieved}");
    }

    #[test]
    fn cpu_constant_matches_table1() {
        let c = CpuSpec::phenom_9950();
        // Table 1 col 1: n=1024 → 2.405 s, n=4096 → 145.2 s
        let t1024 = c.sec_per_task * 1024f64.powi(3);
        let t4096 = c.sec_per_task * 4096f64.powi(3);
        assert!((t1024 - 2.405).abs() / 2.405 < 0.05, "{t1024}");
        assert!((t4096 - 145.2).abs() / 145.2 < 0.05, "{t4096}");
    }
}

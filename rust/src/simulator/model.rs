//! Execution-time composition: phases × stages × occupancy × rooflines.
//!
//! For a blocked variant at problem size `n` (tile `s`, `nb = n/s` stages)
//! each stage launches three kernels (§3.2):
//!
//! * phase 1 — 1 block (the diagonal tile), s³ tasks;
//! * phase 2 — 2(nb−1) blocks, 2(nb−1)·s³ tasks;
//! * phase 3 — (nb−1)² blocks, (nb−1)²·s³ tasks (the hot path).
//!
//! Each kernel's time is `max(compute, memory) + launch overhead`, where
//!
//! * compute = tasks · cycles_per_task / (device issue rate · issue
//!   efficiency(resident threads) · device fill(blocks))
//! * memory  = bytes / (measured bus bandwidth · pattern efficiency)
//!
//! Issue efficiency captures §3.3's latency-hiding argument (resident
//! threads / 512, floored); device fill captures partially-filled waves at
//! small n.  H&N is n sequential launches of an n²-task memory-bound
//! kernel; the CPU row is the calibrated `sec_per_task · n³`.

use super::device::{CpuSpec, DeviceSpec};
use super::kernels::Variant;
use super::occupancy::{occupancy, Occupancy};

/// Simulated execution breakdown for one (variant, n).
#[derive(Clone, Debug)]
pub struct SimResult {
    pub variant: Variant,
    pub n: usize,
    pub seconds: f64,
    /// Seconds spent in each phase [p1, p2, p3] (GPU blocked variants).
    pub phase_seconds: [f64; 3],
    /// Total kernel-launch overhead.
    pub launch_seconds: f64,
    /// Tasks per second over the whole run (n³ / seconds).
    pub tasks_per_sec: f64,
    /// Whether the hot phase was bound by memory (vs issue rate).
    pub memory_bound: bool,
    /// Occupancy of the hot kernel (None for CPU).
    pub occupancy: Option<Occupancy>,
}

/// Simulate `variant` solving an `n`-vertex instance on the C1060 testbed.
pub fn simulate(variant: Variant, n: usize) -> SimResult {
    simulate_on(&DeviceSpec::tesla_c1060(), &CpuSpec::phenom_9950(), variant, n)
}

/// Simulate on explicit device/CPU specs (for what-if ablations).
pub fn simulate_on(
    dev: &DeviceSpec,
    cpu: &CpuSpec,
    variant: Variant,
    n: usize,
) -> SimResult {
    let n3 = (n as f64).powi(3);
    match variant {
        Variant::Cpu => {
            let seconds = cpu.sec_per_task * n3;
            SimResult {
                variant,
                n,
                seconds,
                phase_seconds: [0.0, 0.0, seconds],
                launch_seconds: 0.0,
                tasks_per_sec: n3 / seconds,
                memory_bound: false,
                occupancy: None,
            }
        }
        Variant::HarishNarayanan => simulate_unblocked(dev, variant, n),
        _ => simulate_blocked(dev, variant, n),
    }
}

/// H&N: n sequential kernel launches, each relaxing all n² elements.
fn simulate_unblocked(dev: &DeviceSpec, variant: Variant, n: usize) -> SimResult {
    let km = variant.kernel().expect("GPU variant");
    let occ = occupancy(dev, &km.resources);
    let n2 = (n as f64) * (n as f64);
    let blocks_per_launch = (n2 / km.resources.threads as f64).ceil();
    let fill = device_fill(dev, &occ, blocks_per_launch);
    let eff = dev.issue_efficiency(occ.resident_threads);
    let compute_per_launch = n2 * km.cycles_per_task / (dev.instr_per_sec() * eff * fill);
    // §3.1: 16 bytes/task; the 0.55 bus efficiency (measured 42 of 77 GB/s)
    // lives in DeviceSpec for this uncoalesced-column pattern
    let memory_per_launch = n2 * km.bytes_per_task / dev.effective_bandwidth();
    let per_launch = compute_per_launch.max(memory_per_launch);
    let launch_seconds = n as f64 * dev.launch_overhead_s;
    let seconds = n as f64 * per_launch + launch_seconds;
    SimResult {
        variant,
        n,
        seconds,
        phase_seconds: [0.0, 0.0, n as f64 * per_launch],
        launch_seconds,
        tasks_per_sec: n2 * n as f64 / seconds,
        memory_bound: memory_per_launch > compute_per_launch,
        occupancy: Some(occ),
    }
}

/// Blocked variants: nb stages × three kernels.
fn simulate_blocked(dev: &DeviceSpec, variant: Variant, n: usize) -> SimResult {
    let km = variant.kernel().expect("GPU variant");
    let s = km.tile;
    assert!(n % s == 0, "simulate: n={n} not a multiple of tile {s}");
    let nb = n / s;
    let occ = occupancy(dev, &km.resources);
    let eff = dev.issue_efficiency(occ.resident_threads);
    let rate_full = dev.instr_per_sec() * eff / km.cycles_per_task;
    let s3 = (s as f64).powi(3);
    let bw = dev.dtod_bandwidth_gbs * 1e9 * km.bus_efficiency;

    let kernel_time = |blocks: f64, tasks: f64| -> (f64, bool) {
        if blocks == 0.0 {
            return (0.0, false);
        }
        let fill = device_fill(dev, &occ, blocks);
        let compute = tasks / (rate_full * fill);
        // traffic: each block moves its tiles regardless of fill
        let memory = tasks * km.bytes_per_task / bw;
        (compute.max(memory), memory > compute)
    };

    // stages are identical in cost; compute one stage and multiply by nb
    let mut phase_seconds = [0.0f64; 3];
    let (p1, _) = kernel_time(1.0, s3);
    let (p2, _) = kernel_time(2.0 * (nb as f64 - 1.0), 2.0 * (nb as f64 - 1.0) * s3);
    let (p3, p3_mem) = kernel_time(
        (nb as f64 - 1.0) * (nb as f64 - 1.0),
        (nb as f64 - 1.0) * (nb as f64 - 1.0) * s3,
    );
    phase_seconds[0] = nb as f64 * p1;
    phase_seconds[1] = nb as f64 * p2;
    phase_seconds[2] = nb as f64 * p3;
    let memory_bound = p3_mem;

    let launch_seconds = nb as f64 * 3.0 * dev.launch_overhead_s;
    let seconds = phase_seconds.iter().sum::<f64>() + launch_seconds;
    let n3 = (n as f64).powi(3);
    SimResult {
        variant,
        n,
        seconds,
        phase_seconds,
        launch_seconds,
        tasks_per_sec: n3 / seconds,
        memory_bound,
        occupancy: Some(occ),
    }
}

/// Fraction of the device busy given the grid size: blocks fill SMs in
/// waves of `sm_count × blocks_per_sm`; the last partial wave idles SMs.
fn device_fill(dev: &DeviceSpec, occ: &Occupancy, blocks: f64) -> f64 {
    let concurrent = (dev.sm_count * occ.blocks_per_sm) as f64;
    if blocks >= concurrent {
        // wave quantization: ceil(blocks/concurrent) waves for blocks work
        let waves = (blocks / concurrent).ceil();
        (blocks / concurrent) / waves
    } else {
        blocks / concurrent
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 1, the columns at n = 16384 (seconds).
    const TABLE1_16384: [(Variant, f64); 3] = [
        (Variant::KatzKider, 277.8),
        (Variant::OptimizedBlocked, 126.9),
        (Variant::StagedLoad, 53.02),
    ];

    #[test]
    fn large_n_matches_table1_within_10pct() {
        for (v, expect) in TABLE1_16384 {
            let got = simulate(v, 16384).seconds;
            let err = (got - expect).abs() / expect;
            assert!(err < 0.10, "{v:?}: simulated {got:.1}s vs paper {expect}s");
        }
    }

    #[test]
    fn hn_matches_table1() {
        // n=8192 → 208.6 s; n=1024 → 0.408 s
        let t8192 = simulate(Variant::HarishNarayanan, 8192).seconds;
        assert!((t8192 - 208.6).abs() / 208.6 < 0.05, "{t8192}");
        let t1024 = simulate(Variant::HarishNarayanan, 1024).seconds;
        assert!((t1024 - 0.408).abs() / 0.408 < 0.15, "{t1024}");
    }

    #[test]
    fn cpu_matches_table1() {
        let t = simulate(Variant::Cpu, 3072).seconds;
        assert!((t - 62.04).abs() / 62.04 < 0.05, "{t}");
    }

    #[test]
    fn speedup_factors_match_paper() {
        // §4: staged is ≈5.2× over Katz–Kider; 2.1–2.3× from instructions,
        // 2.3–2.4× from occupancy/staging
        let kk = simulate(Variant::KatzKider, 16384).seconds;
        let opt = simulate(Variant::OptimizedBlocked, 16384).seconds;
        let staged = simulate(Variant::StagedLoad, 16384).seconds;
        assert!((2.0..=2.4).contains(&(kk / opt)), "{}", kk / opt);
        assert!((2.2..=2.6).contains(&(opt / staged)), "{}", opt / staged);
        assert!((4.8..=5.6).contains(&(kk / staged)), "{}", kk / staged);
    }

    #[test]
    fn tasks_per_sec_match_section5() {
        // §5: H&N ≈2.6e9 (bandwidth-bound), K&K ≈14.9e9, staged ≈73.6e9
        let hn = simulate(Variant::HarishNarayanan, 8192);
        assert!(hn.memory_bound);
        assert!((2.4e9..=2.9e9).contains(&hn.tasks_per_sec), "{}", hn.tasks_per_sec);
        let kk = simulate(Variant::KatzKider, 16384);
        assert!(!kk.memory_bound);
        assert!((14.0e9..=16.5e9).contains(&kk.tasks_per_sec), "{}", kk.tasks_per_sec);
        let staged = simulate(Variant::StagedLoad, 16384);
        assert!(
            (70.0e9..=90.0e9).contains(&staged.tasks_per_sec),
            "{}",
            staged.tasks_per_sec
        );
    }

    #[test]
    fn staged_near_bandwidth_crossover() {
        // §5: the staged kernel sits close to the bandwidth roofline
        // ("it achieves 46 GB/sec ... less than the 70 GB/sec or so we
        // could reasonably hope for") — compute-bound, but within ~2×
        let r = simulate(Variant::StagedLoad, 16384);
        assert!(!r.memory_bound);
        let km = Variant::StagedLoad.kernel().unwrap();
        let mem_seconds = (16384f64).powi(3) * km.bytes_per_task
            / (DeviceSpec::tesla_c1060().dtod_bandwidth_gbs * 1e9 * km.bus_efficiency);
        assert!(r.seconds / mem_seconds < 2.0, "{} vs {mem_seconds}", r.seconds);
    }

    #[test]
    fn phase3_dominates_at_scale() {
        let r = simulate(Variant::StagedLoad, 8192);
        let total: f64 = r.phase_seconds.iter().sum();
        assert!(r.phase_seconds[2] / total > 0.9);
    }

    #[test]
    fn cpu_150x_slower_than_staged() {
        // abstract: "over 150× as fast as a basic Floyd-Warshall
        // implementation running on our CPU" (at n = 16384)
        let cpu = simulate(Variant::Cpu, 16384).seconds;
        let staged = simulate(Variant::StagedLoad, 16384).seconds;
        assert!(cpu / staged > 150.0, "{}", cpu / staged);
    }

    #[test]
    fn ablation_simple_k_loses() {
        let cyclic = simulate(Variant::StagedLoad, 4096).seconds;
        let simple = simulate(Variant::StagedSimpleK, 4096).seconds;
        assert!(simple / cyclic > 1.8, "{}", simple / cyclic);
    }

    #[test]
    fn monotone_in_n() {
        for v in [Variant::KatzKider, Variant::StagedLoad, Variant::HarishNarayanan] {
            let mut last = 0.0;
            for n in [1024, 2048, 4096, 8192] {
                let t = simulate(v, n).seconds;
                assert!(t > last, "{v:?} not monotone at {n}");
                last = t;
            }
        }
    }
}

//! Per-variant kernel resource and cost models.
//!
//! Each Table 1 column is a [`Variant`]; each variant's phase-3 kernel (the
//! Θ(n³) hot path) is described by a [`KernelModel`]: per-block resources
//! (⇒ occupancy), cycles per task, and bytes of bus traffic per task.
//!
//! Cycle counts decompose as
//!
//! ```text
//! cycles/task = (2·conflict_degree + 2) · co_issue + index_overhead
//!               └ 2 smem loads   add+min ┘
//! ```
//!
//! * `conflict_degree` comes from the bank model in [`crate::layout`]
//!   (1 for row-major and for tiled+cyclic; 4 for tiled+simple-k, §4.3).
//! * `index_overhead` is the per-task share of address arithmetic: ~5.8
//!   cycles with div/mod and no unrolling (§4: removing it is the 2.1–2.3×
//!   "Optimized" step), ~0.5 after shifts + unrolling, and ~0.47 for the
//!   staged kernel (more tasks per thread amortize setup, §4).
//! * `co_issue` models ILP: the staged kernel holds 16 independent
//!   accumulator chains in registers per thread, letting the SM dual-issue
//!   enough to push effective CPI below 1 (0.82, *calibrated*; equals the
//!   paper's measured 12.7 FLOP-equivalents/task within 2%).
//!
//! Everything else (occupancy → issue efficiency, wave quantization,
//! bandwidth roofline, launch overhead) lives in [`super::model`].

use super::occupancy::BlockResources;
use crate::layout::{bank_conflict_degree, AccessPattern, KSchedule};

/// The five Table 1 columns plus the bank-conflict ablation (E5/E8).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Basic triple loop on the host CPU.
    Cpu,
    /// Harish & Narayanan [3]: one thread per task, no blocking.
    HarishNarayanan,
    /// Katz & Kider [2]: blocked, 3 tiles in shared memory.
    KatzKider,
    /// §4 first round: K&K + shifts/unrolling (fewer, cheaper instructions).
    OptimizedBlocked,
    /// §4 second round: registers + staged panel loads + cyclic k (the paper).
    StagedLoad,
    /// Ablation: staged kernel with the *simple* k order — 4-way bank
    /// conflicts (Fig. 6 middle). Not in Table 1; quantifies §4.3's fix.
    StagedSimpleK,
}

impl Variant {
    pub const TABLE1: [Variant; 5] = [
        Variant::Cpu,
        Variant::HarishNarayanan,
        Variant::KatzKider,
        Variant::OptimizedBlocked,
        Variant::StagedLoad,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Cpu => "CPU",
            Variant::HarishNarayanan => "Harish & Narayanan",
            Variant::KatzKider => "Katz & Kider",
            Variant::OptimizedBlocked => "Optimized & Blocked",
            Variant::StagedLoad => "Staged Load",
            Variant::StagedSimpleK => "Staged (simple k)",
        }
    }

    /// Parse a CLI name.
    pub fn from_str(s: &str) -> Option<Variant> {
        Some(match s {
            "cpu" => Variant::Cpu,
            "hn" | "harish-narayanan" | "naive" => Variant::HarishNarayanan,
            "kk" | "katz-kider" | "blocked" => Variant::KatzKider,
            "opt" | "optimized" => Variant::OptimizedBlocked,
            "staged" | "staged-load" => Variant::StagedLoad,
            "staged-simple-k" => Variant::StagedSimpleK,
            _ => return None,
        })
    }
}

/// Cost model of one GPU kernel (the phase-3 kernel for blocked variants).
#[derive(Clone, Copy, Debug)]
pub struct KernelModel {
    /// Per-block resources → occupancy.
    pub resources: BlockResources,
    /// Issue cycles per task on one SP.
    pub cycles_per_task: f64,
    /// Global-bus bytes per task.
    pub bytes_per_task: f64,
    /// Bus efficiency for this kernel's access pattern (fraction of the
    /// measured 77 GB/s usable).
    pub bus_efficiency: f64,
    /// Tile size (0 = unblocked).
    pub tile: usize,
}

/// Address-arithmetic overhead per task, cycles.
const INDEX_UNOPTIMIZED: f64 = 5.8; // div/mod + no unrolling (§4)
const INDEX_OPTIMIZED: f64 = 0.5; // shifts + unrolled loops
const INDEX_STAGED: f64 = 0.47; // + more tasks per thread

/// ILP factor of the register-tiled staged kernel (*calibrated*).
const CO_ISSUE_STAGED: f64 = 0.82;

/// Tiled coalesced streaming reaches ~70 of 77 GB/s (§5: "the 70 GB/sec or
/// so we could reasonably hope for").
const BUS_EFF_TILED: f64 = 70.0 / 77.0;

fn base_cycles(conflict_degree: usize, co_issue: f64, index_overhead: f64) -> f64 {
    (2.0 * conflict_degree as f64 + 2.0) * co_issue + index_overhead
}

impl Variant {
    /// The phase-3 kernel model for GPU variants; `None` for the CPU row.
    pub fn kernel(&self) -> Option<KernelModel> {
        let tile = 32;
        Some(match self {
            Variant::Cpu => return None,
            Variant::HarishNarayanan => KernelModel {
                // one thread per element, k sequential on the host side;
                // 3 loads + 1 store = 16 B/task over the bus (§3.1)
                resources: BlockResources {
                    threads: 256,
                    regs_per_thread: 10,
                    smem_bytes: 32,
                },
                cycles_per_task: base_cycles(1, 1.0, INDEX_UNOPTIMIZED),
                bytes_per_task: 16.0,
                bus_efficiency: 1.0, // uses DeviceSpec.bus_efficiency semantics below
                tile: 0,
            },
            Variant::KatzKider => KernelModel {
                // 3 full tiles in smem: 3·32²·4 + 32 = 12320 B (§3.3)
                resources: BlockResources {
                    threads: 256,
                    regs_per_thread: 16,
                    smem_bytes: 12320,
                },
                cycles_per_task: base_cycles(
                    bank_conflict_degree(AccessPattern::RowMajor, KSchedule::Simple),
                    1.0,
                    INDEX_UNOPTIMIZED,
                ),
                // 4 tiles of traffic per 32·32² tasks = 0.5 B/task
                bytes_per_task: 16.0 / tile as f64,
                bus_efficiency: BUS_EFF_TILED,
                tile,
            },
            Variant::OptimizedBlocked => KernelModel {
                resources: BlockResources {
                    threads: 256,
                    regs_per_thread: 16,
                    smem_bytes: 12320,
                },
                cycles_per_task: base_cycles(
                    bank_conflict_degree(AccessPattern::RowMajor, KSchedule::Simple),
                    1.0,
                    INDEX_OPTIMIZED,
                ),
                bytes_per_task: 16.0 / tile as f64,
                bus_efficiency: BUS_EFF_TILED,
                tile,
            },
            Variant::StagedLoad => KernelModel {
                // §4.2: 2·32·4·4 + 32 = 1056 B, 64 threads, tile in registers
                resources: BlockResources {
                    threads: 64,
                    regs_per_thread: 32,
                    smem_bytes: 1056,
                },
                cycles_per_task: base_cycles(
                    bank_conflict_degree(AccessPattern::Tiled4x4, KSchedule::Cyclic),
                    CO_ISSUE_STAGED,
                    INDEX_STAGED,
                ),
                bytes_per_task: 16.0 / tile as f64,
                bus_efficiency: BUS_EFF_TILED,
                tile,
            },
            Variant::StagedSimpleK => KernelModel {
                resources: BlockResources {
                    threads: 64,
                    regs_per_thread: 32,
                    smem_bytes: 1056,
                },
                cycles_per_task: base_cycles(
                    bank_conflict_degree(AccessPattern::Tiled4x4, KSchedule::Simple),
                    CO_ISSUE_STAGED,
                    INDEX_STAGED,
                ),
                bytes_per_task: 16.0 / tile as f64,
                bus_efficiency: BUS_EFF_TILED,
                tile,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_hierarchy_matches_paper_ratios() {
        let kk = Variant::KatzKider.kernel().unwrap().cycles_per_task;
        let opt = Variant::OptimizedBlocked.kernel().unwrap().cycles_per_task;
        let staged = Variant::StagedLoad.kernel().unwrap().cycles_per_task;
        // §4: instruction optimization alone is a 2.1–2.3× speedup
        let instr_ratio = kk / opt;
        assert!(
            (2.1..=2.3).contains(&instr_ratio),
            "instr speedup {instr_ratio}"
        );
        // staged cycles must be below optimized (ILP + amortized indexing)
        assert!(staged < opt);
    }

    #[test]
    fn staged_matches_paper_flop_equivalents() {
        // §5: staged uses "the equivalent of 12.7 FLOPs per task" of the
        // 933 GFLOP marketing peak = 12.7/3 ≈ 4.2 issue cycles... the
        // comparable quantity in our 311 G instr/s terms:
        // tasks/s = 311e9 / cycles ⇒ paper's 73.6e9 tasks/s ⇒ 4.23 cycles
        // at full occupancy. Our model: 3.75 cycles at occupancy 512/512.
        let staged = Variant::StagedLoad.kernel().unwrap().cycles_per_task;
        assert!((3.5..=4.4).contains(&staged), "{staged}");
    }

    #[test]
    fn simple_k_ablation_pays_bank_conflicts() {
        let cyclic = Variant::StagedLoad.kernel().unwrap().cycles_per_task;
        let simple = Variant::StagedSimpleK.kernel().unwrap().cycles_per_task;
        // 2 loads go from 1 cycle to 4 cycles each (Fig. 6): >2× slower
        assert!(simple / cyclic > 2.0, "{simple} / {cyclic}");
    }

    #[test]
    fn blocking_reduces_traffic_32x() {
        let hn = Variant::HarishNarayanan.kernel().unwrap().bytes_per_task;
        let kk = Variant::KatzKider.kernel().unwrap().bytes_per_task;
        assert_eq!(hn / kk, 32.0); // §3.2: "reduced by a factor of 32"
    }

    #[test]
    fn cpu_has_no_kernel() {
        assert!(Variant::Cpu.kernel().is_none());
    }

    #[test]
    fn variant_names_roundtrip() {
        for v in Variant::TABLE1 {
            if v != Variant::Cpu {
                assert!(Variant::from_str(match v {
                    Variant::HarishNarayanan => "hn",
                    Variant::KatzKider => "kk",
                    Variant::OptimizedBlocked => "opt",
                    Variant::StagedLoad => "staged",
                    _ => unreachable!(),
                })
                .is_some());
            }
        }
        assert_eq!(Variant::from_str("nope"), None);
    }
}

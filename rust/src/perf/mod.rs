//! First-party benchmark harness (no `criterion` in the vendored set).
//!
//! [`bench`] runs a closure with warm-up, auto-scaled iteration counts,
//! and outlier-aware summary statistics, printing one criterion-style line
//! per benchmark.  `cargo bench` targets under `rust/benches/` drive it.
//!
//! Results are also machine-readable: [`BenchResult::to_json`] serializes
//! one measurement, and [`BenchSink`] accumulates a bench run into the
//! repo's perf-trajectory file (`BENCH_<name>.json` at the repo root by
//! default; `FW_BENCH_JSON=<path>` overrides, `FW_BENCH_JSON=off`
//! disables).  Each `cargo bench` invocation appends one run object, so
//! the file records how the hot paths move across PRs.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::util::json::Json;
use crate::util::stats::Samples;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Wall-clock budget for the measurement phase.
    pub measure_time: Duration,
    /// Wall-clock budget for warm-up.
    pub warmup_time: Duration,
    /// Max samples to record.
    pub max_samples: usize,
    /// Min samples regardless of time budget.
    pub min_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            measure_time: Duration::from_secs(2),
            warmup_time: Duration::from_millis(300),
            max_samples: 200,
            min_samples: 5,
        }
    }
}

impl BenchConfig {
    /// Quick preset for CI-style smoke benches.
    pub fn quick() -> Self {
        BenchConfig {
            measure_time: Duration::from_millis(500),
            warmup_time: Duration::from_millis(100),
            max_samples: 50,
            min_samples: 3,
        }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Samples,
    /// Seconds per iteration (mean).
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
}

/// Measure `f` (one logical operation per call).
pub fn bench<F: FnMut()>(name: &str, config: &BenchConfig, mut f: F) -> BenchResult {
    // warm-up
    let warm_deadline = Instant::now() + config.warmup_time;
    let mut warm_iters = 0u64;
    while Instant::now() < warm_deadline || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    // measurement
    let mut samples = Samples::new();
    let deadline = Instant::now() + config.measure_time;
    while (samples.len() < config.max_samples && Instant::now() < deadline)
        || samples.len() < config.min_samples
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mut s = samples.clone();
    BenchResult {
        name: name.to_string(),
        mean_s: samples.mean(),
        median_s: s.median(),
        stddev_s: samples.stddev(),
        samples,
    }
}

impl BenchResult {
    /// criterion-style report line.
    pub fn report(&self) -> String {
        format!(
            "{:<48} time: [{} {} {}]  (n={})",
            self.name,
            format_time(self.median_s - self.stddev_s),
            format_time(self.median_s),
            format_time(self.median_s + self.stddev_s),
            self.samples.len(),
        )
    }

    /// Report with a derived throughput figure.
    pub fn report_throughput(&self, units: f64, unit_name: &str) -> String {
        format!(
            "{}  thrpt: {:.3e} {unit_name}/s",
            self.report(),
            units / self.median_s
        )
    }

    /// Machine-readable form of one measurement (seconds per iteration;
    /// object keys are sorted by the codec, so output is deterministic).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("mean_s", Json::Num(self.mean_s)),
            ("median_s", Json::Num(self.median_s)),
            ("stddev_s", Json::Num(self.stddev_s)),
            ("samples", Json::Num(self.samples.len() as f64)),
        ])
    }
}

/// Accumulates one bench run and appends it to a perf-trajectory file:
///
/// ```json
/// {"bench": "apsp", "runs": [{"unix_time": …, "meta": {…}, "results": […]}]}
/// ```
///
/// The default path is `BENCH_<name>.json` at the repo root (one directory
/// above the crate), so `cargo bench --bench apsp` grows the trajectory in
/// place; `FW_BENCH_JSON=<path>` redirects it and `FW_BENCH_JSON=off`
/// (or `0`, or empty) disables the sink.  A corrupt or foreign existing
/// file is replaced rather than appended to.
pub struct BenchSink {
    bench: String,
    path: Option<PathBuf>,
    meta: Vec<(String, Json)>,
    results: Vec<Json>,
}

impl BenchSink {
    /// Sink for the named bench, honoring `FW_BENCH_JSON`.
    pub fn from_env(bench: &str) -> BenchSink {
        let path = match std::env::var("FW_BENCH_JSON") {
            Ok(v) if v.is_empty() || v == "off" || v == "0" => None,
            Ok(v) => Some(PathBuf::from(v)),
            Err(_) => Some(default_trajectory_path(bench)),
        };
        BenchSink {
            bench: bench.to_string(),
            path,
            meta: Vec::new(),
            results: Vec::new(),
        }
    }

    /// Sink writing to an explicit path (tests; tooling).
    pub fn to_path(bench: &str, path: impl Into<PathBuf>) -> BenchSink {
        BenchSink {
            bench: bench.to_string(),
            path: Some(path.into()),
            meta: Vec::new(),
            results: Vec::new(),
        }
    }

    /// Whether `finish` will write anywhere.
    pub fn enabled(&self) -> bool {
        self.path.is_some()
    }

    /// Attach run-level metadata (problem size, fast mode, …).
    pub fn set_meta(&mut self, key: &str, value: Json) {
        self.meta.push((key.to_string(), value));
    }

    /// Record one measurement.
    pub fn record(&mut self, r: &BenchResult) {
        self.results.push(r.to_json());
    }

    /// Record one measurement with extra per-result fields (e.g. the
    /// throughput figure the human-readable report derives).
    pub fn record_with(&mut self, r: &BenchResult, extras: Vec<(&str, Json)>) {
        let mut obj = match r.to_json() {
            Json::Obj(map) => map,
            _ => unreachable!("to_json returns an object"),
        };
        for (k, v) in extras {
            obj.insert(k.to_string(), v);
        }
        self.results.push(Json::Obj(obj));
    }

    /// Record an already-shaped measurement object (e.g. the live-serving
    /// histogram rows from [`crate::obs::hist::Histogram::to_bench_json`]),
    /// letting non-`bench()` sources feed the same trajectory file.
    pub fn record_json(&mut self, row: Json) {
        self.results.push(row);
    }

    /// Append this run to the trajectory file.  Returns the path written,
    /// or `None` when the sink is disabled.
    pub fn finish(self) -> std::io::Result<Option<PathBuf>> {
        let Some(path) = self.path else {
            return Ok(None);
        };
        let mut runs: Vec<Json> = match std::fs::read_to_string(&path) {
            Ok(text) => match Json::parse(&text) {
                Ok(v) if v.get("bench").as_str() == Some(self.bench.as_str()) => {
                    v.get("runs").as_arr().map(<[Json]>::to_vec).unwrap_or_default()
                }
                _ => Vec::new(),
            },
            Err(_) => Vec::new(),
        };
        let unix_time = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        runs.push(Json::obj(vec![
            ("unix_time", Json::Num(unix_time)),
            (
                "meta",
                Json::Obj(self.meta.into_iter().collect()),
            ),
            ("results", Json::Arr(self.results)),
        ]));
        let doc = Json::obj(vec![
            ("bench", Json::str(self.bench)),
            ("runs", Json::Arr(runs)),
        ]);
        std::fs::write(&path, doc.to_string())?;
        Ok(Some(path))
    }
}

/// `BENCH_<name>.json` at the repo root (the crate's parent directory —
/// benches compile inside the workspace, so the manifest dir is `rust/`).
fn default_trajectory_path(bench: &str) -> PathBuf {
    let crate_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    crate_dir
        .parent()
        .unwrap_or(crate_dir)
        .join(format!("BENCH_{bench}.json"))
}

/// Human-friendly time formatting (s/ms/µs/ns).
pub fn format_time(seconds: f64) -> String {
    let s = seconds.max(0.0);
    if s >= 1.0 {
        format!("{s:.4} s")
    } else if s >= 1e-3 {
        format!("{:.4} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.4} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let cfg = BenchConfig {
            measure_time: Duration::from_millis(50),
            warmup_time: Duration::from_millis(5),
            max_samples: 20,
            min_samples: 3,
        };
        let mut acc = 0u64;
        let r = bench("noop-ish", &cfg, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.samples.len() >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn format_time_units() {
        assert!(format_time(2.5).ends_with(" s"));
        assert!(format_time(2.5e-3).ends_with(" ms"));
        assert!(format_time(2.5e-6).ends_with(" µs"));
        assert!(format_time(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn throughput_report() {
        let cfg = BenchConfig::quick();
        let r = bench("t", &cfg, || {
            black_box(1 + 1);
        });
        let line = r.report_throughput(1e6, "tasks");
        assert!(line.contains("tasks/s"));
    }

    fn tiny_config() -> BenchConfig {
        BenchConfig {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(2),
            max_samples: 10,
            min_samples: 3,
        }
    }

    #[test]
    fn to_json_carries_the_summary_fields() {
        let r = bench("shape", &tiny_config(), || {
            black_box(1 + 1);
        });
        let j = r.to_json();
        assert_eq!(j.get("name").as_str(), Some("shape"));
        for key in ["mean_s", "median_s", "stddev_s", "samples"] {
            assert!(j.get(key).as_f64().is_some(), "missing {key}");
        }
        // deterministic serialization (sorted keys) round-trips
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn record_json_feeds_raw_rows() {
        let path = std::env::temp_dir().join(format!(
            "fw-stage-perf-sink-raw-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut sink = BenchSink::to_path("rawtest", &path);
        sink.record_json(Json::obj(vec![
            ("name", Json::str("serve/solve")),
            ("count", Json::Num(7.0)),
        ]));
        sink.finish().unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let runs = doc.get("runs").as_arr().unwrap();
        let results = runs[0].get("results").as_arr().unwrap();
        assert_eq!(results[0].get("name").as_str(), Some("serve/solve"));
        assert_eq!(results[0].get("count").as_f64(), Some(7.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sink_accumulates_runs_across_invocations() {
        let path = std::env::temp_dir().join(format!(
            "fw-stage-perf-sink-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let r = bench("noop", &tiny_config(), || {
            black_box(1 + 1);
        });
        for round in 1..=2 {
            let mut sink = BenchSink::to_path("selftest", &path);
            assert!(sink.enabled());
            sink.set_meta("n", Json::Num(64.0));
            sink.record(&r);
            sink.record_with(&r, vec![("tasks_per_sec", Json::Num(123.0))]);
            let written = sink.finish().unwrap().expect("sink enabled");
            assert_eq!(written, path);
            let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            assert_eq!(doc.get("bench").as_str(), Some("selftest"));
            let runs = doc.get("runs").as_arr().unwrap();
            assert_eq!(runs.len(), round, "one run appended per invocation");
            let results = runs[round - 1].get("results").as_arr().unwrap();
            assert_eq!(results.len(), 2);
            assert_eq!(results[0].get("name").as_str(), Some("noop"));
            assert_eq!(results[1].get("tasks_per_sec").as_f64(), Some(123.0));
            assert_eq!(runs[round - 1].get("meta").get("n").as_f64(), Some(64.0));
        }
        // a foreign file is replaced, not appended to
        std::fs::write(&path, r#"{"bench":"other","runs":[1,2,3]}"#).unwrap();
        let mut sink = BenchSink::to_path("selftest", &path);
        sink.record(&r);
        sink.finish().unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("runs").as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}

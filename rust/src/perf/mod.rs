//! First-party benchmark harness (no `criterion` in the vendored set).
//!
//! [`bench`] runs a closure with warm-up, auto-scaled iteration counts,
//! and outlier-aware summary statistics, printing one criterion-style line
//! per benchmark.  `cargo bench` targets under `rust/benches/` drive it.

use std::time::{Duration, Instant};

use crate::util::stats::Samples;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Wall-clock budget for the measurement phase.
    pub measure_time: Duration,
    /// Wall-clock budget for warm-up.
    pub warmup_time: Duration,
    /// Max samples to record.
    pub max_samples: usize,
    /// Min samples regardless of time budget.
    pub min_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            measure_time: Duration::from_secs(2),
            warmup_time: Duration::from_millis(300),
            max_samples: 200,
            min_samples: 5,
        }
    }
}

impl BenchConfig {
    /// Quick preset for CI-style smoke benches.
    pub fn quick() -> Self {
        BenchConfig {
            measure_time: Duration::from_millis(500),
            warmup_time: Duration::from_millis(100),
            max_samples: 50,
            min_samples: 3,
        }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Samples,
    /// Seconds per iteration (mean).
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
}

/// Measure `f` (one logical operation per call).
pub fn bench<F: FnMut()>(name: &str, config: &BenchConfig, mut f: F) -> BenchResult {
    // warm-up
    let warm_deadline = Instant::now() + config.warmup_time;
    let mut warm_iters = 0u64;
    while Instant::now() < warm_deadline || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    // measurement
    let mut samples = Samples::new();
    let deadline = Instant::now() + config.measure_time;
    while (samples.len() < config.max_samples && Instant::now() < deadline)
        || samples.len() < config.min_samples
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mut s = samples.clone();
    BenchResult {
        name: name.to_string(),
        mean_s: samples.mean(),
        median_s: s.median(),
        stddev_s: samples.stddev(),
        samples,
    }
}

impl BenchResult {
    /// criterion-style report line.
    pub fn report(&self) -> String {
        format!(
            "{:<48} time: [{} {} {}]  (n={})",
            self.name,
            format_time(self.median_s - self.stddev_s),
            format_time(self.median_s),
            format_time(self.median_s + self.stddev_s),
            self.samples.len(),
        )
    }

    /// Report with a derived throughput figure.
    pub fn report_throughput(&self, units: f64, unit_name: &str) -> String {
        format!(
            "{}  thrpt: {:.3e} {unit_name}/s",
            self.report(),
            units / self.median_s
        )
    }
}

/// Human-friendly time formatting (s/ms/µs/ns).
pub fn format_time(seconds: f64) -> String {
    let s = seconds.max(0.0);
    if s >= 1.0 {
        format!("{s:.4} s")
    } else if s >= 1e-3 {
        format!("{:.4} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.4} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let cfg = BenchConfig {
            measure_time: Duration::from_millis(50),
            warmup_time: Duration::from_millis(5),
            max_samples: 20,
            min_samples: 3,
        };
        let mut acc = 0u64;
        let r = bench("noop-ish", &cfg, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.samples.len() >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn format_time_units() {
        assert!(format_time(2.5).ends_with(" s"));
        assert!(format_time(2.5e-3).ends_with(" ms"));
        assert!(format_time(2.5e-6).ends_with(" µs"));
        assert!(format_time(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn throughput_report() {
        let cfg = BenchConfig::quick();
        let r = bench("t", &cfg, || {
            black_box(1 + 1);
        });
        let line = r.report_throughput(1e6, "tasks");
        assert!(line.contains("tasks/s"));
    }
}

//! `fw-stage` command-line interface — the launcher for every part of the
//! system.
//!
//! ```text
//! fw-stage solve     --input g.gr [--variant staged|superblock] [--artifacts DIR]
//!                    [--objective shortest|bottleneck|minimax|reachability]
//!                    [--superblock-bucket N] [--superblock-workers W] [--output d.dist]
//!                    [--paths --src A --dst B] [--update "u,v,w[;u,v,w…]"]
//! fw-stage serve     [--addr 127.0.0.1:7878] [--artifacts DIR] [--cache 128]
//!                    [--superblock-bucket N] [--superblock-workers W]
//!                    [--update-max-chain K] [--log-level error|warn|info|debug]
//!                    [--trace-journal K] [--max-connections N]
//!                    [--workers W] [--queue-depth D] [--deadline-ms MS]
//!                    [--idle-timeout-ms MS] [--store-dir DIR]
//!                    [--store-max-bytes BYTES]
//! fw-stage client    --addr HOST:PORT --input g.gr [--variant staged]
//!                    [--objective shortest|bottleneck|minimax|reachability]
//!                    [--paths --src A --dst B] [--update "u,v,w[;u,v,w…]"]
//!                    [--trace] [--binary] [--deadline-ms MS]
//! fw-stage gen       --model er|grid|scale-free|geometric|ring|dag --n N --out g.gr
//! fw-stage simulate  --table1 | --fig7 [--csv] | --analysis | --ablation [--n N] | --accuracy
//! fw-stage bench-tasks [--variant staged] [--n 512] [--iters 5] [--artifacts DIR]
//! fw-stage info      [--artifacts DIR]
//! fw-stage kernel
//! ```
//!
//! Every subcommand honours `FW_KERNEL=scalar|avx2|avx512|neon`, which
//! pins the min-plus microkernel's SIMD ISA (validated at startup — an
//! ISA the host cannot execute is a clean error, never an illegal
//! instruction).  `kernel` prints the resolved dispatch for this host.
//!
//! `--paths` asks the coordinator for successor tracking; with `--src`/
//! `--dst` the reconstructed hop sequence and its cost are printed instead
//! of the distance matrix.
//!
//! `--update` applies an edge-delta batch to the *cached closure* of the
//! input graph (the dynamic-graph tier): semicolon-separated `src,dst,w`
//! triples, `w = inf` deletes the edge.  `solve` primes the cache with the
//! base closure and then updates it; `client` sends only the deltas plus
//! the base fingerprint, falling back to a full solve of the mutated graph
//! when the server has no cached base.
//!
//! `--objective` selects the closed semiring the closure is taken over:
//! `shortest` (min, +; the default), `bottleneck` (max, min — widest
//! path), `minimax` (min, max — smallest maximum edge), or `reachability`
//! (or, and — transitive closure).  The dynamic tier (`--update`) and the
//! johnson variant are shortest-only.
//!
//! Serving limits: `serve --workers` fixes the solve worker-pool width
//! (0 = one per core), `--queue-depth` bounds the request queue feeding
//! it (overflow is shed with a typed `code:"shed"` error), and
//! `--deadline-ms` sets the default per-request deadline (0 disables;
//! requests override it with the wire `"deadline_ms"` field, and
//! `client --deadline-ms` sends exactly that).  `--idle-timeout-ms`
//! closes connections that send nothing, with a typed
//! `code:"idle_timeout"` line.  `client --binary` negotiates the
//! length-prefixed binary matrix frame for the reply instead of
//! line-JSON (bitwise-identical distances, raw little-endian rows).
//!
//! Persistence: `--store-dir` points the coordinator at a content-
//! addressed on-disk closure store (DESIGN.md §Closure store).  Every
//! solved closure is persisted asynchronously (checksummed, written via
//! temp-file + rename) and the cache warm-starts from the store at boot,
//! so a restarted server answers previously solved graphs from disk —
//! bitwise identical, no re-solve.  `--store-max-bytes` bounds the
//! directory (oldest entries evicted; 0 = unbounded).  Corrupt entries
//! are quarantined and re-solved, never served.  `solve` and
//! `bench-tasks` accept the same flags (shared coordinator config).
//!
//! Observability: `serve --log-level` sets the structured-stderr-log
//! threshold (default `warn`) and `--trace-journal K` sizes the in-memory
//! trace ring (0 disables journaling).  `client --trace` asks the server
//! to echo the request's span tree, printed to stderr alongside the
//! summary line; `{"type":"trace"}` / `{"type":"exposition"}` wire
//! requests serve the journal and Prometheus-style metrics text.

pub mod args;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::apsp::incremental::{self, EdgeUpdate};
use crate::apsp::paths::PathsResult;
use crate::coordinator::{self, Coordinator};
use crate::graph::{generators, io, DistMatrix};
use crate::simulator::{self, table, Variant};
use crate::util::stats::Samples;
use args::Args;

const USAGE: &str = "fw-stage — staged blocked Floyd-Warshall serving stack

USAGE:
  fw-stage <subcommand> [flags]

SUBCOMMANDS:
  solve        solve APSP for a graph file (local engine)
  serve        run the TCP coordinator
  client       send a graph to a running server
  gen          generate a workload graph
  simulate     regenerate the paper's Table 1 / Fig 7 / §5 analysis
  bench-tasks  measure tasks/sec through the local engine
  info         describe available artifacts
  kernel       show the SIMD kernel dispatch for this host (FW_KERNEL)
  help         show this message
";

/// CLI entrypoint; returns the process exit code.
pub fn run(raw_args: Vec<String>) -> i32 {
    match dispatch(raw_args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn dispatch(raw: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = raw.split_first() else {
        print!("{USAGE}");
        return Ok(());
    };
    // validate FW_KERNEL before any subcommand runs a kernel: an override
    // naming an ISA this host can't execute must die here with a typed
    // error, not later with an illegal-instruction fault mid-solve
    crate::apsp::simd::init_from_env().map_err(anyhow::Error::msg)?;
    match cmd.as_str() {
        "solve" => cmd_solve(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "gen" => cmd_gen(rest),
        "simulate" => cmd_simulate(rest),
        "bench-tasks" => cmd_bench_tasks(rest),
        "info" => cmd_info(rest),
        "kernel" => cmd_kernel(rest),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn default_artifacts() -> PathBuf {
    // `artifacts/` next to the working directory, or `rust/artifacts/`
    // when launched from the repository root
    crate::runtime::artifact::discover_dir()
}

fn start_coordinator(args: &Args) -> Result<Coordinator> {
    let dir = PathBuf::from(args.get_or("artifacts", default_artifacts().to_str().unwrap()));
    let mut config = coordinator::Config::new(&dir);
    config.cache_capacity = args.get_usize("cache", 128)?;
    config.engine.batch_window =
        std::time::Duration::from_millis(args.get_u64("batch-window-ms", 2)?);
    config.router.cpu_threshold = args.get_usize("cpu-threshold", 32)?;
    // superblock tier: explicit super-tile size (must be a lowered bucket)
    // and pool width; 0 = auto for both
    let sb_bucket = args.get_usize("superblock-bucket", 0)?;
    if sb_bucket > 0 {
        config.router.superblock_bucket = Some(sb_bucket);
    }
    config.superblock_workers = args.get_usize("superblock-workers", 0)?;
    config.update_max_chain = args.get_usize("update-max-chain", 8)? as u32;
    config.obs.journal_capacity = args.get_usize(
        "trace-journal",
        crate::obs::ObsConfig::default().journal_capacity,
    )?;
    // persistent closure store: solved closures survive restarts
    match args.get("store-dir") {
        Some(dir) => {
            config.store = Some(coordinator::store::StoreConfig {
                dir: PathBuf::from(dir),
                max_bytes: args.get_u64("store-max-bytes", 0)?,
            });
        }
        None => {
            if args.get("store-max-bytes").is_some() {
                bail!("--store-max-bytes requires --store-dir");
            }
        }
    }
    Coordinator::start(config)
}

/// Parse `--update "src,dst,w[;src,dst,w…]"` (`w = inf` deletes the edge).
fn parse_updates(spec: &str) -> Result<Vec<EdgeUpdate>> {
    let mut out = Vec::new();
    for (i, triple) in spec.split(';').enumerate() {
        let triple = triple.trim();
        if triple.is_empty() {
            continue;
        }
        let parts: Vec<&str> = triple.split(',').map(str::trim).collect();
        if parts.len() != 3 {
            bail!("--update triple #{i} {triple:?} must be src,dst,w");
        }
        let src: usize = parts[0]
            .parse()
            .with_context(|| format!("--update triple #{i}: bad src {:?}", parts[0]))?;
        let dst: usize = parts[1]
            .parse()
            .with_context(|| format!("--update triple #{i}: bad dst {:?}", parts[1]))?;
        let weight: f32 = if parts[2].eq_ignore_ascii_case("inf") {
            f32::INFINITY
        } else {
            parts[2]
                .parse()
                .with_context(|| format!("--update triple #{i}: bad weight {:?}", parts[2]))?
        };
        out.push(EdgeUpdate { src, dst, weight });
    }
    if out.is_empty() {
        bail!("--update spec {spec:?} contains no src,dst,w triples");
    }
    Ok(out)
}

fn cmd_solve(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["quiet", "paths"])?;
    let input = args.get("input").context("--input <graph file> required")?;
    let variant = args.get_or("variant", "staged").to_string();
    let output = args.get("output").map(PathBuf::from);
    let quiet = args.get_bool("quiet");
    let want_paths = args.get_bool("paths");
    let src = args.get_usize("src", 0)?;
    let dst = args.get_usize("dst", 0)?;
    let update_spec = args.get("update").map(str::to_string);
    let objective = args.get_or("objective", "shortest").to_string();
    let _ = args.get("artifacts");
    let _ = args.get("cache");
    let _ = args.get("batch-window-ms");
    let _ = args.get("cpu-threshold");
    let _ = args.get("superblock-bucket");
    let _ = args.get("superblock-workers");
    let _ = args.get("update-max-chain");
    let _ = args.get("trace-journal");
    let _ = args.get("store-dir");
    let _ = args.get("store-max-bytes");
    args.reject_unknown()?;
    if update_spec.is_some() && objective != "shortest" {
        bail!("--update serves the shortest objective only (got --objective {objective})");
    }

    let graph = io::load(Path::new(input))?;
    let coord = start_coordinator(&args)?;
    // with --update, `graph` is the *base*: prime the cache with its
    // closure (outside the timed window — the headline number must be the
    // update's own cost, not the from-scratch solve's), then apply the
    // delta batch through the incremental tier; path costs reconstruct
    // against the mutated graph
    let prepared = match &update_spec {
        None => None,
        Some(spec) => {
            let updates = parse_updates(spec)?;
            let mutated = incremental::mutated(&graph, &updates)
                .map_err(|e| anyhow::anyhow!("invalid --update batch: {e}"))?;
            coord.solve(&coordinator::Request {
                id: 1,
                graph: graph.clone(),
                variant: variant.clone(),
                no_cache: false,
                want_paths: true, // successor-carrying base keeps increases incremental
                objective: "shortest".into(),
                trace: false,
            })?;
            Some((updates, mutated))
        }
    };
    let t0 = std::time::Instant::now();
    let (resp, effective_graph) = match prepared {
        None => {
            let resp = coord.solve(&coordinator::Request {
                id: 1,
                graph: graph.clone(),
                variant,
                no_cache: false,
                want_paths,
                objective: objective.clone(),
                trace: false,
            })?;
            (resp, graph.clone())
        }
        Some((updates, mutated)) => {
            let outcome = coord.update(&coordinator::UpdateRequest {
                id: 2,
                variant,
                n: graph.n(),
                base_fingerprint: coordinator::cache::graph_fingerprint(&graph),
                updates,
                want_paths,
                objective: "shortest".into(),
            })?;
            match outcome {
                coordinator::UpdateOutcome::Solved(resp) => (resp, mutated),
                coordinator::UpdateOutcome::BaseMissing { fingerprint } => bail!(
                    "internal: base closure {fingerprint:016x} vanished from the cache \
                     (is --cache 0?)"
                ),
            }
        }
    };
    let dt = t0.elapsed().as_secs_f64();
    if !quiet {
        let n = graph.n() as f64;
        eprintln!(
            "solved n={} via {} (bucket {}) in {:.4}s ({:.3e} tasks/s)",
            graph.n(),
            resp.source.name(),
            resp.bucket,
            dt,
            n * n * n / dt,
        );
    }
    if want_paths {
        let succ = resp.succ.context("response is missing successors")?;
        print_path(&effective_graph, resp.dist.clone(), succ, src, dst, &objective)?;
        if let Some(path) = &output {
            io::save(&resp.dist, path)?;
        }
        return Ok(());
    }
    match output {
        Some(path) => io::save(&resp.dist, &path)?,
        None => print!("{}", io::to_matrix_text(&resp.dist)),
    }
    Ok(())
}

/// Reconstruct and print one (src, dst) path from a succ-carrying response.
fn print_path(
    graph: &DistMatrix,
    dist: DistMatrix,
    succ: Vec<usize>,
    src: usize,
    dst: usize,
    objective: &str,
) -> Result<()> {
    let n = graph.n();
    if src >= n || dst >= n {
        bail!("--src/--dst must be < n={n} (got {src}, {dst})");
    }
    let r = PathsResult::from_parts(dist, succ);
    match r.path(src, dst) {
        Some(p) => {
            let hops: Vec<String> = p.iter().map(|v| v.to_string()).collect();
            if objective == "shortest" {
                let cost = r
                    .path_weight(graph, src, dst)
                    .context("reconstructed path uses a non-edge")?;
                println!("path {src} -> {dst}: {} (cost {cost:.2})", hops.join(" -> "));
            } else {
                // non-(min,+) path values do not sum along raw edge
                // weights; report the semiring value the solver computed
                let value = r.dist.get(src, dst);
                println!(
                    "path {src} -> {dst}: {} ({objective} {value:.2})",
                    hops.join(" -> ")
                );
            }
        }
        None => println!("path {src} -> {dst}: unreachable"),
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &[])?;
    let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
    let log_level = args.get_or("log-level", "warn").to_string();
    let defaults = coordinator::server::ServerConfig::default();
    let max_connections = args.get_usize("max-connections", defaults.max_connections)?;
    let workers = args.get_usize("workers", defaults.workers)?;
    let queue_depth = args.get_usize("queue-depth", defaults.queue_depth)?;
    let deadline_ms = args.get_u64("deadline-ms", defaults.deadline_ms)?;
    let idle_timeout_ms = args.get_u64("idle-timeout-ms", defaults.idle_timeout_ms)?;
    let _ = args.get("artifacts");
    let _ = args.get("cache");
    let _ = args.get("batch-window-ms");
    let _ = args.get("cpu-threshold");
    let _ = args.get("superblock-bucket");
    let _ = args.get("superblock-workers");
    let _ = args.get("update-max-chain");
    let _ = args.get("trace-journal");
    let _ = args.get("store-dir");
    let _ = args.get("store-max-bytes");
    args.reject_unknown()?;
    let level = crate::obs::log::Level::parse(&log_level)
        .with_context(|| format!("--log-level {log_level:?} (error, warn, info, debug)"))?;
    crate::obs::log::set_level(level);

    if max_connections == 0 {
        bail!("--max-connections must be at least 1");
    }
    if queue_depth == 0 {
        bail!("--queue-depth must be at least 1 (admission needs somewhere to admit)");
    }
    let coord = Arc::new(start_coordinator(&args)?);
    let store_banner = match coord.store() {
        Some(store) => format!("; store: {}", store.dir().display()),
        None => String::new(),
    };
    let summary = coord.manifest_summary().clone();
    let server = coordinator::server::Server::spawn_with(
        coord,
        &addr,
        coordinator::server::ServerConfig {
            max_connections,
            workers,
            queue_depth,
            deadline_ms,
            idle_timeout_ms,
        },
    )?;
    eprintln!(
        "fw-stage serving on {} (variants: {}; buckets: {:?}; kernel: {}; max-connections: {}; \
         workers: {}; queue-depth: {}; deadline-ms: {}{})",
        server.addr(),
        summary.variants.join(", "),
        summary.buckets,
        crate::apsp::simd::active().name(),
        max_connections,
        server.workers(),
        server.queue_depth(),
        deadline_ms,
        store_banner,
    );
    // serve until killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["stats", "paths", "trace", "binary"])?;
    let addr = args.get("addr").context("--addr HOST:PORT required")?;
    let want_stats = args.get_bool("stats");
    let want_paths = args.get_bool("paths");
    let want_trace = args.get_bool("trace");
    let want_binary = args.get_bool("binary");
    let src = args.get_usize("src", 0)?;
    let dst = args.get_usize("dst", 0)?;
    let input = args.get("input").map(str::to_string);
    let variant = args.get_or("variant", "staged").to_string();
    let output = args.get("output").map(PathBuf::from);
    let update_spec = args.get("update").map(str::to_string);
    let objective = args.get_or("objective", "shortest").to_string();
    let deadline_ms = match args.get("deadline-ms") {
        Some(s) => Some(
            s.parse::<u64>()
                .with_context(|| format!("--deadline-ms {s:?} is not a millisecond count"))?,
        ),
        None => None,
    };
    args.reject_unknown()?;
    if update_spec.is_some() && objective != "shortest" {
        bail!("--update serves the shortest objective only (got --objective {objective})");
    }
    if want_trace && (want_paths || update_spec.is_some() || objective != "shortest") {
        bail!("--trace traces a plain solve (no --paths/--update/--objective)");
    }
    if want_binary && want_trace {
        bail!("--binary replies have no rendering for the --trace echo; pick one");
    }
    if want_binary && update_spec.is_some() {
        bail!("--binary applies to solve replies (updates stay line-JSON)");
    }
    if want_binary && want_paths && objective != "shortest" {
        bail!("--binary --paths serves the shortest objective only");
    }

    let mut client = coordinator::client::Client::connect(addr)?;
    client.set_deadline_ms(deadline_ms);
    if want_stats {
        println!("{}", client.stats()?);
        return Ok(());
    }
    let input = input.context("--input <graph file> required (or --stats)")?;
    let graph = io::load(Path::new(&input))?;
    let (resp, effective_graph) = match &update_spec {
        None if want_trace => {
            // traced solve: the result line carries the request's span
            // tree, echoed here for the operator
            let (resp, trace) = client.solve_traced(&graph, &variant)?;
            eprintln!("trace: {trace}");
            (resp, graph.clone())
        }
        None => {
            let resp = match (want_binary, want_paths) {
                (true, true) => client.solve_paths_binary(&graph, &variant)?,
                (true, false) => client.solve_binary_objective(&graph, &variant, &objective)?,
                (false, true) => client.solve_paths_objective(&graph, &variant, &objective)?,
                (false, false) => client.solve_objective(&graph, &variant, &objective)?,
            };
            (resp, graph.clone())
        }
        Some(spec) => {
            // only the deltas + the base fingerprint travel; on a server
            // cache miss the client re-sends the mutated graph in full
            let updates = parse_updates(spec)?;
            let mutated = incremental::mutated(&graph, &updates)
                .map_err(|e| anyhow::anyhow!("invalid --update batch: {e}"))?;
            let resp = client.update_or_solve(&graph, &updates, &variant, want_paths)?;
            (resp, mutated)
        }
    };
    eprintln!(
        "server solved n={} via {} (bucket {}) in {:.4}s",
        graph.n(),
        resp.source.name(),
        resp.bucket,
        resp.seconds
    );
    if want_paths {
        let succ = resp.succ.context("server response is missing successors")?;
        print_path(&effective_graph, resp.dist.clone(), succ, src, dst, &objective)?;
        if let Some(path) = &output {
            io::save(&resp.dist, path)?;
        }
        return Ok(());
    }
    match output {
        Some(path) => io::save(&resp.dist, &path)?,
        None => print!("{}", io::to_matrix_text(&resp.dist)),
    }
    Ok(())
}

fn cmd_gen(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &[])?;
    let model = args.get_or("model", "er").to_string();
    let n = args.get_usize("n", 256)?;
    let seed = args.get_u64("seed", 42)?;
    let p = args.get_f64("p", 0.3)?;
    let out = args.get("out").map(PathBuf::from);
    args.reject_unknown()?;

    let g = match model.as_str() {
        "er" | "erdos-renyi" => generators::erdos_renyi(n, p, seed),
        "grid" => {
            let side = (n as f64).sqrt().round().max(2.0) as usize;
            generators::grid(side, seed)
        }
        "scale-free" | "sf" => generators::scale_free(n, 2, seed),
        "geometric" | "geo" => generators::geometric(n, 0.3, seed),
        "ring" => generators::ring(n),
        "dag" => generators::layered_dag(n.div_ceil(16).max(2), 16, seed),
        other => bail!("unknown model {other:?} (er, grid, scale-free, geometric, ring, dag)"),
    };
    eprintln!("generated {} with n={} edges={}", model, g.n(), g.edge_count());
    match out {
        Some(path) => io::save(&g, &path)?,
        None => print!("{}", io::to_edge_list(&g)),
    }
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> Result<()> {
    let args = Args::parse(
        rest,
        &["table1", "fig7", "csv", "analysis", "ablation", "accuracy"],
    )?;
    let n = args.get_usize("n", 16384)?;
    let any = args.get_bool("table1") as u8
        + args.get_bool("fig7") as u8
        + args.get_bool("analysis") as u8
        + args.get_bool("ablation") as u8
        + args.get_bool("accuracy") as u8;
    let csv = args.get_bool("csv");
    args.reject_unknown()?;

    if any == 0 || args.get_bool("table1") {
        print!("{}", table::render_table1());
        println!();
    }
    if args.get_bool("fig7") {
        if csv {
            print!("{}", table::fig7_csv());
        } else {
            print!("{}", table::render_table1());
        }
    }
    if any == 0 || args.get_bool("analysis") {
        print!("{}", table::render_analysis());
        println!();
    }
    if any == 0 || args.get_bool("ablation") {
        print!("{}", table::render_ablation(n));
    }
    if args.get_bool("accuracy") {
        println!("simulator accuracy vs paper (relative error per cell):");
        for (n, name, sim, paper, err) in table::accuracy_report() {
            println!(
                "  n={n:<6} {name:<20} sim {sim:>10.4}  paper {paper:>10.4}  {:+6.1}%",
                err * 100.0
            );
        }
    }
    Ok(())
}

fn cmd_bench_tasks(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &[])?;
    let variant = args.get_or("variant", "staged").to_string();
    let n = args.get_usize("n", 512)?;
    let iters = args.get_usize("iters", 5)?;
    let _ = args.get("artifacts");
    let _ = args.get("cache");
    let _ = args.get("batch-window-ms");
    let _ = args.get("cpu-threshold");
    let _ = args.get("superblock-bucket");
    let _ = args.get("superblock-workers");
    let _ = args.get("update-max-chain");
    let _ = args.get("trace-journal");
    let _ = args.get("store-dir");
    let _ = args.get("store-max-bytes");
    args.reject_unknown()?;

    let coord = start_coordinator(&args)?;
    let g = generators::erdos_renyi(n, 0.3, 7);
    // warm (compile + first run)
    coord.solve_graph(&g, &variant)?;
    let mut samples = Samples::new();
    for i in 0..iters {
        let g = generators::erdos_renyi(n, 0.3, 100 + i as u64);
        let t0 = std::time::Instant::now();
        coord
            .solve(&coordinator::Request {
                id: i as u64,
                graph: g,
                variant: variant.clone(),
                no_cache: true,
                want_paths: false,
                objective: "shortest".into(),
                trace: false,
            })
            .context("bench solve")?;
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n3 = (n as f64).powi(3);
    println!(
        "variant={variant} n={n}: {}  → {:.3e} tasks/s (median)",
        samples.summary("s"),
        n3 / samples.median(),
    );
    // put the analogous simulated C1060 figure next to it for context
    if let Some(v) = Variant::from_str(&variant) {
        if n % 32 == 0 {
            let sim = simulator::simulate(v, n);
            println!(
                "  (simulated C1060 {}: {:.3e} tasks/s)",
                v.name(),
                sim.tasks_per_sec
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_updates_triples() {
        let ups = parse_updates("0,1,2.5; 3,4,inf").unwrap();
        assert_eq!(ups.len(), 2);
        assert_eq!((ups[0].src, ups[0].dst, ups[0].weight), (0, 1, 2.5));
        assert_eq!((ups[1].src, ups[1].dst), (3, 4));
        assert!(ups[1].weight.is_infinite());
        // trailing separators tolerated; empty/garbage rejected
        assert_eq!(parse_updates("5,6,0.25;").unwrap().len(), 1);
        assert!(parse_updates("").is_err());
        assert!(parse_updates("1,2").is_err());
        assert!(parse_updates("a,2,3").is_err());
    }
}

/// `fw-stage kernel` — report the SIMD microkernel dispatch for this host.
/// Machine-greppable (`sed -n 's/^active: //p'`): CI uses it to fail the
/// build when dispatch silently resolves to scalar on a vector-capable
/// runner.
fn cmd_kernel(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &[])?;
    args.reject_unknown()?;
    let active = crate::apsp::simd::active();
    println!("active: {}", active.name());
    println!("lanes: {}", active.lanes());
    println!("available: {}", crate::apsp::simd::available_names());
    match std::env::var(crate::apsp::simd::ENV_KERNEL) {
        Ok(v) if !v.is_empty() => println!("override: {v}"),
        _ => println!("override: none"),
    }
    Ok(())
}

fn cmd_info(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &[])?;
    let dir = match args.get("artifacts") {
        Some(d) => PathBuf::from(d),
        None => default_artifacts(),
    };
    args.reject_unknown()?;
    let manifest = crate::runtime::Manifest::load(&dir)?;
    manifest.check_files()?;
    println!("artifact dir: {}", manifest.dir().display());
    println!("tile: {}", manifest.tile);
    for variant in manifest.variants() {
        println!("  {variant}: sizes {:?}", manifest.sizes_for(&variant));
    }
    println!("total artifacts: {}", manifest.entries.len());
    Ok(())
}

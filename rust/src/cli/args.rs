//! Tiny flag parser (the vendored crate set has no `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positionals.  Unknown flags are an error (catches typos in scripts).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: positionals plus flag → value (bool flags map to "").
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags consumed by `get_*` calls (for unknown-flag detection).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse raw arguments. `bool_flags` lists flags that take no value.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&stripped) {
                    args.flags.insert(stripped.to_string(), String::new());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("flag --{stripped} needs a value"))?;
                    args.flags.insert(stripped.to_string(), v.clone());
                }
            } else {
                args.positionals.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().push(key.to_string());
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Call after all `get_*`s: errors if the user passed unknown flags.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for key in self.flags.keys() {
            if !seen.iter().any(|s| s == key) {
                bail!("unknown flag --{key}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_values_and_positionals() {
        let a = Args::parse(&raw("solve --n 64 --variant=staged file.gr"), &[]).unwrap();
        assert_eq!(a.positionals, vec!["solve", "file.gr"]);
        assert_eq!(a.get("n"), Some("64"));
        assert_eq!(a.get("variant"), Some("staged"));
    }

    #[test]
    fn bool_flags_take_no_value() {
        let a = Args::parse(&raw("--csv --n 4"), &["csv"]).unwrap();
        assert!(a.get_bool("csv"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 4);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&raw("--n"), &[]).is_err());
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&raw("--x 1.5 --y 7"), &[]).unwrap();
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_usize("y", 0).unwrap(), 7);
        assert_eq!(a.get_usize("z", 9).unwrap(), 9);
        assert!(a.get_usize("x", 0).is_err()); // 1.5 is not an integer
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = Args::parse(&raw("--known 1 --oops 2"), &[]).unwrap();
        let _ = a.get("known");
        assert!(a.reject_unknown().is_err());
        let _ = a.get("oops");
        assert!(a.reject_unknown().is_ok());
    }
}

//! Super-blocked APSP engine: the paper's three-phase schedule, one level
//! up the memory hierarchy.
//!
//! The device tier solves graphs up to the largest AOT artifact bucket
//! (shared memory, in the paper's terms).  This tier serves **arbitrary n**
//! by decomposing the n×n request into `blocks × blocks` super-tiles of
//! device-bucket size `b` and running blocked Floyd-Warshall over the
//! super-grid — exactly the recursion the blocked decomposition admits
//! (Rucci et al. on Xeon Phi, RAPID-Graph; see PAPERS.md):
//!
//! ```text
//!  round k of `blocks`:
//!    phase 1   diagonal super-tile (k,k)  → existing device engine
//!                                           (or CPU blocked solver)
//!    phase 2   row panel (k,·), col panel (·,k)  → worker pool
//!    phase 3   interior (i,j), i≠k, j≠k   → worker pool, each tile
//!              released the moment ITS two panels resolve
//! ```
//!
//! * [`schedule`] — pure round plans with dependency edges
//! * [`minplus`] — the tiled phase-2/3 primitives: named for the paper's
//!   (min, +) algebra, generic over any [`crate::apsp::semiring::Semiring`]
//! * [`pool`] — the dependency-driven worker pool
//! * [`progress`] — per-round accounting for the serving metrics
//!
//! **Exactness.** The primitives mirror `apsp::blocked` line for line and
//! every tile update reads only finalized inputs, so when the diagonal
//! solver applies phase-1 order ([`solve_cpu`]) the result is *bitwise*
//! equal to `apsp::blocked::solve(padded, bucket)` — regardless of pool
//! width.  Tests pin this.
//!
//! **Path mode.** [`solve_paths`] runs the same schedule with a successor
//! tile carried alongside every distance tile (successors are global
//! vertex ids, so detached tiles copy them freely); distances stay bitwise
//! equal to [`solve_cpu`] while the successor matrix reconstructs real
//! shortest paths (DESIGN.md §Path tier).

pub mod minplus;
pub mod pool;
pub mod progress;
pub mod schedule;

use std::sync::RwLock;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::apsp::paths::{self, PathsResult, NO_PATH};
use crate::apsp::semiring::{
    padded_semiring, BoolOrAnd, MaxMin, MinMax, MinPlus, Objective, Semiring,
};
use crate::graph::DistMatrix;
pub use progress::Report;
use schedule::TileOp;

/// Superblock tier configuration.
#[derive(Clone, Copy, Debug)]
pub struct SuperBlockConfig {
    /// Super-tile size — must match a device artifact bucket when the
    /// diagonal solver is the device engine.
    pub bucket: usize,
    /// Phase-2/3 pool width; 0 = one worker per available core.
    pub workers: usize,
    /// Record per-round worker occupancy and critical-path accounting
    /// into the [`Report`] (via [`pool::run_tasks_profiled`]).  Timing
    /// reads happen around tile bodies, never inside them, so results are
    /// bitwise-identical either way; off keeps the pool measurement-free.
    pub profile: bool,
}

impl SuperBlockConfig {
    pub fn new(bucket: usize) -> SuperBlockConfig {
        SuperBlockConfig {
            bucket,
            workers: 0,
            profile: false,
        }
    }

    /// The pool width actually used (resolves `workers == 0`).
    pub fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
        } else {
            self.workers
        }
    }
}

/// Solve APSP for a graph of any size with the super-blocked schedule.
///
/// `diag_solver` computes the closure of one `bucket × bucket` diagonal
/// tile (the coordinator passes the device engine; [`solve_cpu`] passes the
/// CPU blocked solver).  Returns the distance closure plus the per-round
/// [`Report`].
pub fn solve_with<F>(
    graph: &DistMatrix,
    config: &SuperBlockConfig,
    diag_solver: F,
) -> Result<(DistMatrix, Report)>
where
    F: FnMut(DistMatrix) -> Result<DistMatrix>,
{
    solve_with_semiring::<MinPlus, F>(graph, config, diag_solver)
}

/// Generic super-blocked solve over any [`Semiring`] — the driver behind
/// [`solve_with`].  Expects the graph in the semiring's domain; padding
/// uses the semiring's `ZERO`/`ONE` so padded vertices stay unreachable
/// under any `⊕`/`⊗`.
pub fn solve_with_semiring<S: Semiring, F>(
    graph: &DistMatrix,
    config: &SuperBlockConfig,
    mut diag_solver: F,
) -> Result<(DistMatrix, Report)>
where
    F: FnMut(DistMatrix) -> Result<DistMatrix>,
{
    let n = graph.n();
    let b = config.bucket;
    ensure!(b > 0, "superblock bucket must be positive");
    let workers = config.effective_workers();
    if n == 0 {
        return Ok((graph.clone(), Report::new(0, 0, b, 0, workers)));
    }
    let blocks = n.div_ceil(b);
    let padded_n = blocks * b;
    let padded = if padded_n == n {
        graph.clone()
    } else {
        padded_semiring::<S>(graph, padded_n)
    };

    let tiles = split_tiles(&padded, blocks, b);
    let mut report = Report::new(n, padded_n, b, blocks, workers);

    for k in 0..blocks {
        // ---- phase 1: diagonal super-tile through the pluggable solver
        let t0 = Instant::now();
        let diag_idx = k * blocks + k;
        let diag_in = DistMatrix::from_vec(b, tiles[diag_idx].read().unwrap().clone());
        let solved = diag_solver(diag_in)?;
        ensure!(
            solved.n() == b,
            "diagonal solver returned n={}, expected bucket {b}",
            solved.n()
        );
        let diag = solved.into_vec();
        tiles[diag_idx].write().unwrap().copy_from_slice(&diag);
        let diag_seconds = t0.elapsed().as_secs_f64();

        // ---- phases 2 + 3: stream tiles through the pool as deps resolve
        let t1 = Instant::now();
        let plan = schedule::round_plan(blocks, k);
        // degenerate grids (e.g. 2×2: one interior tile per round) would
        // leave most workers idle, so split interior rows across the spare
        // width — divided by the interior count so concurrent tile tasks
        // never oversubscribe the pool
        let intra_threads = match plan.interior_tiles() {
            n_int if n_int > 0 && n_int < workers => (workers / n_int).max(1),
            _ => 1,
        };
        let exec = |id: usize| match plan.tasks[id].op {
            TileOp::PanelRow { bj } => {
                let mut tile = tiles[k * blocks + bj].write().unwrap();
                minplus::panel_row_semiring::<S>(&mut tile, &diag, b);
            }
            TileOp::PanelCol { bi } => {
                let mut tile = tiles[bi * blocks + k].write().unwrap();
                minplus::panel_col_semiring::<S>(&mut tile, &diag, b);
            }
            TileOp::Interior { bi, bj } => {
                let col = tiles[bi * blocks + k].read().unwrap();
                let row = tiles[k * blocks + bj].read().unwrap();
                let mut tile = tiles[bi * blocks + bj].write().unwrap();
                if intra_threads > 1 {
                    minplus::interior_parallel_semiring::<S>(
                        &mut tile,
                        &col,
                        &row,
                        b,
                        intra_threads,
                    );
                } else {
                    minplus::interior_semiring::<S>(&mut tile, &col, &row, b);
                }
            }
        };
        let deps = plan.dep_graph();
        let (busy_seconds, idle_seconds, critical_path) = if config.profile {
            let prof = pool::run_tasks_profiled(&deps, workers, &exec);
            (prof.busy_total(), prof.idle_total(), prof.critical_path)
        } else {
            pool::run_tasks(&deps, workers, &exec);
            (0.0, 0.0, 0)
        };
        report.rounds.push(progress::RoundStats {
            round: k,
            diag_seconds,
            tile_seconds: t1.elapsed().as_secs_f64(),
            panel_tiles: plan.panel_tiles(),
            interior_tiles: plan.interior_tiles(),
            busy_seconds,
            idle_seconds,
            critical_path,
        });
    }

    let mut out = join_tiles(&tiles, blocks, b);
    if padded_n != n {
        out = out.truncated(n);
    }
    Ok((out, report))
}

/// Superblock solve with the CPU phase-1 kernel as the diagonal tier.
///
/// The diagonal tile is solved in phase-1 order ([`minplus::phase1`], the
/// detached mirror of `apsp::blocked::phase1_diag`), which makes the whole
/// solve bitwise equal to `apsp::blocked::solve(padded, bucket)` — the
/// exactness oracle the tests and benches lean on.  Infallible: the CPU
/// kernel cannot fail.
pub fn solve_cpu(graph: &DistMatrix, config: &SuperBlockConfig) -> (DistMatrix, Report) {
    solve_cpu_semiring::<MinPlus>(graph, config)
}

/// Generic CPU-diagonal super-blocked solve — [`solve_cpu`] for any
/// [`Semiring`].  Same exactness contract against
/// `apsp::blocked::solve_semiring::<S>(padded, bucket)`: the phase
/// primitives perform identical `⊕`/`⊗` applications in identical order.
pub fn solve_cpu_semiring<S: Semiring>(
    graph: &DistMatrix,
    config: &SuperBlockConfig,
) -> (DistMatrix, Report) {
    solve_with_semiring::<S, _>(graph, config, |mut tile| {
        let s = tile.n();
        minplus::phase1_semiring::<S>(tile.as_mut_slice(), s);
        Ok(tile)
    })
    .expect("CPU diagonal solver is infallible")
}

/// Super-blocked CPU solve dispatched by serving objective.  Expects the
/// graph already in the objective's domain ([`Objective::prepare`]).  The
/// coordinator's super-block arm uses this for non-shortest objectives —
/// the AOT device artifacts are `(min, +)`-only, so other semirings never
/// loop diagonal tiles through the device engine.
pub fn solve_cpu_objective(
    objective: Objective,
    graph: &DistMatrix,
    config: &SuperBlockConfig,
) -> (DistMatrix, Report) {
    match objective {
        Objective::Shortest => solve_cpu_semiring::<MinPlus>(graph, config),
        Objective::Bottleneck => solve_cpu_semiring::<MaxMin>(graph, config),
        Objective::Minimax => solve_cpu_semiring::<MinMax>(graph, config),
        Objective::Reachability => solve_cpu_semiring::<BoolOrAnd>(graph, config),
    }
}

/// Super-blocked path mode dispatched by serving objective — the path-mode
/// twin of [`solve_cpu_objective`].
pub fn solve_paths_objective(
    objective: Objective,
    graph: &DistMatrix,
    config: &SuperBlockConfig,
) -> (PathsResult, Report) {
    match objective {
        Objective::Shortest => solve_paths_semiring::<MinPlus>(graph, config),
        Objective::Bottleneck => solve_paths_semiring::<MaxMin>(graph, config),
        Objective::Minimax => solve_paths_semiring::<MinMax>(graph, config),
        Objective::Reachability => solve_paths_semiring::<BoolOrAnd>(graph, config),
    }
}

/// One detached super-tile in path mode: distances plus the matching
/// successor tile.  Successor values are global vertex ids (assigned before
/// the split), so tiles can copy them between each other freely.
struct PathTile {
    dist: Vec<f32>,
    succ: Vec<usize>,
}

/// Super-blocked APSP with successor tracking: the same three-phase
/// schedule as [`solve_with`], with a successor tile carried alongside
/// every distance tile through the worker pool
/// ([`minplus::panel_row_succ`] / [`minplus::panel_col_succ`] /
/// [`minplus::interior_succ`]).
///
/// Diagonal tiles are solved by the CPU phase-1 kernel with successor
/// tracking ([`minplus::phase1_succ`]) — the AOT device artifacts compute
/// distances only, so path mode cannot loop diagonal tiles back through
/// the device engine.  Because `phase1_succ` applies phase-1 relaxation
/// order and every succ primitive performs the distance arithmetic of its
/// distance-only twin, the returned distances are **bitwise equal** to
/// [`solve_cpu`] (and hence to `apsp::blocked::solve(padded, bucket)`),
/// regardless of pool width.  Infallible: no pluggable solver is involved.
pub fn solve_paths(graph: &DistMatrix, config: &SuperBlockConfig) -> (PathsResult, Report) {
    solve_paths_semiring::<MinPlus>(graph, config)
}

/// Generic super-blocked path mode — [`solve_paths`] for any [`Semiring`].
/// Distances stay exactly equal to [`solve_cpu_semiring`]; successors use
/// the semiring's strict-accept `improves` predicate, so within this
/// schedule they are pool-width-independent.
pub fn solve_paths_semiring<S: Semiring>(
    graph: &DistMatrix,
    config: &SuperBlockConfig,
) -> (PathsResult, Report) {
    let n = graph.n();
    let b = config.bucket;
    assert!(b > 0, "superblock bucket must be positive");
    let workers = config.effective_workers();
    if n == 0 {
        return (
            PathsResult::from_parts(graph.clone(), Vec::new()),
            Report::new(0, 0, b, 0, workers),
        );
    }
    let blocks = n.div_ceil(b);
    let padded_n = blocks * b;
    let padded = if padded_n == n {
        graph.clone()
    } else {
        padded_semiring::<S>(graph, padded_n)
    };
    let full_succ = paths::init_succ_semiring::<S>(&padded);

    let tiles = split_path_tiles(&padded, &full_succ, blocks, b);
    let mut report = Report::new(n, padded_n, b, blocks, workers);

    for k in 0..blocks {
        // ---- phase 1: diagonal super-tile, CPU succ kernel in place
        let t0 = Instant::now();
        let diag_idx = k * blocks + k;
        let (diag, dsucc) = {
            let mut guard = tiles[diag_idx].write().unwrap();
            let tile = &mut *guard;
            minplus::phase1_succ_semiring::<S>(&mut tile.dist, &mut tile.succ, b);
            (tile.dist.clone(), tile.succ.clone())
        };
        let diag_seconds = t0.elapsed().as_secs_f64();

        // ---- phases 2 + 3: stream tiles through the pool as deps resolve
        let t1 = Instant::now();
        let plan = schedule::round_plan(blocks, k);
        // same degenerate-grid escape hatch as the distance tier: split
        // interior rows across spare pool width when there are fewer
        // interior tiles than workers
        let intra_threads = match plan.interior_tiles() {
            n_int if n_int > 0 && n_int < workers => (workers / n_int).max(1),
            _ => 1,
        };
        let exec = |id: usize| match plan.tasks[id].op {
            TileOp::PanelRow { bj } => {
                let mut guard = tiles[k * blocks + bj].write().unwrap();
                let tile = &mut *guard;
                minplus::panel_row_succ_semiring::<S>(
                    &mut tile.dist,
                    &mut tile.succ,
                    &diag,
                    &dsucc,
                    b,
                );
            }
            TileOp::PanelCol { bi } => {
                let mut guard = tiles[bi * blocks + k].write().unwrap();
                let tile = &mut *guard;
                minplus::panel_col_succ_semiring::<S>(&mut tile.dist, &mut tile.succ, &diag, b);
            }
            TileOp::Interior { bi, bj } => {
                let col = tiles[bi * blocks + k].read().unwrap();
                let row = tiles[k * blocks + bj].read().unwrap();
                let mut guard = tiles[bi * blocks + bj].write().unwrap();
                let tile = &mut *guard;
                minplus::interior_succ_parallel_semiring::<S>(
                    &mut tile.dist,
                    &mut tile.succ,
                    &col.dist,
                    &col.succ,
                    &row.dist,
                    b,
                    intra_threads,
                );
            }
        };
        let deps = plan.dep_graph();
        let (busy_seconds, idle_seconds, critical_path) = if config.profile {
            let prof = pool::run_tasks_profiled(&deps, workers, &exec);
            (prof.busy_total(), prof.idle_total(), prof.critical_path)
        } else {
            pool::run_tasks(&deps, workers, &exec);
            (0.0, 0.0, 0)
        };
        report.rounds.push(progress::RoundStats {
            round: k,
            diag_seconds,
            tile_seconds: t1.elapsed().as_secs_f64(),
            panel_tiles: plan.panel_tiles(),
            interior_tiles: plan.interior_tiles(),
            busy_seconds,
            idle_seconds,
            critical_path,
        });
    }

    let (dist, succ) = join_path_tiles(&tiles, blocks, b);
    let mut result = PathsResult::from_parts(dist, succ);
    if padded_n != n {
        // padded vertices are unreachable, so the corner is self-contained
        result = result.truncated(n);
    }
    (result, report)
}

/// Cut the padded matrix + successor matrix into detached path tiles.
fn split_path_tiles(
    w: &DistMatrix,
    full_succ: &[usize],
    blocks: usize,
    b: usize,
) -> Vec<RwLock<PathTile>> {
    let m = w.n();
    debug_assert_eq!(m, blocks * b);
    debug_assert_eq!(full_succ.len(), m * m);
    let mut tiles = Vec::with_capacity(blocks * blocks);
    for bi in 0..blocks {
        for bj in 0..blocks {
            let mut dist = Vec::with_capacity(b * b);
            let mut succ = Vec::with_capacity(b * b);
            for i in 0..b {
                let base = (bi * b + i) * m + bj * b;
                dist.extend_from_slice(&w.as_slice()[base..base + b]);
                succ.extend_from_slice(&full_succ[base..base + b]);
            }
            tiles.push(RwLock::new(PathTile { dist, succ }));
        }
    }
    tiles
}

/// Reassemble path tiles into one `(blocks·b) × (blocks·b)` matrix pair.
fn join_path_tiles(
    tiles: &[RwLock<PathTile>],
    blocks: usize,
    b: usize,
) -> (DistMatrix, Vec<usize>) {
    let m = blocks * b;
    let mut dist = vec![0f32; m * m];
    let mut succ = vec![NO_PATH; m * m];
    for bi in 0..blocks {
        for bj in 0..blocks {
            let tile = tiles[bi * blocks + bj].read().unwrap();
            for i in 0..b {
                let base = (bi * b + i) * m + bj * b;
                dist[base..base + b].copy_from_slice(&tile.dist[i * b..(i + 1) * b]);
                succ[base..base + b].copy_from_slice(&tile.succ[i * b..(i + 1) * b]);
            }
        }
    }
    (DistMatrix::from_vec(m, dist), succ)
}

/// Cut the padded matrix into row-major `b × b` tile buffers (row-major
/// super-grid order).
fn split_tiles(w: &DistMatrix, blocks: usize, b: usize) -> Vec<RwLock<Vec<f32>>> {
    let m = w.n();
    debug_assert_eq!(m, blocks * b);
    let mut tiles = Vec::with_capacity(blocks * blocks);
    for bi in 0..blocks {
        for bj in 0..blocks {
            let mut tile = Vec::with_capacity(b * b);
            for i in 0..b {
                let row = &w.row(bi * b + i)[bj * b..(bj + 1) * b];
                tile.extend_from_slice(row);
            }
            tiles.push(RwLock::new(tile));
        }
    }
    tiles
}

/// Reassemble the tile grid into one `(blocks·b) × (blocks·b)` matrix.
fn join_tiles(tiles: &[RwLock<Vec<f32>>], blocks: usize, b: usize) -> DistMatrix {
    let m = blocks * b;
    let mut data = vec![0f32; m * m];
    for bi in 0..blocks {
        for bj in 0..blocks {
            let tile = tiles[bi * blocks + bj].read().unwrap();
            for i in 0..b {
                let dst = &mut data[(bi * b + i) * m + bj * b..][..b];
                dst.copy_from_slice(&tile[i * b..(i + 1) * b]);
            }
        }
    }
    DistMatrix::from_vec(m, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp;
    use crate::graph::generators;

    fn cfg(bucket: usize, workers: usize) -> SuperBlockConfig {
        SuperBlockConfig {
            bucket,
            workers,
            profile: false,
        }
    }

    #[test]
    fn split_join_roundtrip() {
        let g = generators::erdos_renyi(48, 0.4, 7);
        let tiles = split_tiles(&g, 3, 16);
        assert_eq!(tiles.len(), 9);
        assert_eq!(join_tiles(&tiles, 3, 16), g);
    }

    #[test]
    fn bitwise_equal_to_blocked_when_n_divides() {
        // the exactness claim in the module docs, at unit scale
        let g = generators::erdos_renyi(96, 0.3, 11);
        let oracle = apsp::blocked::solve(&g, 32);
        for workers in [1, 2, 4, 8] {
            let (dist, report) = solve_cpu(&g, &cfg(32, workers));
            assert_eq!(dist, oracle, "workers={workers}");
            assert_eq!(report.round_count(), 3);
            assert_eq!(report.blocks, 3);
            assert_eq!(report.total_tiles(), 3 * (4 + 4));
        }
    }

    #[test]
    fn non_multiple_n_pads_and_truncates() {
        let g = generators::erdos_renyi(50, 0.4, 13);
        let (dist, report) = solve_cpu(&g, &cfg(16, 4));
        assert_eq!(report.padded, 64);
        assert_eq!(report.blocks, 4);
        assert_eq!(dist.n(), 50);
        // bitwise vs the padded blocked oracle, close vs the naive oracle
        let oracle = apsp::blocked::solve(&g.padded(64), 16).truncated(50);
        assert_eq!(dist, oracle);
        assert!(dist.allclose(&apsp::naive::solve(&g), 1e-5, 1e-6));
    }

    #[test]
    fn single_block_grid_is_one_diag_solve() {
        let g = generators::erdos_renyi(20, 0.5, 17);
        let (dist, report) = solve_cpu(&g, &cfg(32, 4));
        assert_eq!(report.blocks, 1);
        assert_eq!(report.total_tiles(), 0);
        assert_eq!(report.diag_solves(), 1);
        assert!(dist.allclose(&apsp::naive::solve(&g), 1e-5, 1e-6));
    }

    #[test]
    fn structured_graphs_match_naive() {
        for g in [
            generators::ring(80),
            generators::grid(9, 3), // n = 81
            generators::scale_free(75, 2, 5),
            generators::layered_dag(10, 8, 7), // negative weights
        ] {
            let (dist, _) = solve_cpu(&g, &cfg(16, 3));
            let naive = apsp::naive::solve(&g);
            assert!(
                dist.allclose(&naive, 1e-5, 1e-6),
                "diverges by {}",
                dist.max_abs_diff(&naive)
            );
        }
    }

    #[test]
    fn diag_solver_errors_propagate() {
        let g = generators::ring(64);
        let err = solve_with(&g, &cfg(32, 2), |_| anyhow::bail!("device fell over"));
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("device fell over"));
    }

    #[test]
    fn diag_solver_shape_mismatch_rejected() {
        let g = generators::ring(64);
        let err = solve_with(&g, &cfg(32, 2), |_| Ok(DistMatrix::unconnected(16)));
        assert!(err.unwrap_err().to_string().contains("expected bucket"));
    }

    #[test]
    fn empty_graph() {
        let g = DistMatrix::unconnected(0);
        let (dist, report) = solve_cpu(&g, &cfg(32, 2));
        assert_eq!(dist.n(), 0);
        assert_eq!(report.round_count(), 0);
    }

    #[test]
    fn custom_diag_solver_is_used() {
        // a diag solver that runs the naive CPU solver still yields a
        // correct closure (order differs, values agree within tolerance)
        let g = generators::erdos_renyi(64, 0.4, 23);
        let (dist, _) = solve_with(&g, &cfg(16, 2), |tile| Ok(apsp::naive::solve(&tile)))
            .unwrap();
        assert!(dist.allclose(&apsp::naive::solve(&g), 1e-5, 1e-6));
    }

    #[test]
    fn paths_distances_bitwise_equal_to_distance_tier() {
        // path mode's documented contract, across pool widths
        let g = generators::erdos_renyi(96, 0.3, 11);
        let oracle = apsp::blocked::solve(&g, 32);
        for workers in [1, 2, 4] {
            let (r, report) = solve_paths(&g, &cfg(32, workers));
            assert_eq!(r.dist, oracle, "workers={workers}");
            assert_eq!(report.round_count(), 3);
        }
    }

    #[test]
    fn paths_non_multiple_n_pads_truncates_and_reconstructs() {
        let g = generators::erdos_renyi(50, 0.4, 13);
        let (r, report) = solve_paths(&g, &cfg(16, 4));
        assert_eq!(report.padded, 64);
        assert_eq!(r.n(), 50);
        // distances bitwise vs the padded blocked oracle
        let oracle = apsp::blocked::solve(&g.padded(64), 16).truncated(50);
        assert_eq!(r.dist, oracle);
        // every reconstructed path is a real edge walk of the right weight,
        // and no successor references a padded vertex
        for i in 0..50 {
            for j in 0..50 {
                let s = r.succ_at(i, j);
                assert!(
                    s == crate::apsp::paths::NO_PATH || s < 50,
                    "({i},{j}) references padded vertex {s}"
                );
                match r.path(i, j) {
                    Some(_) => {
                        let w = r.path_weight(&g, i, j).expect("valid edge walk");
                        let d = r.dist.get(i, j) as f64;
                        assert!((w - d).abs() < 1e-3, "({i},{j}): {w} vs {d}");
                    }
                    None => assert!(!r.dist.get(i, j).is_finite() || i == j),
                }
            }
        }
    }

    #[test]
    fn paths_pool_width_cannot_perturb_successors() {
        // panel/interior writes only read finalized inputs, so even the
        // successor matrix is schedule-independent
        let g = generators::erdos_renyi(80, 0.35, 17);
        let (serial, _) = solve_paths(&g, &cfg(16, 1));
        for workers in [2, 4, 8] {
            let (par, _) = solve_paths(&g, &cfg(16, workers));
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn paths_empty_graph() {
        let (r, report) = solve_paths(&DistMatrix::unconnected(0), &cfg(32, 2));
        assert_eq!(r.n(), 0);
        assert_eq!(report.round_count(), 0);
    }

    #[test]
    fn generic_semirings_match_blocked_exactly() {
        // the exactness claim carries over verbatim to every semiring: the
        // super-blocked primitives apply the same ⊕/⊗ in the same order as
        // apsp::blocked, so outputs are equal (selection semirings never
        // round, so `==` is the right comparison)
        use crate::apsp::semiring::{blocked_solve, MaxMin, Objective};
        for objective in [
            Objective::Bottleneck,
            Objective::Minimax,
            Objective::Reachability,
        ] {
            let raw = generators::erdos_renyi(80, 0.3, 41);
            let g = objective.prepare(&raw).expect("positive weights");
            let oracle = blocked_solve(objective, &g, 16);
            for workers in [1, 4] {
                let (dist, _) = solve_cpu_objective(objective, &g, &cfg(16, workers));
                assert_eq!(dist, oracle, "{objective:?} workers={workers}");
            }
        }
        // non-multiple n exercises semiring-aware padding
        let raw = generators::erdos_renyi(50, 0.4, 43);
        let g = Objective::Bottleneck.prepare(&raw).unwrap();
        let (dist, report) = solve_cpu_semiring::<MaxMin>(&g, &cfg(16, 4));
        assert_eq!(report.padded, 64);
        assert_eq!(dist, crate::apsp::blocked::solve_semiring::<MaxMin>(&g, 16));
    }

    #[test]
    fn generic_paths_pool_width_independent_and_distance_exact() {
        use crate::apsp::semiring::{MaxMin, Objective};
        let raw = generators::erdos_renyi(64, 0.35, 47);
        let g = Objective::Bottleneck.prepare(&raw).unwrap();
        let (serial, _) = solve_paths_semiring::<MaxMin>(&g, &cfg(16, 1));
        // distances exactly match the distance-only tier
        let (dist_only, _) = solve_cpu_semiring::<MaxMin>(&g, &cfg(16, 1));
        assert_eq!(serial.dist, dist_only);
        // pool width cannot perturb even the successor matrix
        for workers in [2, 4] {
            let (par, _) = solve_paths_semiring::<MaxMin>(&g, &cfg(16, workers));
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn profiling_is_bitwise_neutral_and_accounts_workers() {
        // the observability contract: profile on/off cannot perturb a
        // single bit of output, but on populates occupancy accounting
        let g = generators::erdos_renyi(96, 0.3, 31);
        for workers in [1, 4] {
            let plain = cfg(32, workers);
            let profiled = SuperBlockConfig {
                profile: true,
                ..plain
            };
            let (d0, r0) = solve_cpu(&g, &plain);
            let (d1, r1) = solve_cpu(&g, &profiled);
            assert_eq!(d0, d1, "workers={workers}");
            assert_eq!(r0.busy_seconds(), 0.0, "off records nothing");
            assert_eq!(r0.max_critical_path(), 0);
            assert!(r1.busy_seconds() > 0.0, "on accounts busy time");
            // blocks=3 → per round 1 panel-depth + 1 interior-depth
            assert_eq!(r1.max_critical_path(), 2);
            let occ = r1.occupancy();
            assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
            // path mode carries the same accounting
            let (p0, _) = solve_paths(&g, &plain);
            let (p1, pr1) = solve_paths(&g, &profiled);
            assert_eq!(p0, p1, "workers={workers}");
            assert_eq!(pr1.max_critical_path(), 2);
        }
    }

    #[test]
    fn report_accounts_every_tile() {
        let g = generators::erdos_renyi(128, 0.3, 29);
        let (_, report) = solve_cpu(&g, &cfg(32, 4));
        // blocks=4: per round 2·3 panels + 3² interiors = 15, 4 rounds
        assert_eq!(report.blocks, 4);
        assert_eq!(report.total_tiles(), 4 * 15);
        assert_eq!(report.diag_solves(), 4);
        assert_eq!(report.bucket, 32);
        assert_eq!(report.n, 128);
        assert_eq!(report.padded, 128);
    }
}

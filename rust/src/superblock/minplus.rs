//! Tiled semiring update primitives for the super-block tier.
//!
//! Every primitive is generic over the [`Semiring`] (`*_semiring`
//! variants); the historical `(min, +)` names are the generics
//! monomorphized at [`MinPlus`], bitwise-pinned as before.
//!
//! These are the paper's three phase bodies (Fig. 2) operating on
//! *detached* `b × b` tile buffers instead of in-place windows of one big
//! matrix.  Loop order and finiteness guards mirror
//! [`crate::apsp::blocked`] line for line — phases 1–2 through the shared
//! branchless row sweep ([`kernel::relax_row`], sequential k), phase 3
//! through the shared register-tiled microkernel
//! ([`kernel::minplus_panel`]; detached tiles are contiguous, so no
//! packing is needed — `should_pack(b, b)` is false by construction).
//! Both shared kernels dispatch to the runtime-selected SIMD ISA
//! ([`crate::apsp::simd`]), bitwise-invisibly.
//! This buys a strong property the tests pin: a super-blocked solve whose
//! diagonal tiles are solved in phase-1 order is **bitwise identical** to
//! `apsp::blocked::solve(g, bucket)` — every relaxation performs the same
//! f32 additions on the same values (phase 3 is a pure min-reduction, so
//! the register tiling cannot perturb a bit; see `kernel`'s module docs),
//! and tile updates within a phase only read finalized inputs, so
//! execution order (and hence pool parallelism) cannot either.

use crate::apsp::kernel;
use crate::apsp::semiring::{MinPlus, Semiring};

/// Phase 1: full Floyd-Warshall on a detached `b × b` diagonal tile
/// (sequential k; the order of `apsp::blocked::phase1_diag`) —
/// [`phase1_semiring`] at `(min, +)`.
pub fn phase1(diag: &mut [f32], b: usize) {
    phase1_semiring::<MinPlus>(diag, b);
}

/// Generic phase 1 over any [`Semiring`].
pub fn phase1_semiring<S: Semiring>(diag: &mut [f32], b: usize) {
    debug_assert_eq!(diag.len(), b * b);
    for k in 0..b {
        for i in 0..b {
            if i == k {
                continue;
            }
            let wik = diag[i * b + k];
            if S::is_zero(wik) {
                continue;
            }
            let (out, row_k) = kernel::row_pair_mut(diag, b, i, k, 0, b);
            kernel::relax_row_semiring::<S>(out, row_k, wik);
        }
    }
}

/// Phase 2, row panel: tile `(k, bj)` relaxed against the final diagonal
/// tile — `t[i][j] <- t[i][j] ⊕ (diag[i][k] ⊗ t[k][j])`, sequential k
/// (one dependency is in the panel itself) — [`panel_row_semiring`] at
/// `(min, +)`.
pub fn panel_row(tile: &mut [f32], diag: &[f32], b: usize) {
    panel_row_semiring::<MinPlus>(tile, diag, b);
}

/// Generic phase-2 row panel over any [`Semiring`].
pub fn panel_row_semiring<S: Semiring>(tile: &mut [f32], diag: &[f32], b: usize) {
    debug_assert_eq!(tile.len(), b * b);
    debug_assert_eq!(diag.len(), b * b);
    for k in 0..b {
        for i in 0..b {
            if i == k {
                continue;
            }
            let dik = diag[i * b + k];
            if S::is_zero(dik) {
                continue;
            }
            let (out, row_k) = kernel::row_pair_mut(tile, b, i, k, 0, b);
            kernel::relax_row_semiring::<S>(out, row_k, dik);
        }
    }
}

/// Phase 2, column panel: tile `(bi, k)` relaxed against the final
/// diagonal tile — `t[i][j] <- t[i][j] ⊕ (t[i][k] ⊗ diag[k][j])`,
/// sequential k — [`panel_col_semiring`] at `(min, +)`.
pub fn panel_col(tile: &mut [f32], diag: &[f32], b: usize) {
    panel_col_semiring::<MinPlus>(tile, diag, b);
}

/// Generic phase-2 column panel over any [`Semiring`].
pub fn panel_col_semiring<S: Semiring>(tile: &mut [f32], diag: &[f32], b: usize) {
    debug_assert_eq!(tile.len(), b * b);
    debug_assert_eq!(diag.len(), b * b);
    for k in 0..b {
        for i in 0..b {
            let wik = tile[i * b + k];
            if S::is_zero(wik) {
                continue;
            }
            let row_k = &diag[k * b..(k + 1) * b];
            let out = &mut tile[i * b..(i + 1) * b];
            kernel::relax_row_semiring::<S>(out, row_k, wik);
        }
    }
}

/// Phase 3, interior: `c <- c ⊕ (col ⊗ row)` where `⊗` is the semiring
/// tile product, `col` is the finalized column-panel tile `(bi, k)` and
/// `row` the finalized row-panel tile `(k, bj)`.  Routed through the
/// shared register-tiled microkernel; all three tiles are detached and
/// contiguous, so the kernel's disjointness contract holds trivially.
/// [`interior_semiring`] at `(min, +)`.
pub fn interior(c: &mut [f32], col: &[f32], row: &[f32], b: usize) {
    interior_semiring::<MinPlus>(c, col, row, b);
}

/// Generic phase-3 interior over any [`Semiring`].
pub fn interior_semiring<S: Semiring>(c: &mut [f32], col: &[f32], row: &[f32], b: usize) {
    debug_assert_eq!(c.len(), b * b);
    debug_assert_eq!(col.len(), b * b);
    debug_assert_eq!(row.len(), b * b);
    // detached tiles are contiguous: repacking would be a pure copy
    debug_assert!(!kernel::should_pack(b, b));
    kernel::panel::<S>(c, b, col, b, row, b, b, b, b);
}

// ------------------------------------------------- successor tracking --
//
// Each primitive below is the successor-tracking twin of the one above:
// identical distance arithmetic and loop order (so distances stay bitwise
// equal to the distance-only tier), with a parallel `b × b` successor tile
// updated by the shared rule — an improvement via pivot `k` copies the
// successor of the `(i, k)` dependency.  Successor values are *global*
// vertex ids (the orchestrator initializes them before splitting tiles),
// so copying them between detached tiles is position-independent.

/// [`phase1`] with successor tracking: pivot column `(i, k)` is in the
/// diagonal tile itself.  [`phase1_succ_semiring`] at `(min, +)`.
pub fn phase1_succ(diag: &mut [f32], dsucc: &mut [usize], b: usize) {
    phase1_succ_semiring::<MinPlus>(diag, dsucc, b);
}

/// Generic successor-tracking phase 1.
pub fn phase1_succ_semiring<S: Semiring>(diag: &mut [f32], dsucc: &mut [usize], b: usize) {
    debug_assert_eq!(diag.len(), b * b);
    debug_assert_eq!(dsucc.len(), b * b);
    for k in 0..b {
        for i in 0..b {
            if i == k {
                continue;
            }
            let wik = diag[i * b + k];
            if S::is_zero(wik) {
                continue;
            }
            let sik = dsucc[i * b + k];
            for j in 0..b {
                let cand = S::extend(wik, diag[k * b + j]);
                if S::improves(cand, diag[i * b + j]) {
                    diag[i * b + j] = cand;
                    dsucc[i * b + j] = sik;
                }
            }
        }
    }
}

/// [`panel_row`] with successor tracking: the `(i, k)` dependency lives in
/// the diagonal tile, so the successor source is `dsucc`.
/// [`panel_row_succ_semiring`] at `(min, +)`.
pub fn panel_row_succ(
    tile: &mut [f32],
    tsucc: &mut [usize],
    diag: &[f32],
    dsucc: &[usize],
    b: usize,
) {
    panel_row_succ_semiring::<MinPlus>(tile, tsucc, diag, dsucc, b);
}

/// Generic successor-tracking phase-2 row panel.
pub fn panel_row_succ_semiring<S: Semiring>(
    tile: &mut [f32],
    tsucc: &mut [usize],
    diag: &[f32],
    dsucc: &[usize],
    b: usize,
) {
    debug_assert_eq!(tile.len(), b * b);
    debug_assert_eq!(tsucc.len(), b * b);
    debug_assert_eq!(diag.len(), b * b);
    debug_assert_eq!(dsucc.len(), b * b);
    for k in 0..b {
        for i in 0..b {
            if i == k {
                continue;
            }
            let dik = diag[i * b + k];
            if S::is_zero(dik) {
                continue;
            }
            let sik = dsucc[i * b + k];
            for j in 0..b {
                let cand = S::extend(dik, tile[k * b + j]);
                if S::improves(cand, tile[i * b + j]) {
                    tile[i * b + j] = cand;
                    tsucc[i * b + j] = sik;
                }
            }
        }
    }
}

/// [`panel_col`] with successor tracking: the `(i, k)` dependency lives in
/// the panel itself, so no diagonal successors are needed.
/// [`panel_col_succ_semiring`] at `(min, +)`.
pub fn panel_col_succ(tile: &mut [f32], tsucc: &mut [usize], diag: &[f32], b: usize) {
    panel_col_succ_semiring::<MinPlus>(tile, tsucc, diag, b);
}

/// Generic successor-tracking phase-2 column panel.
pub fn panel_col_succ_semiring<S: Semiring>(
    tile: &mut [f32],
    tsucc: &mut [usize],
    diag: &[f32],
    b: usize,
) {
    debug_assert_eq!(tile.len(), b * b);
    debug_assert_eq!(tsucc.len(), b * b);
    debug_assert_eq!(diag.len(), b * b);
    for k in 0..b {
        for i in 0..b {
            let wik = tile[i * b + k];
            if S::is_zero(wik) {
                continue;
            }
            let sik = tsucc[i * b + k];
            for j in 0..b {
                let cand = S::extend(wik, diag[k * b + j]);
                if S::improves(cand, tile[i * b + j]) {
                    tile[i * b + j] = cand;
                    tsucc[i * b + j] = sik;
                }
            }
        }
    }
}

/// [`interior`] with successor tracking: the `(i, k)` dependency is the
/// finalized column-panel tile, so the successor source is `colsucc`.
/// Routed through the register-tiled succ microkernel (same accept
/// sequence as the scalar loop — distances *and* successors bitwise).
/// [`interior_succ_semiring`] at `(min, +)`.
pub fn interior_succ(
    c: &mut [f32],
    csucc: &mut [usize],
    col: &[f32],
    colsucc: &[usize],
    row: &[f32],
    b: usize,
) {
    interior_succ_semiring::<MinPlus>(c, csucc, col, colsucc, row, b);
}

/// Generic successor-tracking phase-3 interior.
pub fn interior_succ_semiring<S: Semiring>(
    c: &mut [f32],
    csucc: &mut [usize],
    col: &[f32],
    colsucc: &[usize],
    row: &[f32],
    b: usize,
) {
    debug_assert_eq!(c.len(), b * b);
    debug_assert_eq!(csucc.len(), b * b);
    debug_assert_eq!(col.len(), b * b);
    debug_assert_eq!(colsucc.len(), b * b);
    debug_assert_eq!(row.len(), b * b);
    kernel::panel_succ::<S>(c, csucc, b, col, colsucc, b, row, b, b, b, b);
}

/// Parallel path for [`interior_succ`]: split the tile's rows (of both the
/// distance and successor tiles) over `threads` scoped workers — the path
/// tier's mirror of [`interior_parallel`], for the same degenerate
/// super-grids (a 2×2 grid has one interior tile per round, so tile-level
/// pooling alone leaves workers idle).  Row bands of `c`/`csucc` are
/// disjoint and `col`/`colsucc`/`row` are read-only, so no locking; each
/// band is one microkernel call over its rows.
pub fn interior_succ_parallel(
    c: &mut [f32],
    csucc: &mut [usize],
    col: &[f32],
    colsucc: &[usize],
    row: &[f32],
    b: usize,
    threads: usize,
) {
    interior_succ_parallel_semiring::<MinPlus>(c, csucc, col, colsucc, row, b, threads);
}

/// Generic banded successor-tracking interior.
#[allow(clippy::too_many_arguments)]
pub fn interior_succ_parallel_semiring<S: Semiring>(
    c: &mut [f32],
    csucc: &mut [usize],
    col: &[f32],
    colsucc: &[usize],
    row: &[f32],
    b: usize,
    threads: usize,
) {
    if threads <= 1 || b == 0 {
        interior_succ_semiring::<S>(c, csucc, col, colsucc, row, b);
        return;
    }
    let rows_per_band = b.div_ceil(threads.min(b));
    std::thread::scope(|scope| {
        let bands = c
            .chunks_mut(rows_per_band * b)
            .zip(csucc.chunks_mut(rows_per_band * b));
        for (band_idx, (band, succ_band)) in bands.enumerate() {
            scope.spawn(move || {
                let first_row = band_idx * rows_per_band;
                let band_rows = band.len() / b;
                let col_rows = &col[first_row * b..];
                let colsucc_rows = &colsucc[first_row * b..];
                kernel::panel_succ::<S>(
                    band,
                    succ_band,
                    b,
                    col_rows,
                    colsucc_rows,
                    b,
                    row,
                    b,
                    band_rows,
                    b,
                    b,
                );
            });
        }
    });
}

/// Parallel path for [`interior`]: split the tile's rows over `threads`
/// scoped workers.  Row bands of `c` (and the matching rows of `col`) are
/// disjoint and `row` is read-only, so this needs no locking; it exists for
/// degenerate super-grids (2 × 2 has a single interior tile per round, so
/// tile-level pooling alone leaves workers idle).
pub fn interior_parallel(c: &mut [f32], col: &[f32], row: &[f32], b: usize, threads: usize) {
    interior_parallel_semiring::<MinPlus>(c, col, row, b, threads);
}

/// Generic banded interior.
pub fn interior_parallel_semiring<S: Semiring>(
    c: &mut [f32],
    col: &[f32],
    row: &[f32],
    b: usize,
    threads: usize,
) {
    if threads <= 1 || b == 0 {
        interior_semiring::<S>(c, col, row, b);
        return;
    }
    let rows_per_band = b.div_ceil(threads.min(b));
    std::thread::scope(|scope| {
        for (band_idx, band) in c.chunks_mut(rows_per_band * b).enumerate() {
            scope.spawn(move || {
                let first_row = band_idx * rows_per_band;
                let band_rows = band.len() / b;
                let col_rows = &col[first_row * b..];
                kernel::panel::<S>(band, b, col_rows, b, row, b, band_rows, b, b);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::blocked;
    use crate::graph::{generators, DistMatrix};

    const B: usize = 16;

    /// Extract the B×B tile at super-coords (bi, bj) of a (2B)×(2B) matrix.
    fn tile_of(w: &DistMatrix, bi: usize, bj: usize) -> Vec<f32> {
        let mut out = vec![0f32; B * B];
        for i in 0..B {
            for j in 0..B {
                out[i * B + j] = w.get(bi * B + i, bj * B + j);
            }
        }
        out
    }

    fn full_matrix() -> DistMatrix {
        generators::erdos_renyi(2 * B, 0.4, 99)
    }

    #[test]
    fn phase1_matches_blocked_phase1_diag_bitwise() {
        let mut w = full_matrix();
        let mut detached = tile_of(&w, 0, 0);
        phase1(&mut detached, B);
        blocked::phase1_diag(&mut w, 0, B); // in-place oracle
        assert_eq!(detached, tile_of(&w, 0, 0));
    }

    #[test]
    fn panels_match_in_place_phase2_bitwise() {
        // stage 0 of a 2×2 super-grid: phase 1 in place, then both phase-2
        // flavors detached vs in place on the same values
        let mut w = full_matrix();
        blocked::phase1_diag(&mut w, 0, B);
        let diag = tile_of(&w, 0, 0);

        let mut row_panel = tile_of(&w, 0, 1);
        panel_row(&mut row_panel, &diag, B);
        blocked::phase2_row_tile(&mut w, 0, B, B);
        assert_eq!(row_panel, tile_of(&w, 0, 1));

        let mut col_panel = tile_of(&w, 1, 0);
        panel_col(&mut col_panel, &diag, B);
        blocked::phase2_col_tile(&mut w, 0, B, B);
        assert_eq!(col_panel, tile_of(&w, 1, 0));
    }

    #[test]
    fn interior_matches_naive_min_fold_bitwise() {
        // For a fixed (i, j) the interior update applies min over ascending
        // k with identical f32 additions, and f32 min is exact — so a naive
        // i-j-k fold is a bitwise oracle (this is the reassociation freedom
        // the register-tiled kernel leans on).
        let w = full_matrix();
        let col = tile_of(&w, 1, 0);
        let row = tile_of(&w, 0, 1);
        let mut got = tile_of(&w, 1, 1);
        interior(&mut got, &col, &row, B);

        let base = tile_of(&w, 1, 1);
        for i in 0..B {
            for j in 0..B {
                let mut best = base[i * B + j];
                for k in 0..B {
                    best = best.min(col[i * B + k] + row[k * B + j]);
                }
                assert_eq!(got[i * B + j].to_bits(), best.to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn interior_parallel_is_bitwise_equal_to_serial() {
        let w = full_matrix();
        let col = tile_of(&w, 1, 0);
        let row = tile_of(&w, 0, 1);
        let mut serial = tile_of(&w, 1, 1);
        interior(&mut serial, &col, &row, B);
        for threads in [2, 3, 8, 64] {
            let mut par = tile_of(&w, 1, 1);
            interior_parallel(&mut par, &col, &row, B, threads);
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn succ_variants_leave_distances_bitwise_unchanged() {
        // the documented contract: the succ twins perform identical float
        // arithmetic, so every distance tile matches the distance-only run
        let w = full_matrix();
        let n = w.n();
        let succ_of = |bi: usize, bj: usize| -> Vec<usize> {
            let full = crate::apsp::paths::init_succ(&w);
            let mut out = vec![0usize; B * B];
            for i in 0..B {
                for j in 0..B {
                    out[i * B + j] = full[(bi * B + i) * n + bj * B + j];
                }
            }
            out
        };

        // phase 1
        let mut d_plain = tile_of(&w, 0, 0);
        let mut d_succ = d_plain.clone();
        let mut dsucc = succ_of(0, 0);
        phase1(&mut d_plain, B);
        phase1_succ(&mut d_succ, &mut dsucc, B);
        assert_eq!(d_plain, d_succ);

        // panels against the solved diagonal
        let mut row_plain = tile_of(&w, 0, 1);
        let mut row_succ_t = row_plain.clone();
        let mut rsucc = succ_of(0, 1);
        panel_row(&mut row_plain, &d_plain, B);
        panel_row_succ(&mut row_succ_t, &mut rsucc, &d_succ, &dsucc, B);
        assert_eq!(row_plain, row_succ_t);

        let mut col_plain = tile_of(&w, 1, 0);
        let mut col_succ_t = col_plain.clone();
        let mut csucc = succ_of(1, 0);
        panel_col(&mut col_plain, &d_plain, B);
        panel_col_succ(&mut col_succ_t, &mut csucc, &d_succ, B);
        assert_eq!(col_plain, col_succ_t);

        // interior against the solved panels
        let mut int_plain = tile_of(&w, 1, 1);
        let mut int_succ_t = int_plain.clone();
        let mut isucc = succ_of(1, 1);
        interior(&mut int_plain, &col_plain, &row_plain, B);
        interior_succ(&mut int_succ_t, &mut isucc, &col_succ_t, &csucc, &row_plain, B);
        assert_eq!(int_plain, int_succ_t);
    }

    #[test]
    fn interior_succ_parallel_is_bitwise_equal_to_serial() {
        let w = full_matrix();
        let full = crate::apsp::paths::init_succ(&w);
        let n = w.n();
        let succ_of = |bi: usize, bj: usize| -> Vec<usize> {
            let mut out = vec![0usize; B * B];
            for i in 0..B {
                for j in 0..B {
                    out[i * B + j] = full[(bi * B + i) * n + bj * B + j];
                }
            }
            out
        };
        let col = tile_of(&w, 1, 0);
        let colsucc = succ_of(1, 0);
        let row = tile_of(&w, 0, 1);
        let mut serial_d = tile_of(&w, 1, 1);
        let mut serial_s = succ_of(1, 1);
        interior_succ(&mut serial_d, &mut serial_s, &col, &colsucc, &row, B);
        for threads in [2, 3, 8, 64] {
            let mut par_d = tile_of(&w, 1, 1);
            let mut par_s = succ_of(1, 1);
            interior_succ_parallel(&mut par_d, &mut par_s, &col, &colsucc, &row, B, threads);
            assert_eq!(serial_d, par_d, "threads={threads}");
            assert_eq!(serial_s, par_s, "threads={threads}");
        }
    }

    #[test]
    fn succ_updates_record_the_pivot_hop() {
        // 0 → 2 → 1 shortcut inside one phase-1 tile: succ(0,1) must become
        // succ(0,2) (= 2, the first hop of the improving path)
        let b = 3;
        let inf = f32::INFINITY;
        let mut diag = vec![
            0.0, 10.0, 2.0, //
            inf, 0.0, inf, //
            inf, 3.0, 0.0,
        ];
        let mut dsucc = vec![
            crate::apsp::paths::NO_PATH,
            1,
            2,
            crate::apsp::paths::NO_PATH,
            crate::apsp::paths::NO_PATH,
            crate::apsp::paths::NO_PATH,
            crate::apsp::paths::NO_PATH,
            1,
            crate::apsp::paths::NO_PATH,
        ];
        phase1_succ(&mut diag, &mut dsucc, b);
        assert_eq!(diag[1], 5.0); // 0→2→1
        assert_eq!(dsucc[1], 2); // first hop goes through vertex 2
    }

    #[test]
    fn infinite_entries_stay_infinite() {
        let mut diag = vec![f32::INFINITY; B * B];
        for i in 0..B {
            diag[i * B + i] = 0.0;
        }
        let mut tile = diag.clone();
        panel_row(&mut tile, &diag, B);
        panel_col(&mut tile, &diag, B);
        let col = diag.clone();
        interior(&mut tile, &col, &diag, B);
        for i in 0..B {
            for j in 0..B {
                if i == j {
                    assert_eq!(tile[i * B + j], 0.0);
                } else {
                    assert!(tile[i * B + j].is_infinite());
                }
            }
        }
    }
}

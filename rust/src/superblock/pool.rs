//! Dependency-driven worker pool for one round of superblock tile tasks.
//!
//! A minimal task-graph executor: tasks become ready when their
//! dependencies complete, workers pull ready tasks from a shared queue, and
//! completion of a task releases its dependents — so phase-3 interior tiles
//! start streaming the moment *their* two panels finish, not when the whole
//! phase-2 barrier clears (the paper's staged pipeline, one level up).
//!
//! All bookkeeping (ready queue, per-task pending counts, remaining total)
//! lives under one mutex; only the task bodies run outside it.  With
//! `workers <= 1` tasks run inline in plan order (plans are topologically
//! sorted), which is the deterministic single-thread schedule the benches
//! compare against.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Execute every task of a dependency graph.
///
/// * `deps[t]` lists the task indices `t` waits on (must be acyclic; plans
///   from [`super::schedule`] are topologically ordered which is stricter).
/// * `exec(t)` performs task `t`; it must be safe to call concurrently for
///   distinct tasks (tile tasks touch disjoint write sets by construction).
/// * `workers` is the maximum concurrency; it is clamped to the task count.
pub fn run_tasks<F>(deps: &[Vec<usize>], workers: usize, exec: F)
where
    F: Fn(usize) + Sync,
{
    let total = deps.len();
    if total == 0 {
        return;
    }
    if workers <= 1 {
        // plans are emitted dependency-first; run them in order
        for t in 0..total {
            debug_assert!(deps[t].iter().all(|&d| d < t), "plan not topological");
            exec(t);
        }
        return;
    }

    // reverse edges: who gets released when t completes
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); total];
    for (t, ds) in deps.iter().enumerate() {
        for &d in ds {
            assert!(d < total, "dependency {d} out of range");
            dependents[d].push(t);
        }
    }

    struct State {
        ready: VecDeque<usize>,
        pending: Vec<usize>,
        remaining: usize,
    }
    let state = Mutex::new(State {
        ready: (0..total).filter(|&t| deps[t].is_empty()).collect(),
        pending: deps.iter().map(Vec::len).collect(),
        remaining: total,
    });
    let cv = Condvar::new();

    let workers = workers.min(total);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let task = {
                    let mut st = state.lock().unwrap();
                    loop {
                        if st.remaining == 0 {
                            return;
                        }
                        if let Some(t) = st.ready.pop_front() {
                            break t;
                        }
                        st = cv.wait(st).unwrap();
                    }
                };
                exec(task);
                let mut st = state.lock().unwrap();
                st.remaining -= 1;
                for &d in &dependents[task] {
                    st.pending[d] -= 1;
                    if st.pending[d] == 0 {
                        st.ready.push_back(d);
                    }
                }
                if st.remaining == 0 || !st.ready.is_empty() {
                    cv.notify_all();
                }
            });
        }
    });
}

/// Worker-occupancy accounting for one [`run_tasks_profiled`] round.
///
/// `busy_seconds[w]` is the wall-clock time worker `w` spent inside task
/// bodies; `idle_seconds[w]` the time it spent waiting for a ready task
/// (queue empty or lock contention).  `critical_path` is the longest
/// dependency chain in the round's graph, in tasks — the schedule-imposed
/// lower bound on rounds of parallel work, independent of pool width.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PoolProfile {
    pub workers: usize,
    pub busy_seconds: Vec<f64>,
    pub idle_seconds: Vec<f64>,
    pub critical_path: usize,
}

impl PoolProfile {
    pub fn busy_total(&self) -> f64 {
        self.busy_seconds.iter().sum()
    }

    pub fn idle_total(&self) -> f64 {
        self.idle_seconds.iter().sum()
    }

    /// Fraction of worker wall-clock spent in task bodies (1.0 for an
    /// empty round — nothing was wasted).
    pub fn occupancy(&self) -> f64 {
        let busy = self.busy_total();
        let total = busy + self.idle_total();
        if total == 0.0 {
            1.0
        } else {
            busy / total
        }
    }
}

/// Longest dependency chain of a task graph, in tasks (0 for an empty
/// graph).  Expects the topologically-ordered graphs [`super::schedule`]
/// emits (`deps[t]` only references earlier tasks).
pub fn critical_path(deps: &[Vec<usize>]) -> usize {
    let mut chain = vec![0usize; deps.len()];
    let mut best = 0;
    for (t, ds) in deps.iter().enumerate() {
        let deepest = ds
            .iter()
            .map(|&d| {
                debug_assert!(d < t, "plan not topological");
                chain[d]
            })
            .max()
            .unwrap_or(0);
        chain[t] = deepest + 1;
        best = best.max(chain[t]);
    }
    best
}

/// [`run_tasks`] with per-worker occupancy accounting.
///
/// Executes the identical schedule — same ready-queue discipline, same
/// release order — and additionally times each worker's task bodies and
/// waits.  Task bodies themselves are untouched (timing reads happen
/// around `exec`, never inside it), so results are exactly those of
/// [`run_tasks`]; the profiled path exists so the hot path stays
/// measurement-free when observability is off.
pub fn run_tasks_profiled<F>(deps: &[Vec<usize>], workers: usize, exec: F) -> PoolProfile
where
    F: Fn(usize) + Sync,
{
    let total = deps.len();
    let cp = critical_path(deps);
    if total == 0 {
        return PoolProfile {
            workers: 0,
            critical_path: cp,
            ..PoolProfile::default()
        };
    }
    if workers <= 1 {
        let mut busy = 0.0;
        for t in 0..total {
            debug_assert!(deps[t].iter().all(|&d| d < t), "plan not topological");
            let t0 = Instant::now();
            exec(t);
            busy += t0.elapsed().as_secs_f64();
        }
        return PoolProfile {
            workers: 1,
            busy_seconds: vec![busy],
            idle_seconds: vec![0.0],
            critical_path: cp,
        };
    }

    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); total];
    for (t, ds) in deps.iter().enumerate() {
        for &d in ds {
            assert!(d < total, "dependency {d} out of range");
            dependents[d].push(t);
        }
    }

    struct State {
        ready: VecDeque<usize>,
        pending: Vec<usize>,
        remaining: usize,
    }
    let state = Mutex::new(State {
        ready: (0..total).filter(|&t| deps[t].is_empty()).collect(),
        pending: deps.iter().map(Vec::len).collect(),
        remaining: total,
    });
    let cv = Condvar::new();

    let workers = workers.min(total);
    let per_worker: Vec<(f64, f64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut busy = 0.0;
                    let mut idle = 0.0;
                    loop {
                        let wait0 = Instant::now();
                        let task = {
                            let mut st = state.lock().unwrap();
                            loop {
                                if st.remaining == 0 {
                                    idle += wait0.elapsed().as_secs_f64();
                                    return (busy, idle);
                                }
                                if let Some(t) = st.ready.pop_front() {
                                    break t;
                                }
                                st = cv.wait(st).unwrap();
                            }
                        };
                        idle += wait0.elapsed().as_secs_f64();
                        let t0 = Instant::now();
                        exec(task);
                        busy += t0.elapsed().as_secs_f64();
                        let mut st = state.lock().unwrap();
                        st.remaining -= 1;
                        for &d in &dependents[task] {
                            st.pending[d] -= 1;
                            if st.pending[d] == 0 {
                                st.ready.push_back(d);
                            }
                        }
                        if st.remaining == 0 || !st.ready.is_empty() {
                            cv.notify_all();
                        }
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let (busy_seconds, idle_seconds) = per_worker.into_iter().unzip();
    PoolProfile {
        workers,
        busy_seconds,
        idle_seconds,
        critical_path: cp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex as StdMutex;

    /// Record completion order and assert every dependency finished first.
    fn check_order(deps: &[Vec<usize>], workers: usize) {
        let order: StdMutex<Vec<usize>> = StdMutex::new(Vec::new());
        run_tasks(deps, workers, |t| {
            order.lock().unwrap().push(t);
        });
        let order = order.into_inner().unwrap();
        assert_eq!(order.len(), deps.len(), "every task ran exactly once");
        let mut position = vec![usize::MAX; deps.len()];
        for (pos, &t) in order.iter().enumerate() {
            assert_eq!(position[t], usize::MAX, "task {t} ran twice");
            position[t] = pos;
        }
        for (t, ds) in deps.iter().enumerate() {
            for &d in ds {
                assert!(
                    position[d] < position[t],
                    "task {t} started before its dependency {d} (order {order:?})"
                );
            }
        }
    }

    fn diamond() -> Vec<Vec<usize>> {
        // 0 → {1, 2} → 3
        vec![vec![], vec![0], vec![0], vec![1, 2]]
    }

    #[test]
    fn respects_dependencies_serial_and_parallel() {
        for workers in [1, 2, 4, 16] {
            check_order(&diamond(), workers);
        }
    }

    #[test]
    fn runs_a_real_round_plan() {
        let plan = crate::superblock::schedule::round_plan(5, 2);
        for workers in [1, 3, 8] {
            check_order(&plan.dep_graph(), workers);
        }
    }

    #[test]
    fn empty_graph_is_a_noop() {
        run_tasks(&[], 4, |_| panic!("no tasks to run"));
    }

    #[test]
    fn independent_tasks_all_run() {
        let deps: Vec<Vec<usize>> = (0..50).map(|_| Vec::new()).collect();
        let count = AtomicUsize::new(0);
        run_tasks(&deps, 8, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn chain_executes_in_order() {
        // 0 → 1 → 2 → … → 9: only one task is ever ready, any worker count
        let deps: Vec<Vec<usize>> = (0..10)
            .map(|t| if t == 0 { vec![] } else { vec![t - 1] })
            .collect();
        check_order(&deps, 4);
    }

    #[test]
    fn more_workers_than_tasks() {
        check_order(&diamond(), 64);
    }

    #[test]
    fn critical_path_pins() {
        assert_eq!(critical_path(&[]), 0);
        assert_eq!(critical_path(&diamond()), 3, "0 → 1|2 → 3");
        let chain: Vec<Vec<usize>> = (0..10)
            .map(|t| if t == 0 { vec![] } else { vec![t - 1] })
            .collect();
        assert_eq!(critical_path(&chain), 10);
        let independent: Vec<Vec<usize>> = (0..7).map(|_| Vec::new()).collect();
        assert_eq!(critical_path(&independent), 1);
        // a real round plan: panels (depth 1) feed interiors (depth 2)
        let plan = crate::superblock::schedule::round_plan(5, 2);
        assert_eq!(critical_path(&plan.dep_graph()), 2);
    }

    /// Profiled runs obey the same ordering contract as [`run_tasks`].
    fn check_order_profiled(deps: &[Vec<usize>], workers: usize) -> PoolProfile {
        let order: StdMutex<Vec<usize>> = StdMutex::new(Vec::new());
        let profile = run_tasks_profiled(deps, workers, |t| {
            order.lock().unwrap().push(t);
        });
        let order = order.into_inner().unwrap();
        assert_eq!(order.len(), deps.len(), "every task ran exactly once");
        let mut position = vec![usize::MAX; deps.len()];
        for (pos, &t) in order.iter().enumerate() {
            assert_eq!(position[t], usize::MAX, "task {t} ran twice");
            position[t] = pos;
        }
        for (t, ds) in deps.iter().enumerate() {
            for &d in ds {
                assert!(position[d] < position[t], "task {t} before dep {d}");
            }
        }
        profile
    }

    #[test]
    fn profiled_runs_match_schedule_and_account_workers() {
        for workers in [1, 2, 4] {
            let profile = check_order_profiled(&diamond(), workers);
            assert_eq!(profile.workers, workers.min(4));
            assert_eq!(profile.busy_seconds.len(), profile.workers);
            assert_eq!(profile.idle_seconds.len(), profile.workers);
            assert_eq!(profile.critical_path, 3);
            assert!(profile.busy_total() >= 0.0);
            assert!(profile.idle_total() >= 0.0);
            let occ = profile.occupancy();
            assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
        }
    }

    #[test]
    fn profiled_empty_round() {
        let profile = run_tasks_profiled(&[], 4, |_| panic!("no tasks"));
        assert_eq!(profile.workers, 0);
        assert_eq!(profile.critical_path, 0);
        assert_eq!(profile.occupancy(), 1.0, "empty round wastes nothing");
    }

    #[test]
    fn profiled_serial_accumulates_busy_only() {
        let deps: Vec<Vec<usize>> = (0..5).map(|_| Vec::new()).collect();
        let profile = run_tasks_profiled(&deps, 1, |_| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(profile.workers, 1);
        assert_eq!(profile.idle_seconds, vec![0.0]);
        assert!(profile.busy_seconds[0] >= 0.0);
        assert_eq!(profile.occupancy(), 1.0);
    }
}

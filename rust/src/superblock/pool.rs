//! Dependency-driven worker pool for one round of superblock tile tasks.
//!
//! A minimal task-graph executor: tasks become ready when their
//! dependencies complete, workers pull ready tasks from a shared queue, and
//! completion of a task releases its dependents — so phase-3 interior tiles
//! start streaming the moment *their* two panels finish, not when the whole
//! phase-2 barrier clears (the paper's staged pipeline, one level up).
//!
//! All bookkeeping (ready queue, per-task pending counts, remaining total)
//! lives under one mutex; only the task bodies run outside it.  With
//! `workers <= 1` tasks run inline in plan order (plans are topologically
//! sorted), which is the deterministic single-thread schedule the benches
//! compare against.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Execute every task of a dependency graph.
///
/// * `deps[t]` lists the task indices `t` waits on (must be acyclic; plans
///   from [`super::schedule`] are topologically ordered which is stricter).
/// * `exec(t)` performs task `t`; it must be safe to call concurrently for
///   distinct tasks (tile tasks touch disjoint write sets by construction).
/// * `workers` is the maximum concurrency; it is clamped to the task count.
pub fn run_tasks<F>(deps: &[Vec<usize>], workers: usize, exec: F)
where
    F: Fn(usize) + Sync,
{
    let total = deps.len();
    if total == 0 {
        return;
    }
    if workers <= 1 {
        // plans are emitted dependency-first; run them in order
        for t in 0..total {
            debug_assert!(deps[t].iter().all(|&d| d < t), "plan not topological");
            exec(t);
        }
        return;
    }

    // reverse edges: who gets released when t completes
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); total];
    for (t, ds) in deps.iter().enumerate() {
        for &d in ds {
            assert!(d < total, "dependency {d} out of range");
            dependents[d].push(t);
        }
    }

    struct State {
        ready: VecDeque<usize>,
        pending: Vec<usize>,
        remaining: usize,
    }
    let state = Mutex::new(State {
        ready: (0..total).filter(|&t| deps[t].is_empty()).collect(),
        pending: deps.iter().map(Vec::len).collect(),
        remaining: total,
    });
    let cv = Condvar::new();

    let workers = workers.min(total);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let task = {
                    let mut st = state.lock().unwrap();
                    loop {
                        if st.remaining == 0 {
                            return;
                        }
                        if let Some(t) = st.ready.pop_front() {
                            break t;
                        }
                        st = cv.wait(st).unwrap();
                    }
                };
                exec(task);
                let mut st = state.lock().unwrap();
                st.remaining -= 1;
                for &d in &dependents[task] {
                    st.pending[d] -= 1;
                    if st.pending[d] == 0 {
                        st.ready.push_back(d);
                    }
                }
                if st.remaining == 0 || !st.ready.is_empty() {
                    cv.notify_all();
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex as StdMutex;

    /// Record completion order and assert every dependency finished first.
    fn check_order(deps: &[Vec<usize>], workers: usize) {
        let order: StdMutex<Vec<usize>> = StdMutex::new(Vec::new());
        run_tasks(deps, workers, |t| {
            order.lock().unwrap().push(t);
        });
        let order = order.into_inner().unwrap();
        assert_eq!(order.len(), deps.len(), "every task ran exactly once");
        let mut position = vec![usize::MAX; deps.len()];
        for (pos, &t) in order.iter().enumerate() {
            assert_eq!(position[t], usize::MAX, "task {t} ran twice");
            position[t] = pos;
        }
        for (t, ds) in deps.iter().enumerate() {
            for &d in ds {
                assert!(
                    position[d] < position[t],
                    "task {t} started before its dependency {d} (order {order:?})"
                );
            }
        }
    }

    fn diamond() -> Vec<Vec<usize>> {
        // 0 → {1, 2} → 3
        vec![vec![], vec![0], vec![0], vec![1, 2]]
    }

    #[test]
    fn respects_dependencies_serial_and_parallel() {
        for workers in [1, 2, 4, 16] {
            check_order(&diamond(), workers);
        }
    }

    #[test]
    fn runs_a_real_round_plan() {
        let plan = crate::superblock::schedule::round_plan(5, 2);
        for workers in [1, 3, 8] {
            check_order(&plan.dep_graph(), workers);
        }
    }

    #[test]
    fn empty_graph_is_a_noop() {
        run_tasks(&[], 4, |_| panic!("no tasks to run"));
    }

    #[test]
    fn independent_tasks_all_run() {
        let deps: Vec<Vec<usize>> = (0..50).map(|_| Vec::new()).collect();
        let count = AtomicUsize::new(0);
        run_tasks(&deps, 8, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn chain_executes_in_order() {
        // 0 → 1 → 2 → … → 9: only one task is ever ready, any worker count
        let deps: Vec<Vec<usize>> = (0..10)
            .map(|t| if t == 0 { vec![] } else { vec![t - 1] })
            .collect();
        check_order(&deps, 4);
    }

    #[test]
    fn more_workers_than_tasks() {
        check_order(&diamond(), 64);
    }
}

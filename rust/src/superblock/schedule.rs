//! Pure round plans for the super-blocked schedule.
//!
//! One round of the paper's three-phase decomposition, lifted to the
//! coordinator level: the diagonal super-tile (phase 1) is solved by the
//! orchestrator before the round plan runs, so a plan holds only the
//! phase-2 panel tasks and the phase-3 interior tasks, with explicit
//! dependency edges from each interior tile to the two panel tiles it
//! reads.  Plans are pure data — no threads, no tiles — so the dependency
//! structure is exhaustively testable, and the worker pool ([`super::pool`])
//! can stream interior updates the moment their panels resolve instead of
//! waiting for a whole-phase barrier.

/// One tile update within a round (super-grid coordinates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileOp {
    /// Phase 2, row panel: tile `(round, bj)` relaxed against the final
    /// diagonal tile (`w[i][j] <- min(w[i][j], diag[i][k] + w[k][j])`).
    PanelRow { bj: usize },
    /// Phase 2, column panel: tile `(bi, round)` relaxed against the final
    /// diagonal tile (`w[i][j] <- min(w[i][j], w[i][k] + diag[k][j])`).
    PanelCol { bi: usize },
    /// Phase 3, interior: tile `(bi, bj)` relaxed by the (min, +) product
    /// of its column-panel tile `(bi, round)` and row-panel tile
    /// `(round, bj)`.
    Interior { bi: usize, bj: usize },
}

/// A schedulable tile update plus the plan-local indices it waits on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Task {
    pub op: TileOp,
    /// Indices into the owning plan's task list; always smaller than this
    /// task's own index (plans are emitted in topological order).
    pub deps: Vec<usize>,
}

/// All phase-2/3 work for one round `k` of a `blocks × blocks` super-grid.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    pub round: usize,
    pub blocks: usize,
    pub tasks: Vec<Task>,
}

impl RoundPlan {
    /// Number of phase-2 (panel) tasks: `2 · (blocks − 1)`.
    pub fn panel_tiles(&self) -> usize {
        self.tasks
            .iter()
            .filter(|t| !matches!(t.op, TileOp::Interior { .. }))
            .count()
    }

    /// Number of phase-3 (interior) tasks: `(blocks − 1)²`.
    pub fn interior_tiles(&self) -> usize {
        self.tasks.len() - self.panel_tiles()
    }

    /// Dependency lists, one per task (what [`super::pool::run_tasks`] eats).
    pub fn dep_graph(&self) -> Vec<Vec<usize>> {
        self.tasks.iter().map(|t| t.deps.clone()).collect()
    }
}

/// Build the plan for round `k` of a `blocks × blocks` super-grid.
///
/// Panel tasks come first (no dependencies — the diagonal tile is final
/// when the plan runs); each interior task depends on exactly its column
/// panel `(bi, k)` and row panel `(k, bj)`.
pub fn round_plan(blocks: usize, round: usize) -> RoundPlan {
    assert!(round < blocks, "round {round} out of range for {blocks} blocks");
    let k = round;
    let outer = blocks.saturating_sub(1);
    let mut tasks = Vec::with_capacity(2 * outer + outer * outer);
    // phase 2: panels, indexed so interiors can name them
    let mut row_panel_idx = vec![usize::MAX; blocks];
    let mut col_panel_idx = vec![usize::MAX; blocks];
    for bj in 0..blocks {
        if bj != k {
            row_panel_idx[bj] = tasks.len();
            tasks.push(Task {
                op: TileOp::PanelRow { bj },
                deps: Vec::new(),
            });
        }
    }
    for bi in 0..blocks {
        if bi != k {
            col_panel_idx[bi] = tasks.len();
            tasks.push(Task {
                op: TileOp::PanelCol { bi },
                deps: Vec::new(),
            });
        }
    }
    // phase 3: interiors, each gated on its two panels
    for bi in 0..blocks {
        for bj in 0..blocks {
            if bi != k && bj != k {
                tasks.push(Task {
                    op: TileOp::Interior { bi, bj },
                    deps: vec![col_panel_idx[bi], row_panel_idx[bj]],
                });
            }
        }
    }
    RoundPlan {
        round,
        blocks,
        tasks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_the_paper_decomposition() {
        for blocks in [1usize, 2, 3, 4, 7] {
            for round in 0..blocks {
                let plan = round_plan(blocks, round);
                assert_eq!(plan.panel_tiles(), 2 * (blocks - 1), "blocks={blocks}");
                assert_eq!(plan.interior_tiles(), (blocks - 1) * (blocks - 1));
                assert_eq!(plan.tasks.len(), plan.panel_tiles() + plan.interior_tiles());
            }
        }
    }

    #[test]
    fn single_block_grid_has_no_tile_work() {
        assert!(round_plan(1, 0).tasks.is_empty());
    }

    #[test]
    fn interiors_depend_on_exactly_their_panels() {
        let blocks = 4;
        for round in 0..blocks {
            let plan = round_plan(blocks, round);
            for task in &plan.tasks {
                match task.op {
                    TileOp::PanelRow { bj } => {
                        assert_ne!(bj, round);
                        assert!(task.deps.is_empty());
                    }
                    TileOp::PanelCol { bi } => {
                        assert_ne!(bi, round);
                        assert!(task.deps.is_empty());
                    }
                    TileOp::Interior { bi, bj } => {
                        assert_ne!(bi, round);
                        assert_ne!(bj, round);
                        assert_eq!(task.deps.len(), 2);
                        let dep_ops: Vec<TileOp> =
                            task.deps.iter().map(|&d| plan.tasks[d].op).collect();
                        assert!(dep_ops.contains(&TileOp::PanelCol { bi }), "{dep_ops:?}");
                        assert!(dep_ops.contains(&TileOp::PanelRow { bj }), "{dep_ops:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn plans_are_topologically_ordered() {
        let plan = round_plan(5, 2);
        for (idx, task) in plan.tasks.iter().enumerate() {
            for &d in &task.deps {
                assert!(d < idx, "task {idx} depends forward on {d}");
            }
        }
    }

    #[test]
    fn every_tile_appears_exactly_once() {
        let blocks = 3;
        let plan = round_plan(blocks, 1);
        let mut seen = std::collections::BTreeSet::new();
        for task in &plan.tasks {
            let coords = match task.op {
                TileOp::PanelRow { bj } => (plan.round, bj),
                TileOp::PanelCol { bi } => (bi, plan.round),
                TileOp::Interior { bi, bj } => (bi, bj),
            };
            assert!(seen.insert(coords), "tile {coords:?} scheduled twice");
        }
        // every tile except the diagonal one
        assert_eq!(seen.len(), blocks * blocks - 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn round_must_be_in_range() {
        round_plan(3, 3);
    }
}

//! Per-round accounting for superblock solves.
//!
//! The orchestrator records one [`RoundStats`] per round; the aggregate
//! [`Report`] is what the coordinator feeds into the serving metrics
//! (`superblock_rounds` / `superblock_tiles` counters) and what the benches
//! print when comparing pool widths.

use std::fmt;

/// What one round of the super-blocked schedule did.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundStats {
    pub round: usize,
    /// Seconds spent in the phase-1 diagonal solve (device or CPU).
    pub diag_seconds: f64,
    /// Seconds spent draining the phase-2/3 tile pool.
    pub tile_seconds: f64,
    pub panel_tiles: usize,
    pub interior_tiles: usize,
    /// Summed seconds pool workers spent inside tile bodies this round
    /// (0 unless the solve ran with profiling on).
    pub busy_seconds: f64,
    /// Summed seconds pool workers spent waiting for ready tiles this
    /// round (0 unless profiling was on).
    pub idle_seconds: f64,
    /// Longest dependency chain in this round's tile graph, in tasks
    /// (0 unless profiling was on).
    pub critical_path: usize,
}

/// Aggregate accounting for one superblock solve.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Report {
    /// Request size.
    pub n: usize,
    /// Padded size actually scheduled (`blocks · bucket`).
    pub padded: usize,
    /// Device-bucket tile size.
    pub bucket: usize,
    /// Super-grid width (`padded / bucket`).
    pub blocks: usize,
    /// Pool width used for phase-2/3 tasks.
    pub workers: usize,
    pub rounds: Vec<RoundStats>,
}

impl Report {
    pub fn new(n: usize, padded: usize, bucket: usize, blocks: usize, workers: usize) -> Report {
        Report {
            n,
            padded,
            bucket,
            blocks,
            workers,
            rounds: Vec::with_capacity(blocks),
        }
    }

    pub fn round_count(&self) -> usize {
        self.rounds.len()
    }

    /// Total phase-2 + phase-3 tile updates across all rounds.
    pub fn total_tiles(&self) -> usize {
        self.rounds
            .iter()
            .map(|r| r.panel_tiles + r.interior_tiles)
            .sum()
    }

    /// Total diagonal (phase-1) solves — one per round.
    pub fn diag_solves(&self) -> usize {
        self.rounds.len()
    }

    pub fn diag_seconds(&self) -> f64 {
        self.rounds.iter().map(|r| r.diag_seconds).sum()
    }

    pub fn tile_seconds(&self) -> f64 {
        self.rounds.iter().map(|r| r.tile_seconds).sum()
    }

    /// Total worker-busy seconds across rounds (0 without profiling).
    pub fn busy_seconds(&self) -> f64 {
        self.rounds.iter().map(|r| r.busy_seconds).sum()
    }

    /// Total worker-idle seconds across rounds (0 without profiling).
    pub fn idle_seconds(&self) -> f64 {
        self.rounds.iter().map(|r| r.idle_seconds).sum()
    }

    /// Aggregate pool occupancy: busy / (busy + idle); 1.0 when nothing
    /// was measured (profiling off or no pool work at all).
    pub fn occupancy(&self) -> f64 {
        let busy = self.busy_seconds();
        let total = busy + self.idle_seconds();
        if total == 0.0 {
            1.0
        } else {
            busy / total
        }
    }

    /// Deepest per-round critical path, in tile tasks (0 without
    /// profiling).
    pub fn max_critical_path(&self) -> usize {
        self.rounds
            .iter()
            .map(|r| r.critical_path)
            .max()
            .unwrap_or(0)
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "superblock n={} padded={} bucket={} blocks={} workers={}: \
             {} rounds, {} tiles ({:.3}s diag + {:.3}s tiles)",
            self.n,
            self.padded,
            self.bucket,
            self.blocks,
            self.workers,
            self.round_count(),
            self.total_tiles(),
            self.diag_seconds(),
            self.tile_seconds(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_across_rounds() {
        let mut report = Report::new(1000, 1024, 256, 4, 8);
        for round in 0..4 {
            report.rounds.push(RoundStats {
                round,
                diag_seconds: 0.5,
                tile_seconds: 1.0,
                panel_tiles: 6,
                interior_tiles: 9,
                busy_seconds: 0.75,
                idle_seconds: 0.25,
                critical_path: 2,
            });
        }
        assert_eq!(report.round_count(), 4);
        assert_eq!(report.diag_solves(), 4);
        assert_eq!(report.total_tiles(), 4 * 15);
        assert!((report.diag_seconds() - 2.0).abs() < 1e-12);
        assert!((report.tile_seconds() - 4.0).abs() < 1e-12);
        let line = report.to_string();
        assert!(line.contains("blocks=4"), "{line}");
        assert!(line.contains("60 tiles"), "{line}");
        // occupancy fields aggregate too
        assert!((report.busy_seconds() - 3.0).abs() < 1e-12);
        assert!((report.idle_seconds() - 1.0).abs() < 1e-12);
        assert!((report.occupancy() - 0.75).abs() < 1e-12);
        assert_eq!(report.max_critical_path(), 2);
    }

    #[test]
    fn empty_report_is_zero() {
        let report = Report::new(64, 64, 64, 1, 1);
        assert_eq!(report.total_tiles(), 0);
        assert_eq!(report.round_count(), 0);
        assert_eq!(report.occupancy(), 1.0, "nothing measured, nothing wasted");
        assert_eq!(report.max_critical_path(), 0);
    }
}

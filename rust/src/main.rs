//! `fw-stage` binary: see [`fw_stage::cli`] for the subcommands.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(fw_stage::cli::run(args));
}

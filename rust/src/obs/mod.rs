//! Observability for the serving stack: request-scoped traces, fixed-bucket
//! latency histograms, and leveled structured logging.
//!
//! The paper's speedups came from *measuring* where time went before
//! restructuring the kernel; this module gives the serving stack the same
//! attribution.  Three pieces:
//!
//! * [`trace`] — per-request span trees (decode → route → solve →
//!   cache put → encode, with phase/round breakdown inside the solve)
//!   journaled into a bounded ring buffer and served over the wire
//!   (`{"type":"trace"}`, or echoed inline via the request `"trace"` flag).
//! * [`hist`] — log-scaled-bucket latency histograms keyed
//!   `(source, objective)` in the metrics: exact, mergeable, O(1) memory,
//!   with a Prometheus text exposition and a parser that round-trips it.
//! * [`log`] — one JSON line per server-side error on stderr, leveled by a
//!   process-global `--log-level`.
//!
//! **Bitwise neutrality.** Every hook reads wall-clock time *around*
//! numeric sections (or counts scheduler events); none reorders a float
//! operation.  Traced and untraced solves are therefore bitwise equal —
//! the conformance suite pins this, and [`ObsConfig::enabled`] makes the
//! hooks one branch when off.

pub mod hist;
pub mod log;
pub mod trace;

pub use hist::Histogram;
pub use trace::{Span, TraceJournal, TraceRecord};

/// Observability configuration for a coordinator.
#[derive(Clone, Copy, Debug)]
pub struct ObsConfig {
    /// Master switch: when false, no spans are built, no traces are
    /// journaled, and the profiled solver twins are never chosen.  The
    /// per-`(source, objective)` histograms stay on either way — they are
    /// O(1) counters on the metrics mutex the request already takes.
    pub enabled: bool,
    /// Trace-journal ring size (finished request traces retained for
    /// `{"type":"trace"}`).
    pub journal_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: true,
            journal_capacity: 256,
        }
    }
}

impl ObsConfig {
    /// Tracing fully off: no span assembly, empty journal.
    pub fn disabled() -> ObsConfig {
        ObsConfig {
            enabled: false,
            journal_capacity: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_and_disable() {
        let on = ObsConfig::default();
        assert!(on.enabled);
        assert!(on.journal_capacity > 0);
        let off = ObsConfig::disabled();
        assert!(!off.enabled);
        assert_eq!(off.journal_capacity, 0);
    }
}

//! Minimal leveled structured logging for the serving stack.
//!
//! One machine-parsable JSON line per event on stderr — enough for the
//! server to stop silently dropping connection errors and malformed
//! requests, without pulling a logging crate into the vendored set.  The
//! level is a process-global atomic (`--log-level` on `fw-stage serve`);
//! the default is [`Level::Warn`], so a healthy server stays quiet.
//!
//! ```text
//! {"addr":"127.0.0.1:51724","error":"connection reset","event":"conn_error","level":"warn"}
//! ```

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::json::Json;

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `--log-level` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// Set the process-global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current process-global log level.
pub fn level() -> Level {
    Level::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Whether events at `l` are currently emitted (one relaxed atomic load —
/// cheap enough for any hot path).
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Emit one structured line to stderr: `event` and `level` keys plus the
/// caller's fields, serialized by the deterministic sorted-key codec.
pub fn log(l: Level, event: &str, fields: Vec<(&str, Json)>) {
    if !enabled(l) {
        return;
    }
    let mut obj = vec![
        ("event", Json::str(event)),
        ("level", Json::str(l.name())),
    ];
    obj.extend(fields);
    eprintln!("{}", Json::obj(obj));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_order_and_gate() {
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Debug);
        // exercise the global gate across every level, restoring the
        // default afterwards (tests share the process-global)
        let prior = level();
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
        log(Level::Debug, "selftest", vec![("k", Json::num(1.0))]);
        set_level(prior);
        assert_eq!(level(), prior);
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.name()), Some(l));
            assert_eq!(Level::from_u8(l as u8), l);
        }
    }
}

//! Request-scoped span trees and the bounded trace journal.
//!
//! Every served request gets one [`Span`] tree — decode → route decision
//! (with the router's reason) → tier solve (with phase/round breakdown) →
//! cache put → encode — assembled by the server and coordinator as the
//! request flows through them.  Finished trees are pushed into a
//! [`TraceJournal`]: a mutex-guarded ring buffer of `Arc`'d records, so
//! recording is one short critical section and readers never copy span
//! trees.  The journal lock recovers from poisoning
//! ([`crate::util::sync`]) — observability must never become the reason
//! serving stops.  The journal is served over the wire by the `{"type":"trace"}`
//! request and echoed inline when a client sets `"trace": true`.
//!
//! Spans carry **timing read outside the numeric kernels** only: the
//! solvers' profiled twins take `Instant` readings *between* phases, never
//! reordering a float op, so traced and untraced solves are bitwise equal
//! (pinned by the conformance suite).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// One node of a request trace: a named, timed section with string notes
/// and child spans.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub name: String,
    /// Wall-clock seconds spent in this section (children included).
    pub seconds: f64,
    /// Key/value annotations (route reason, tier source, tile counts, …).
    pub notes: Vec<(String, String)>,
    pub children: Vec<Span>,
}

impl Span {
    pub fn new(name: impl Into<String>) -> Span {
        Span {
            name: name.into(),
            seconds: 0.0,
            notes: Vec::new(),
            children: Vec::new(),
        }
    }

    /// Attach a key/value note.
    pub fn note(&mut self, key: &str, value: impl Into<String>) {
        self.notes.push((key.to_string(), value.into()));
    }

    /// Attach a finished child span.
    pub fn child(&mut self, child: Span) {
        self.children.push(child);
    }

    /// First note value for `key`, if any (test/display helper).
    pub fn note_value(&self, key: &str) -> Option<&str> {
        self.notes
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// First child (depth-first) named `name`, if any.
    pub fn find(&self, name: &str) -> Option<&Span> {
        for c in &self.children {
            if c.name == name {
                return Some(c);
            }
            if let Some(hit) = c.find(name) {
                return Some(hit);
            }
        }
        None
    }

    /// Compact tree-shape signature, e.g. `request(decode,route,solve(
    /// phase1,phase2,phase3),cache_put,encode)` — timing-free, so it is
    /// deterministic for a replayed request and pinnable in tests.
    pub fn shape(&self) -> String {
        if self.children.is_empty() {
            return self.name.clone();
        }
        let inner: Vec<String> = self.children.iter().map(Span::shape).collect();
        format!("{}({})", self.name, inner.join(","))
    }

    /// JSON form: `{"name":…,"seconds":…,"notes":{…},"spans":[…]}` (notes
    /// and spans omitted when empty; keys sort deterministically).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            ("seconds", Json::Num(self.seconds)),
        ];
        if !self.notes.is_empty() {
            fields.push((
                "notes",
                Json::Obj(
                    self.notes
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                        .collect(),
                ),
            ));
        }
        if !self.children.is_empty() {
            fields.push((
                "spans",
                Json::Arr(self.children.iter().map(Span::to_json).collect()),
            ));
        }
        Json::obj(fields)
    }
}

/// One journaled request trace.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    pub id: u64,
    /// Tier that served the request (`Source::name()`).
    pub source: String,
    /// Objective name (`shortest`, `bottleneck`, …).
    pub objective: String,
    pub n: usize,
    pub root: Span,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("n", Json::Num(self.n as f64)),
            ("objective", Json::str(self.objective.clone())),
            ("source", Json::str(self.source.clone())),
            ("root", self.root.to_json()),
        ])
    }
}

/// Bounded ring buffer of finished traces.  Recording takes the mutex for
/// one push/pop; records are `Arc`'d so serving the journal never clones a
/// span tree.  Capacity 0 disables retention (records pass through).
#[derive(Debug)]
pub struct TraceJournal {
    capacity: usize,
    inner: Mutex<VecDeque<Arc<TraceRecord>>>,
}

impl TraceJournal {
    pub fn new(capacity: usize) -> TraceJournal {
        TraceJournal {
            capacity,
            inner: Mutex::new(VecDeque::new()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Journal one finished trace, evicting the oldest past capacity.
    /// Returns the shared record (the server echoes it when the client
    /// asked for an inline trace).
    pub fn record(&self, record: TraceRecord) -> Arc<TraceRecord> {
        let record = Arc::new(record);
        if self.capacity > 0 {
            let mut q = crate::recover_lock!(&self.inner, "trace.journal");
            q.push_back(Arc::clone(&record));
            while q.len() > self.capacity {
                q.pop_front();
            }
        }
        record
    }

    /// Last `k` traces, newest first, optionally filtered by tier source
    /// and/or objective name.
    pub fn last(
        &self,
        k: usize,
        source: Option<&str>,
        objective: Option<&str>,
    ) -> Vec<Arc<TraceRecord>> {
        let q = crate::recover_lock!(&self.inner, "trace.journal");
        q.iter()
            .rev()
            .filter(|r| source.is_none_or(|s| r.source == s))
            .filter(|r| objective.is_none_or(|o| r.objective == o))
            .take(k)
            .cloned()
            .collect()
    }

    pub fn len(&self) -> usize {
        crate::recover_lock!(&self.inner, "trace.journal").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(id: u64, source: &str, objective: &str) -> TraceRecord {
        let mut root = Span::new("request");
        root.seconds = 0.5;
        let mut solve = Span::new("solve");
        solve.note("source", source);
        root.child(solve);
        TraceRecord {
            id,
            source: source.into(),
            objective: objective.into(),
            n: 64,
            root,
        }
    }

    #[test]
    fn span_shape_and_lookup() {
        let mut root = Span::new("request");
        root.child(Span::new("decode"));
        let mut solve = Span::new("solve");
        solve.child(Span::new("phase1"));
        solve.child(Span::new("phase2"));
        root.child(solve);
        root.child(Span::new("encode"));
        assert_eq!(root.shape(), "request(decode,solve(phase1,phase2),encode)");
        assert!(root.find("phase2").is_some());
        assert!(root.find("phase9").is_none());
    }

    #[test]
    fn span_json_omits_empty_fields_and_roundtrips() {
        let mut s = Span::new("route");
        s.seconds = 1.25e-6;
        s.note("reason", "n <= cpu_threshold");
        let j = s.to_json();
        assert_eq!(j.get("name").as_str(), Some("route"));
        assert_eq!(j.get("notes").get("reason").as_str(), Some("n <= cpu_threshold"));
        assert!(j.get("spans").is_null());
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn journal_is_a_ring() {
        let journal = TraceJournal::new(3);
        for id in 0..5 {
            journal.record(record(id, "cpu", "shortest"));
        }
        assert_eq!(journal.len(), 3);
        let got = journal.last(10, None, None);
        let ids: Vec<u64> = got.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![4, 3, 2], "newest first, oldest evicted");
    }

    #[test]
    fn journal_filters_by_source_and_objective() {
        let journal = TraceJournal::new(16);
        journal.record(record(1, "cpu", "shortest"));
        journal.record(record(2, "superblock", "shortest"));
        journal.record(record(3, "cpu", "bottleneck"));
        assert_eq!(journal.last(10, Some("cpu"), None).len(), 2);
        assert_eq!(journal.last(10, None, Some("shortest")).len(), 2);
        let both = journal.last(10, Some("cpu"), Some("bottleneck"));
        assert_eq!(both.len(), 1);
        assert_eq!(both[0].id, 3);
        assert_eq!(journal.last(1, Some("cpu"), None)[0].id, 3);
    }

    #[test]
    fn journal_survives_a_poisoned_lock() {
        let journal = TraceJournal::new(4);
        journal.record(record(1, "cpu", "shortest"));
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = journal.inner.lock().unwrap();
            panic!("poisoning the journal lock (expected by this test)");
        }));
        assert!(caught.is_err());
        assert!(journal.inner.is_poisoned());
        journal.record(record(2, "cpu", "shortest"));
        assert_eq!(journal.len(), 2, "recording continues after the poison");
        let ids: Vec<u64> = journal.last(10, None, None).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 1], "pre-poison records survive");
    }

    #[test]
    fn zero_capacity_journal_passes_through() {
        let journal = TraceJournal::new(0);
        let rec = journal.record(record(7, "cache", "shortest"));
        assert_eq!(rec.id, 7);
        assert!(journal.is_empty());
    }
}

//! Fixed-bucket log-scaled latency histograms.
//!
//! The serving metrics need per-`(source, objective)` latency distributions
//! that survive a long-running coordinator: exact bucket counts, mergeable,
//! and O(1) memory — unlike [`crate::util::stats::Samples`], which retains
//! raw values.  Buckets are powers of two over seconds:
//!
//! ```text
//!   bound(i) = 1e-6 · 2^i      for i in 0..28   (1 µs … ~134 s)
//! ```
//!
//! plus one overflow bucket.  Doubling bounds are exact in f64 (only the
//! 1e-6 anchor rounds, identically for every bound), so bucket boundaries
//! are deterministic and pinnable: `observe(2e-6)` always lands in bucket 1
//! under the `x <= bound` (Prometheus `le`) convention.
//!
//! [`render_series`] emits one histogram in the Prometheus text exposition
//! format (cumulative `_bucket{le=…}` lines plus `_sum`/`_count`);
//! [`parse_exposition`] reads that format back — the round-trip is the
//! scrape-safety gate in the tests and the serve_demo smoke.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Number of finite bucket bounds (the last array slot is the overflow
/// bucket).
pub const FINITE_BOUNDS: usize = 28;

/// A fixed-memory latency histogram (seconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    /// Per-bucket counts; `counts[FINITE_BOUNDS]` is the overflow bucket.
    counts: [u64; FINITE_BOUNDS + 1],
    count: u64,
    sum: f64,
    sum_sq: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; FINITE_BOUNDS + 1],
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Upper bound (inclusive, `le`) of finite bucket `i`.
    pub fn bound(i: usize) -> f64 {
        debug_assert!(i < FINITE_BOUNDS);
        1e-6 * (1u64 << i) as f64
    }

    /// Index of the bucket an observation falls into (`x <= bound`, first
    /// match; everything else — including NaN — overflows).
    pub fn bucket_index(x: f64) -> usize {
        for i in 0..FINITE_BOUNDS {
            if x <= Self::bound(i) {
                return i;
            }
        }
        FINITE_BOUNDS
    }

    /// Record one observation (seconds).
    pub fn observe(&mut self, x: f64) {
        self.counts[Self::bucket_index(x)] += 1;
        self.count += 1;
        self.sum += x;
        self.sum_sq += x * x;
    }

    /// Add another histogram's observations into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Raw per-bucket counts (last slot = overflow).
    pub fn bucket_counts(&self) -> &[u64; FINITE_BOUNDS + 1] {
        &self.counts
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub fn stddev(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        let n = self.count as f64;
        let var = (self.sum_sq - self.sum * self.sum / n) / (n - 1.0);
        var.max(0.0).sqrt()
    }

    /// Upper-bound quantile estimate: the `le` bound of the bucket holding
    /// the `q`-th observation (`q` in [0, 1]).  The estimate never
    /// undershoots the true quantile — the right bias for latency alerts.
    ///
    /// Edge cases are **pinned**, never a panic or a silent 0
    /// (`quantile_edge_cases_are_pinned`):
    ///
    /// * empty histogram → NaN for every `q` (downstream renders it as
    ///   `"-"`/`null`, keeping "no data" distinguishable from "fast");
    /// * all mass in the overflow bucket (observations past the largest
    ///   finite bound, ~134 s) → `+inf` for every `q` — the honest answer,
    ///   since the histogram only knows the value exceeded every bound;
    /// * `q` outside [0, 1] is a caller bug and asserts.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile {q}");
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return if i < FINITE_BOUNDS {
                    Self::bound(i)
                } else {
                    f64::INFINITY
                };
            }
        }
        f64::INFINITY
    }

    /// Compact summary for the `stats` snapshot (non-finite values render
    /// as JSON null via the codec).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum_s", Json::Num(self.sum)),
            ("mean_s", Json::Num(self.mean())),
            ("p50_s", Json::Num(self.quantile(0.5))),
            ("p95_s", Json::Num(self.quantile(0.95))),
            ("p99_s", Json::Num(self.quantile(0.99))),
        ])
    }

    /// A `perf::BenchResult::to_json`-shaped record, so live histograms
    /// land in the same `BENCH_<name>.json` trajectory as CI bench runs.
    pub fn to_bench_json(&self, name: &str) -> Json {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("mean_s", Json::Num(self.mean())),
            ("median_s", Json::Num(self.quantile(0.5))),
            ("stddev_s", Json::Num(self.stddev())),
            ("samples", Json::Num(self.count as f64)),
        ])
    }
}

/// Format an exposition float the way Prometheus expects (shortest
/// round-tripping decimal; Rust's `Display` for f64 guarantees this).
fn fmt_f64(x: f64) -> String {
    if x.is_infinite() {
        if x > 0.0 {
            "+Inf".into()
        } else {
            "-Inf".into()
        }
    } else {
        format!("{x}")
    }
}

/// Escape a string for use as a Prometheus label *value* (the part inside
/// the double quotes).  The exposition format reserves exactly three
/// characters there: backslash, double quote, and newline.  Everything
/// else — including `,`, `{`, `}`, and spaces — is legal verbatim.
///
/// Callers rendering label bodies (e.g. the metrics exposition) must pass
/// every dynamic value through this, or a hostile objective/source name
/// containing `"` breaks the line grammar and poisons the whole scrape.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Split a label body on the `,` separators *between* `name="value"` pairs,
/// honouring quoting: commas inside a quoted value (and escaped quotes
/// within it) do not split.  A naive `split(',')` corrupts any series whose
/// label values contain commas — legal after [`escape_label_value`].
fn split_labels(body: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let (mut start, mut in_quotes, mut escaped) = (0usize, false, false);
    for (i, b) in body.bytes().enumerate() {
        if escaped {
            escaped = false;
        } else if in_quotes {
            match b {
                b'\\' => escaped = true,
                b'"' => in_quotes = false,
                _ => {}
            }
        } else {
            match b {
                b'"' => in_quotes = true,
                b',' => {
                    parts.push(&body[start..i]);
                    start = i + 1;
                }
                _ => {}
            }
        }
    }
    parts.push(&body[start..]);
    parts
}

/// Append one histogram as Prometheus text-exposition lines.
///
/// `labels` is the pre-rendered label body **without** `le`, e.g.
/// `objective="shortest",source="cpu"` (may be empty); dynamic values in
/// it must already be [`escape_label_value`]-escaped.  Bucket lines are
/// cumulative, as the format requires.
pub fn render_series(out: &mut String, metric: &str, labels: &str, h: &Histogram) {
    let sep = if labels.is_empty() { "" } else { "," };
    let mut cum = 0u64;
    for (i, &c) in h.bucket_counts().iter().enumerate() {
        cum += c;
        let le = if i < FINITE_BOUNDS {
            fmt_f64(Histogram::bound(i))
        } else {
            "+Inf".into()
        };
        out.push_str(&format!(
            "{metric}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}\n"
        ));
    }
    let brace = if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    };
    out.push_str(&format!("{metric}_sum{brace} {}\n", fmt_f64(h.sum())));
    out.push_str(&format!("{metric}_count{brace} {}\n", h.count()));
}

/// Parse Prometheus text exposition produced by [`render_series`] back
/// into histograms, keyed `metric{labels}` (labels without `le`, in the
/// order written).  Reconstructs per-bucket counts from the cumulative
/// lines; `sum_sq` is not part of the wire format and comes back as 0.
pub fn parse_exposition(text: &str) -> Result<BTreeMap<String, Histogram>, String> {
    struct Acc {
        cum: [Option<u64>; FINITE_BOUNDS + 1],
        sum: Option<f64>,
        count: Option<u64>,
    }
    let mut accs: BTreeMap<String, Acc> = BTreeMap::new();
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("malformed exposition line {line:?}"))?;
        let (name, labels) = match head.split_once('{') {
            Some((n, rest)) => (
                n,
                rest.strip_suffix('}')
                    .ok_or_else(|| format!("unterminated labels in {line:?}"))?,
            ),
            None => (head, ""),
        };
        if let Some(base) = name.strip_suffix("_bucket") {
            let mut le = None;
            let mut kept: Vec<&str> = Vec::new();
            for part in split_labels(labels).into_iter().filter(|p| !p.is_empty()) {
                match part.strip_prefix("le=\"").and_then(|v| v.strip_suffix('"')) {
                    Some(v) => le = Some(v),
                    None => kept.push(part),
                }
            }
            let le = le.ok_or_else(|| format!("bucket line without le: {line:?}"))?;
            let idx = if le == "+Inf" {
                FINITE_BOUNDS
            } else {
                let bound: f64 = le.parse().map_err(|_| format!("bad le {le:?}"))?;
                (0..FINITE_BOUNDS)
                    .find(|&i| Histogram::bound(i) == bound)
                    .ok_or_else(|| format!("le {le:?} is not a known bound"))?
            };
            let cum: u64 = value.parse().map_err(|_| format!("bad count {value:?}"))?;
            let key = format!("{base}{{{}}}", kept.join(","));
            accs.entry(key)
                .or_insert_with(|| Acc {
                    cum: [None; FINITE_BOUNDS + 1],
                    sum: None,
                    count: None,
                })
                .cum[idx] = Some(cum);
        } else if let Some(base) = name.strip_suffix("_sum") {
            let key = format!("{base}{{{labels}}}");
            let sum: f64 = value.parse().map_err(|_| format!("bad sum {value:?}"))?;
            accs.entry(key)
                .or_insert_with(|| Acc {
                    cum: [None; FINITE_BOUNDS + 1],
                    sum: None,
                    count: None,
                })
                .sum = Some(sum);
        } else if let Some(base) = name.strip_suffix("_count") {
            let key = format!("{base}{{{labels}}}");
            let count: u64 = value.parse().map_err(|_| format!("bad count {value:?}"))?;
            accs.entry(key)
                .or_insert_with(|| Acc {
                    cum: [None; FINITE_BOUNDS + 1],
                    sum: None,
                    count: None,
                })
                .count = Some(count);
        }
        // other metric families (plain counters) pass through unparsed
    }
    let mut out = BTreeMap::new();
    for (key, acc) in accs {
        let mut counts = [0u64; FINITE_BOUNDS + 1];
        let mut prev = 0u64;
        for (i, slot) in acc.cum.iter().enumerate() {
            let cum = slot.ok_or_else(|| format!("{key}: missing bucket {i}"))?;
            counts[i] = cum
                .checked_sub(prev)
                .ok_or_else(|| format!("{key}: non-monotone cumulative buckets"))?;
            prev = cum;
        }
        let count = acc.count.ok_or_else(|| format!("{key}: missing _count"))?;
        if count != prev {
            return Err(format!("{key}: _count {count} != +Inf bucket {prev}"));
        }
        out.insert(
            key,
            Histogram {
                counts,
                count,
                sum: acc.sum.ok_or_else(|| format!("{key}: missing _sum"))?,
                sum_sq: 0.0,
            },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_pinned() {
        // doubling bounds are exact, so le-semantics placement is exact
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(1e-6), 0); // x <= bound inclusive
        assert_eq!(Histogram::bucket_index(2e-6), 1);
        assert_eq!(Histogram::bucket_index(1.5e-6), 1);
        assert_eq!(Histogram::bucket_index(Histogram::bound(10)), 10);
        assert_eq!(
            Histogram::bucket_index(Histogram::bound(FINITE_BOUNDS - 1)),
            FINITE_BOUNDS - 1
        );
        // past the largest finite bound (~134 s) → overflow
        assert_eq!(Histogram::bucket_index(1000.0), FINITE_BOUNDS);
        assert_eq!(Histogram::bucket_index(f64::NAN), FINITE_BOUNDS);
    }

    #[test]
    fn bounds_double_exactly() {
        for i in 1..FINITE_BOUNDS {
            assert_eq!(Histogram::bound(i), 2.0 * Histogram::bound(i - 1));
        }
        assert_eq!(Histogram::bound(0), 1e-6);
    }

    #[test]
    fn observe_and_summarize() {
        let mut h = Histogram::new();
        assert!(h.mean().is_nan());
        assert!(h.quantile(0.5).is_nan());
        for _ in 0..9 {
            h.observe(1e-3);
        }
        h.observe(1.0);
        assert_eq!(h.count(), 10);
        assert!((h.sum() - (9e-3 + 1.0)).abs() < 1e-12);
        // 1e-3 lands in bucket 10 (bound 1.024e-3 ≥ 1e-3 > 5.12e-4)
        assert_eq!(Histogram::bucket_index(1e-3), 10);
        assert_eq!(h.quantile(0.5), Histogram::bound(10));
        // rank 10 (p100) is the single 1.0s observation: bucket bound 1.048576
        assert_eq!(h.quantile(1.0), Histogram::bound(20));
        assert!(h.stddev() > 0.0);
    }

    #[test]
    fn merge_adds_counts_and_sums() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.observe(1e-4);
        b.observe(1e-2);
        b.observe(5.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.sum() - (1e-4 + 1e-2 + 5.0)).abs() < 1e-12);
        let expect = [1e-4, 1e-2, 5.0].map(Histogram::bucket_index);
        for idx in expect {
            assert!(a.bucket_counts()[idx] >= 1);
        }
    }

    #[test]
    fn exposition_roundtrips() {
        let mut h = Histogram::new();
        for x in [1e-6, 2e-6, 3e-4, 0.25, 7.5, 500.0] {
            h.observe(x);
        }
        let mut text = String::new();
        render_series(
            &mut text,
            "fw_request_seconds",
            "objective=\"shortest\",source=\"cpu\"",
            &h,
        );
        let parsed = parse_exposition(&text).unwrap();
        assert_eq!(parsed.len(), 1);
        let key = "fw_request_seconds{objective=\"shortest\",source=\"cpu\"}";
        let back = parsed.get(key).expect("series keyed by labels");
        assert_eq!(back.bucket_counts(), h.bucket_counts());
        assert_eq!(back.count(), h.count());
        assert_eq!(back.sum(), h.sum()); // Display round-trips f64 exactly
    }

    #[test]
    fn exposition_roundtrips_without_labels() {
        let mut h = Histogram::new();
        h.observe(0.5);
        let mut text = String::new();
        render_series(&mut text, "m", "", &h);
        assert!(text.contains("m_bucket{le=\"+Inf\"} 1\n"));
        let parsed = parse_exposition(&text).unwrap();
        assert_eq!(parsed.get("m{}").unwrap().count(), 1);
    }

    #[test]
    fn parse_rejects_inconsistent_series() {
        let mut text = String::new();
        render_series(&mut text, "m", "", &Histogram::new());
        let broken = text.replace("m_count 0", "m_count 5");
        assert!(parse_exposition(&broken).is_err());
    }

    #[test]
    fn quantile_edge_cases_are_pinned() {
        // empty → NaN for every q, never 0 or a panic
        let empty = Histogram::new();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert!(empty.quantile(q).is_nan(), "empty q={q}");
        }
        // all mass past the largest finite bound → +inf for every q
        let mut over = Histogram::new();
        for _ in 0..5 {
            over.observe(1e9);
        }
        assert_eq!(over.bucket_counts()[FINITE_BOUNDS], 5);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(over.quantile(q), f64::INFINITY, "overflow q={q}");
        }
        // mixed mass: high quantiles hit the overflow bucket, low ones don't
        let mut mixed = Histogram::new();
        mixed.observe(1e-3);
        mixed.observe(1e9);
        assert!(mixed.quantile(0.5).is_finite());
        assert_eq!(mixed.quantile(1.0), f64::INFINITY);
    }

    #[test]
    fn quantile_rejects_out_of_range_q() {
        let h = Histogram::new();
        assert!(std::panic::catch_unwind(|| h.quantile(1.5)).is_err());
        assert!(std::panic::catch_unwind(|| h.quantile(-0.1)).is_err());
    }

    #[test]
    fn escape_label_value_covers_reserved_chars() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\"b"), "a\\\"b");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("a\nb"), "a\\nb");
        // commas, braces, spaces are legal inside quoted values: untouched
        assert_eq!(escape_label_value("a,b {c}"), "a,b {c}");
    }

    #[test]
    fn split_labels_honours_quoting() {
        assert_eq!(
            split_labels("a=\"x,y\",b=\"p\\\"q\",le=\"+Inf\""),
            vec!["a=\"x,y\"", "b=\"p\\\"q\"", "le=\"+Inf\""]
        );
        assert_eq!(split_labels(""), vec![""]);
    }

    #[test]
    fn hostile_label_values_roundtrip() {
        // a source name abusing every reserved/tricky character: quote,
        // backslash, newline, comma, braces, space
        let hostile = "cp\"u\\x\ny,{z} w";
        let labels = format!(
            "objective=\"shortest\",source=\"{}\"",
            escape_label_value(hostile)
        );
        let mut h = Histogram::new();
        h.observe(1e-3);
        h.observe(0.25);
        let mut text = String::new();
        render_series(&mut text, "fw_request_seconds", &labels, &h);
        // escaping keeps the exposition one-line-per-sample
        for line in text.lines() {
            assert!(line.ends_with(|c: char| c.is_ascii_digit()), "{line:?}");
        }
        let parsed = parse_exposition(&text).unwrap();
        assert_eq!(parsed.len(), 1, "hostile labels split the series");
        let key = format!("fw_request_seconds{{{labels}}}");
        let back = parsed.get(&key).expect("series keyed by escaped labels");
        assert_eq!(back.bucket_counts(), h.bucket_counts());
        assert_eq!(back.count(), h.count());
    }

    #[test]
    fn bench_json_matches_bench_result_schema() {
        let mut h = Histogram::new();
        h.observe(0.01);
        h.observe(0.02);
        let j = h.to_bench_json("serve/cpu/shortest");
        for key in ["name", "mean_s", "median_s", "stddev_s", "samples"] {
            assert!(!j.get(key).is_null(), "missing {key}");
        }
        assert_eq!(j.get("samples").as_f64(), Some(2.0));
    }
}

//! Artifact manifest: discovery and validation of the AOT outputs.
//!
//! `artifacts/manifest.json` is written by `python/compile/aot.py` and is
//! the contract between the build-time Python layer and this runtime: it
//! names every HLO-text file and the (variant, n, tile, kchunk, dtype)
//! it was lowered for.  The Rust side never guesses shapes — everything is
//! validated against this manifest.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Manifest version this runtime understands.
pub const SUPPORTED_VERSION: usize = 2;

/// Conventional artifact directory relative to the current working
/// directory: `artifacts/` when launched from the crate root (where
/// `make artifacts` lands them), `rust/artifacts/` from the repository
/// root.  Falls back to `artifacts` so error messages point at the
/// conventional location.
pub fn discover_dir() -> PathBuf {
    for candidate in ["artifacts", "rust/artifacts"] {
        let dir = PathBuf::from(candidate);
        if dir.join("manifest.json").exists() {
            return dir;
        }
    }
    PathBuf::from("artifacts")
}

/// One AOT-compiled program.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    /// Model variant: "naive" | "blocked" | "staged".
    pub variant: String,
    /// Problem size (matrix is n × n).
    pub n: usize,
    pub tile: usize,
    /// k-chunk for staged variants (None otherwise).
    pub kchunk: Option<usize>,
    /// Absolute path to the HLO text.
    pub path: PathBuf,
    /// Size in bytes (sanity check against the file on disk).
    pub bytes: usize,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub tile: usize,
    pub entries: Vec<ArtifactEntry>,
    dir: PathBuf,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (artifact paths resolved relative to `dir`).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest.json is not valid JSON")?;
        let version = root
            .get("version")
            .as_usize()
            .context("manifest missing 'version'")?;
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version}, this runtime supports {SUPPORTED_VERSION}");
        }
        let tile = root.get("tile").as_usize().context("manifest missing 'tile'")?;
        let arr = root
            .get("artifacts")
            .as_arr()
            .context("manifest missing 'artifacts'")?;
        let mut entries = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let name = e
                .get("name")
                .as_str()
                .with_context(|| format!("artifact[{i}] missing 'name'"))?
                .to_string();
            let variant = e
                .get("variant")
                .as_str()
                .with_context(|| format!("artifact[{i}] missing 'variant'"))?
                .to_string();
            let n = e
                .get("n")
                .as_usize()
                .with_context(|| format!("artifact[{i}] missing 'n'"))?;
            let dtype = e.get("dtype").as_str().unwrap_or("f32");
            if dtype != "f32" {
                bail!("artifact {name}: unsupported dtype {dtype}");
            }
            let shape = e.get("input_shape");
            let shape = shape.as_arr().unwrap_or(&[]);
            if shape.len() != 2
                || shape[0].as_usize() != Some(n)
                || shape[1].as_usize() != Some(n)
            {
                bail!("artifact {name}: input_shape does not match n={n}");
            }
            entries.push(ArtifactEntry {
                path: dir.join(&name),
                name,
                variant,
                n,
                tile: e.get("tile").as_usize().unwrap_or(tile),
                kchunk: e.get("kchunk").as_usize(),
                bytes: e.get("bytes").as_usize().unwrap_or(0),
            });
        }
        if entries.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Manifest {
            tile,
            entries,
            dir: dir.to_path_buf(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The entry for (variant, n), if lowered.
    pub fn find(&self, variant: &str, n: usize) -> Option<&ArtifactEntry> {
        // prefer the default kchunk (ablation artifacts carry a _m tag name)
        self.entries
            .iter()
            .filter(|e| e.variant == variant && e.n == n)
            .min_by_key(|e| e.name.len())
    }

    /// All sizes available for a variant, ascending.
    pub fn sizes_for(&self, variant: &str) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.variant == variant)
            .map(|e| e.n)
            .collect();
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }

    /// Smallest lowered size ≥ `n` for a variant (the padding bucket the
    /// coordinator routes to).
    pub fn bucket_for(&self, variant: &str, n: usize) -> Option<usize> {
        self.sizes_for(variant).into_iter().find(|&s| s >= n)
    }

    /// Distinct variants present.
    pub fn variants(&self) -> Vec<String> {
        let mut set: BTreeMap<&str, ()> = BTreeMap::new();
        for e in &self.entries {
            set.insert(&e.variant, ());
        }
        set.into_keys().map(str::to_string).collect()
    }

    /// Verify every artifact file exists (and matches recorded size).
    pub fn check_files(&self) -> Result<()> {
        for e in &self.entries {
            let meta = fs::metadata(&e.path)
                .with_context(|| format!("artifact file missing: {}", e.path.display()))?;
            if e.bytes != 0 && meta.len() as usize != e.bytes {
                bail!(
                    "artifact {} is {} bytes on disk, manifest says {}",
                    e.name,
                    meta.len(),
                    e.bytes
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 2, "tile": 32, "kchunk": 8, "jax_version": "0.8.2",
      "artifacts": [
        {"name": "apsp_staged_n64.hlo.txt", "variant": "staged", "n": 64,
         "tile": 32, "kchunk": 8, "dtype": "f32",
         "input_shape": [64, 64], "output_shape": [64, 64], "bytes": 100},
        {"name": "apsp_staged_n128.hlo.txt", "variant": "staged", "n": 128,
         "tile": 32, "kchunk": 8, "dtype": "f32",
         "input_shape": [128, 128], "output_shape": [128, 128], "bytes": 100},
        {"name": "apsp_staged_n128_m16.hlo.txt", "variant": "staged", "n": 128,
         "tile": 32, "kchunk": 16, "dtype": "f32",
         "input_shape": [128, 128], "output_shape": [128, 128], "bytes": 100},
        {"name": "apsp_naive_n64.hlo.txt", "variant": "naive", "n": 64,
         "tile": 32, "kchunk": null, "dtype": "f32",
         "input_shape": [64, 64], "output_shape": [64, 64], "bytes": 100}
      ]
    }"#;

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = sample();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.tile, 32);
        assert_eq!(m.variants(), vec!["naive".to_string(), "staged".to_string()]);
    }

    #[test]
    fn find_prefers_default_kchunk() {
        let m = sample();
        let e = m.find("staged", 128).unwrap();
        assert_eq!(e.name, "apsp_staged_n128.hlo.txt");
        assert_eq!(e.kchunk, Some(8));
    }

    #[test]
    fn bucket_rounds_up() {
        let m = sample();
        assert_eq!(m.bucket_for("staged", 1), Some(64));
        assert_eq!(m.bucket_for("staged", 64), Some(64));
        assert_eq!(m.bucket_for("staged", 65), Some(128));
        assert_eq!(m.bucket_for("staged", 129), None);
        assert_eq!(m.bucket_for("missing", 1), None);
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 2", "\"version\": 99");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let bad = SAMPLE.replace("\"input_shape\": [64, 64]", "\"input_shape\": [64, 32]");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_empty() {
        let err = Manifest::parse(
            r#"{"version": 2, "tile": 32, "artifacts": []}"#,
            Path::new("/tmp"),
        );
        assert!(err.is_err());
    }

    #[test]
    fn paths_resolved_against_dir() {
        let m = sample();
        assert_eq!(
            m.entries[0].path,
            Path::new("/tmp/artifacts/apsp_staged_n64.hlo.txt")
        );
    }
}

//! Executor pool: lazily-compiled, cached executables keyed by
//! (variant, n), shared across coordinator worker threads.
//!
//! Compilation is the expensive step (XLA optimizes the whole while-loop
//! nest), so executables are compiled once on first use and retained.  The
//! pool also owns the padding/truncation logic: a request for any n is
//! routed to the smallest lowered bucket ≥ n, padded with unreachable
//! vertices (provably distance-preserving — `DistMatrix::padded`), solved,
//! and truncated back.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::pjrt::{Executable, PjrtRuntime};
use crate::graph::DistMatrix;

/// A compiled model handle.
pub struct LoadedModel {
    pub variant: String,
    pub n: usize,
    exe: Executable,
}

impl LoadedModel {
    /// Solve an exactly-n-sized matrix.
    pub fn run(&self, w: &DistMatrix) -> Result<DistMatrix> {
        anyhow::ensure!(
            w.n() == self.n,
            "model is lowered for n={}, got {}",
            self.n,
            w.n()
        );
        let out = self.exe.run(w.as_slice())?;
        Ok(DistMatrix::from_vec(self.n, out))
    }
}

/// Thread-safe pool of compiled executables over one PJRT client.
pub struct ExecutorPool {
    runtime: PjrtRuntime,
    manifest: Manifest,
    cache: Mutex<HashMap<(String, usize), Arc<LoadedModel>>>,
}

impl ExecutorPool {
    /// Open the artifact directory and create the PJRT client.
    pub fn open(artifact_dir: &Path) -> Result<ExecutorPool> {
        let manifest = Manifest::load(artifact_dir)?;
        manifest.check_files()?;
        let runtime = PjrtRuntime::cpu()?;
        Ok(ExecutorPool {
            runtime,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }

    /// Get (compiling if needed) the model for an exact lowered size.
    pub fn model(&self, variant: &str, n: usize) -> Result<Arc<LoadedModel>> {
        let key = (variant.to_string(), n);
        if let Some(m) = self.cache.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        // compile outside the lock: first-touch compiles of different keys
        // can proceed in parallel, duplicate compiles of the same key are
        // tolerated (last one wins, both are valid)
        let entry = self
            .manifest
            .find(variant, n)
            .with_context(|| format!("no artifact for variant={variant} n={n}"))?;
        let exe = self.runtime.compile_file(&entry.path, entry.n)?;
        let model = Arc::new(LoadedModel {
            variant: variant.to_string(),
            n: entry.n,
            exe,
        });
        self.cache
            .lock()
            .unwrap()
            .insert(key, model.clone());
        Ok(model)
    }

    /// Compile a *specific* manifest entry (bypasses the default-kchunk
    /// preference of [`Manifest::find`]; used by the ablation benches).
    pub fn model_for_entry(&self, entry: &super::artifact::ArtifactEntry) -> Result<Arc<LoadedModel>> {
        let key = (entry.name.clone(), entry.n);
        if let Some(m) = self.cache.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        let exe = self.runtime.compile_file(&entry.path, entry.n)?;
        let model = Arc::new(LoadedModel {
            variant: entry.variant.clone(),
            n: entry.n,
            exe,
        });
        self.cache.lock().unwrap().insert(key, model.clone());
        Ok(model)
    }

    /// Eagerly compile every artifact of a variant (server warm-up).
    pub fn warm(&self, variant: &str) -> Result<usize> {
        let sizes = self.manifest.sizes_for(variant);
        for &n in &sizes {
            self.model(variant, n)?;
        }
        Ok(sizes.len())
    }

    /// Number of compiled executables resident.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Solve a graph of *any* size: route to the smallest bucket ≥ n,
    /// pad, run, truncate.  Returns the distances and the bucket used.
    pub fn solve(&self, variant: &str, w: &DistMatrix) -> Result<(DistMatrix, usize)> {
        let bucket = self
            .manifest
            .bucket_for(variant, w.n())
            .with_context(|| {
                format!(
                    "no artifact bucket ≥ {} for variant {variant} (available: {:?})",
                    w.n(),
                    self.manifest.sizes_for(variant)
                )
            })?;
        let model = self.model(variant, bucket)?;
        let padded = if w.n() == bucket {
            w.clone()
        } else {
            w.padded(bucket)
        };
        let solved = model.run(&padded)?;
        let out = if w.n() == bucket {
            solved
        } else {
            solved.truncated(w.n())
        };
        Ok((out, bucket))
    }
}

//! PJRT execution layer — offline stub (DESIGN.md §Substitutions).
//!
//! The real deployment compiles the HLO-text artifacts with the `xla`
//! crate's PJRT CPU client (`HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos jax ≥ 0.5 emits that
//! xla_extension 0.5.1 rejects).  That crate is not in the vendored set, so
//! this build substitutes an interpreter stub with the identical API:
//! "compiling" an artifact validates the HLO text and records its lowered
//! size, and "executing" it evaluates the artifact's contract — APSP over
//! an `f32[n,n]` input with `+inf` as "no edge" — with the CPU blocked
//! solver ([`crate::apsp::blocked`]).
//!
//! Every caller-visible property of the real path is preserved: exact input
//! and output shapes, determinism across runs, identical results for all
//! lowered variants (they compute the same closure), and compile-before-run
//! failure for missing or empty artifacts.  Swapping the stub back out for
//! the `xla`-backed implementation touches only this file.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::graph::DistMatrix;
use crate::Dist;

/// Process-wide "PJRT client" + compile/execute helpers (stubbed).
pub struct PjrtRuntime {
    platform: &'static str,
}

/// A compiled program taking one f32[n,n] input and returning a 1-tuple of
/// f32[n,n] (the `apsp_fn` convention).
pub struct Executable {
    /// Where the program came from (error messages / debugging).
    source: PathBuf,
    n: usize,
}

impl PjrtRuntime {
    /// Create the CPU "client".  Infallible in the stub; kept fallible so
    /// the call sites match the real PJRT path.
    pub fn cpu() -> Result<PjrtRuntime> {
        Ok(PjrtRuntime { platform: "cpu" })
    }

    pub fn platform(&self) -> String {
        self.platform.to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    /// "Compile" an HLO-text artifact expecting f32[n,n] → (f32[n,n],):
    /// read and sanity-check the text, record the lowered size.
    pub fn compile_file(&self, path: &Path, n: usize) -> Result<Executable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading HLO text {}", path.display()))?;
        ensure!(
            !text.trim().is_empty(),
            "artifact {} is empty",
            path.display()
        );
        ensure!(
            text.contains("f32"),
            "artifact {} does not look like an f32 HLO module",
            path.display()
        );
        Ok(Executable {
            source: path.to_path_buf(),
            n,
        })
    }

    /// Compile HLO text from memory (used by tests).
    pub fn compile_text(&self, text: &str, n: usize) -> Result<Executable> {
        use std::sync::atomic::{AtomicU64, Ordering};
        // unique per call: concurrent test threads must not share a file
        static INLINE_COUNTER: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "fw_stage_inline_{}_{}_{}.hlo.txt",
            std::process::id(),
            INLINE_COUNTER.fetch_add(1, Ordering::Relaxed),
            n
        ));
        std::fs::write(&path, text)?;
        let result = self.compile_file(&path, n);
        let _ = std::fs::remove_file(&path);
        result
    }
}

impl Executable {
    /// Problem size this executable was lowered for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Run the program on a row-major n×n f32 buffer; returns the solved
    /// row-major buffer.  The stub evaluates the artifact's semantic
    /// contract (APSP closure) with the CPU blocked solver; all variants
    /// compute the same (min,+) closure, so results agree bitwise across
    /// variants — the property `runtime_integration` asserts.
    pub fn run(&self, input: &[Dist]) -> Result<Vec<Dist>> {
        let n = self.n;
        ensure!(
            input.len() == n * n,
            "input length {} != {n}² (artifact {})",
            input.len(),
            self.source.display()
        );
        let mut m = DistMatrix::from_vec(n, input.to_vec());
        crate::apsp::blocked::solve_in_place(&mut m, crate::DEFAULT_TILE);
        Ok(m.into_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp;
    use crate::graph::generators;

    const FAKE_HLO: &str = "HloModule apsp, entry: f32[8,8] -> (f32[8,8])";

    #[test]
    fn compile_text_and_run_matches_oracle() {
        let rt = PjrtRuntime::cpu().unwrap();
        let exe = rt.compile_text(FAKE_HLO, 8).unwrap();
        assert_eq!(exe.n(), 8);
        let g = generators::erdos_renyi(8, 0.5, 1);
        let out = exe.run(g.as_slice()).unwrap();
        let solved = DistMatrix::from_vec(8, out);
        assert_eq!(solved, apsp::naive::solve(&g));
    }

    #[test]
    fn rejects_wrong_input_length() {
        let rt = PjrtRuntime::cpu().unwrap();
        let exe = rt.compile_text(FAKE_HLO, 8).unwrap();
        assert!(exe.run(&[0.0; 9]).is_err());
    }

    #[test]
    fn rejects_missing_and_empty_artifacts() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt
            .compile_file(Path::new("/nonexistent/apsp.hlo.txt"), 8)
            .is_err());
        assert!(rt.compile_text("   ", 8).is_err());
    }

    #[test]
    fn reports_cpu_platform() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
        assert_eq!(rt.device_count(), 1);
    }
}

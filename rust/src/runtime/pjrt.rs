//! Thin wrapper over the `xla` crate's PJRT client.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! DESIGN.md): `HloModuleProto::from_text_file` reassigns instruction ids,
//! sidestepping the 64-bit-id protos jax ≥ 0.5 emits that xla_extension
//! 0.5.1 rejects.  One client is shared process-wide; compiled executables
//! are cheap handles that can be executed concurrently.

use std::path::Path;

use anyhow::{Context, Result};

use crate::Dist;

/// Process-wide PJRT client + compile/execute helpers.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// A compiled program taking one f32[n,n] input and returning a 1-tuple of
/// f32[n,n] (the `apsp_fn` convention).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    n: usize,
}

impl PjrtRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text artifact expecting f32[n,n] → (f32[n,n],).
    pub fn compile_file(&self, path: &Path, n: usize) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, n })
    }

    /// Compile HLO text from memory (used by tests).
    pub fn compile_text(&self, text: &str, n: usize) -> Result<Executable> {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "fw_stage_inline_{}_{}.hlo.txt",
            std::process::id(),
            n
        ));
        std::fs::write(&path, text)?;
        let result = self.compile_file(&path, n);
        let _ = std::fs::remove_file(&path);
        result
    }
}

impl Executable {
    /// Problem size this executable was lowered for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Run the program on a row-major n×n f32 buffer; returns the solved
    /// row-major buffer.
    pub fn run(&self, input: &[Dist]) -> Result<Vec<Dist>> {
        let n = self.n;
        anyhow::ensure!(
            input.len() == n * n,
            "input length {} != {n}²",
            input.len()
        );
        let lit = xla::Literal::vec1(input)
            .reshape(&[n as i64, n as i64])
            .context("reshaping input literal")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .context("executing")?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result buffer")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        let out = out.to_tuple1().context("unwrapping result tuple")?;
        let values = out.to_vec::<Dist>().context("reading result values")?;
        anyhow::ensure!(
            values.len() == n * n,
            "result length {} != {n}²",
            values.len()
        );
        Ok(values)
    }
}

//! PJRT runtime: load AOT HLO-text artifacts and execute them on the
//! request path.
//!
//! `python/compile/aot.py` lowers each (variant × size) once at build time;
//! this module discovers the artifacts through `manifest.json`
//! ([`artifact`]), compiles them on a shared PJRT CPU client ([`pjrt`]),
//! and serves execute calls through a pooled, size-keyed executor registry
//! ([`executor`]).  Python is never invoked here.

pub mod artifact;
pub mod executor;
pub mod pjrt;

pub use artifact::{ArtifactEntry, Manifest};
pub use executor::{ExecutorPool, LoadedModel};
pub use pjrt::PjrtRuntime;

//! The device engine: a dedicated executor thread owning the PJRT client.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (not `Send`), so all
//! device work is confined to one engine thread fed by an MPSC channel —
//! the same leader/worker split a GPU serving stack uses.  The engine:
//!
//! 1. blocks on the queue for the first pending job;
//! 2. drains whatever else arrives within the batch window;
//! 3. groups jobs by variant and plans device calls with the
//!    block-diagonal packer ([`super::batcher`]);
//! 4. executes each plan (packing/unpacking matrices as needed) and sends
//!    each job its result through its reply channel.
//!
//! Backpressure: the submission channel is bounded; when the engine falls
//! behind, `submit` blocks the caller (TCP handler threads), which is the
//! correct shed point for a solve service.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::{plan, BatchPolicy, Item};
use super::metrics::Metrics;
use crate::apsp;
use crate::graph::DistMatrix;
use crate::runtime::ExecutorPool;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifact_dir: PathBuf,
    /// How long to linger collecting more jobs after the first (batching
    /// window). Zero = no batching delay (still batches what is queued).
    pub batch_window: Duration,
    /// Max jobs drained into one planning round.
    pub max_batch_jobs: usize,
    /// Submission queue bound (backpressure).
    pub queue_depth: usize,
    /// Packing policy.
    pub policy: BatchPolicy,
    /// Eagerly compile all artifacts of these variants at startup.
    pub warm_variants: Vec<String>,
}

impl EngineConfig {
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Self {
        EngineConfig {
            artifact_dir: artifact_dir.into(),
            batch_window: Duration::from_millis(2),
            max_batch_jobs: 64,
            queue_depth: 256,
            policy: BatchPolicy::default(),
            warm_variants: vec!["staged".to_string()],
        }
    }
}

/// A solve job travelling to the engine thread.
struct Job {
    variant: String,
    graph: DistMatrix,
    reply: mpsc::Sender<Result<EngineSolve>>,
    /// When the caller enqueued the job — the batcher's queue-wait metric
    /// is measured from here to the start of the device round.
    submitted: Instant,
}

/// A successful engine solve.
#[derive(Clone, Debug)]
pub struct EngineSolve {
    pub dist: DistMatrix,
    pub bucket: usize,
    /// Number of jobs co-scheduled in the same device call.
    pub batch_size: usize,
}

/// Handle to the engine thread (cheap to clone; `Send + Sync`).
pub struct Engine {
    tx: mpsc::SyncSender<Job>,
    metrics: Arc<Metrics>,
    handle: Option<JoinHandle<()>>,
}

impl Engine {
    /// Start the engine thread. Fails fast (synchronously) if the artifact
    /// manifest is unreadable or the PJRT client cannot start.
    pub fn start(config: EngineConfig, metrics: Arc<Metrics>) -> Result<Engine> {
        let (tx, rx) = mpsc::sync_channel::<Job>(config.queue_depth);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread_metrics = metrics.clone();
        let handle = std::thread::Builder::new()
            .name("fw-stage-engine".into())
            .spawn(move || engine_main(config, rx, ready_tx, thread_metrics))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(Engine {
            tx,
            metrics,
            handle: Some(handle),
        })
    }

    /// Submit a solve and block for the result.
    pub fn solve(&self, variant: &str, graph: DistMatrix) -> Result<EngineSolve> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job {
                variant: variant.to_string(),
                graph,
                reply: reply_tx,
                submitted: Instant::now(),
            })
            .map_err(|_| anyhow!("engine thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the job (shutting down?)"))?
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Successor-tracking fallback for device-scale path requests.
    ///
    /// The AOT artifacts compute distances only (succ tracking has no
    /// lowered kernel), so a `want_paths` request that routed to the
    /// device tier is served by the multithreaded CPU blocked solver
    /// instead.  It runs on the **calling thread**, deliberately bypassing
    /// the engine channel: path solves must not serialize behind (or stall)
    /// the device batch queue, and the solver fans out over its own scoped
    /// threads anyway.
    ///
    /// Sizes that are not a multiple of `tile` pad up and truncate inside
    /// the solver itself (`apsp::parallel::solve_paths` — the device
    /// tier's own padding trick) so every device-scale n takes the banded
    /// fast path rather than degrading to the single-threaded reference
    /// solver.  Padding never changes distances, and padded vertices are
    /// unreachable, so no surviving successor can reference one.
    pub fn solve_paths(&self, graph: &DistMatrix, tile: usize) -> apsp::paths::PathsResult {
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        apsp::parallel::solve_paths(graph, tile, threads)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // closing the channel stops the loop; join to flush in-flight work
        let (tx, _) = mpsc::sync_channel(1);
        drop(std::mem::replace(&mut self.tx, tx));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn engine_main(
    config: EngineConfig,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::Sender<Result<()>>,
    metrics: Arc<Metrics>,
) {
    let pool = match ExecutorPool::open(&config.artifact_dir) {
        Ok(pool) => {
            let mut warm_err = None;
            for v in &config.warm_variants {
                if let Err(e) = pool.warm(v) {
                    warm_err = Some(e);
                    break;
                }
            }
            match warm_err {
                None => {
                    let _ = ready.send(Ok(()));
                    pool
                }
                Some(e) => {
                    let _ = ready.send(Err(e));
                    return;
                }
            }
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    loop {
        // block for the first job; channel closed = shutdown
        let first = match rx.recv() {
            Ok(job) => job,
            Err(_) => return,
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + config.batch_window;
        while jobs.len() < config.max_batch_jobs {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => jobs.push(job),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        run_round(&pool, &config.policy, jobs, &metrics);
    }
}

/// Plan and execute one drained round of jobs.
fn run_round(pool: &ExecutorPool, policy: &BatchPolicy, jobs: Vec<Job>, metrics: &Metrics) {
    // group by variant
    let mut by_variant: HashMap<String, Vec<Job>> = HashMap::new();
    for job in jobs {
        by_variant.entry(job.variant.clone()).or_default().push(job);
    }
    for (variant, jobs) in by_variant {
        let buckets = pool.manifest().sizes_for(&variant);
        if buckets.is_empty() {
            for job in jobs {
                let _ = job
                    .reply
                    .send(Err(anyhow!("no artifacts for variant {variant:?}")));
            }
            continue;
        }
        let items: Vec<Item> = jobs
            .iter()
            .enumerate()
            .map(|(i, job)| Item {
                ticket: i as u64,
                n: job.graph.n(),
            })
            .collect();
        let mut jobs: Vec<Option<Job>> = jobs.into_iter().map(Some).collect();
        for batch in plan(&items, &buckets, policy) {
            if batch.bucket == 0 {
                for p in &batch.placements {
                    if let Some(job) = jobs[p.ticket as usize].take() {
                        let _ = job.reply.send(Err(anyhow!(
                            "graph size {} exceeds largest artifact bucket {}",
                            p.n,
                            buckets.last().unwrap()
                        )));
                    }
                }
                continue;
            }
            // assemble block-diagonal input
            let t0 = Instant::now();
            let mut packed = DistMatrix::unconnected(batch.bucket);
            let mut queue_wait_seconds = 0.0;
            for p in &batch.placements {
                let job = jobs[p.ticket as usize].as_ref().expect("ticket reuse");
                queue_wait_seconds += t0.duration_since(job.submitted).as_secs_f64();
                copy_block(&mut packed, &job.graph, p.offset);
            }
            let solved = pool
                .model(&variant, batch.bucket)
                .and_then(|m| m.run(&packed));
            let device_seconds = t0.elapsed().as_secs_f64();
            metrics.record_batch(batch.placements.len(), device_seconds, queue_wait_seconds);
            match solved {
                Ok(solved) => {
                    let batch_size = batch.placements.len();
                    for p in &batch.placements {
                        let job = jobs[p.ticket as usize].take().expect("ticket reuse");
                        let dist = slice_block(&solved, p.offset, p.n);
                        let _ = job.reply.send(Ok(EngineSolve {
                            dist,
                            bucket: batch.bucket,
                            batch_size,
                        }));
                    }
                }
                Err(e) => {
                    let msg = format!("device execution failed: {e:#}");
                    for p in &batch.placements {
                        if let Some(job) = jobs[p.ticket as usize].take() {
                            let _ = job.reply.send(Err(anyhow!("{msg}")));
                        }
                    }
                }
            }
        }
        // any job not covered by the plan is a planner bug; fail loudly
        for job in jobs.into_iter().flatten() {
            let _ = job
                .reply
                .send(Err(anyhow!("internal: job missing from batch plan")));
        }
    }
}

/// Copy `g` onto the diagonal of `dst` at `offset`.
fn copy_block(dst: &mut DistMatrix, g: &DistMatrix, offset: usize) {
    let n = g.n();
    let m = dst.n();
    debug_assert!(offset + n <= m);
    for i in 0..n {
        let src = g.row(i);
        let dst_row = &mut dst.as_mut_slice()[(offset + i) * m + offset..][..n];
        dst_row.copy_from_slice(src);
    }
}

/// Extract the `n×n` diagonal block at `offset`.
fn slice_block(src: &DistMatrix, offset: usize, n: usize) -> DistMatrix {
    let m = src.n();
    debug_assert!(offset + n <= m);
    let mut out = DistMatrix::unconnected(n);
    for i in 0..n {
        let row = &src.row(offset + i)[offset..offset + n];
        out.as_mut_slice()[i * n..(i + 1) * n].copy_from_slice(row);
    }
    out
}

/// Block-diagonal identity used by tests: packing then slicing is lossless
/// and blocks cannot interact (all cross-block entries are `INF`).
#[cfg(test)]
pub fn pack_roundtrip_check(graphs: &[DistMatrix], bucket: usize) -> bool {
    use crate::INF;
    let mut packed = DistMatrix::unconnected(bucket);
    let mut offset = 0;
    let mut offsets = Vec::new();
    for g in graphs {
        copy_block(&mut packed, g, offset);
        offsets.push(offset);
        offset += g.n();
    }
    // cross-block entries untouched (INF)
    for (gi, g) in graphs.iter().enumerate() {
        for (gj, h) in graphs.iter().enumerate() {
            if gi == gj {
                continue;
            }
            for i in 0..g.n() {
                for j in 0..h.n() {
                    if packed.get(offsets[gi] + i, offsets[gj] + j) != INF {
                        return false;
                    }
                }
            }
        }
    }
    graphs
        .iter()
        .zip(&offsets)
        .all(|(g, &off)| &slice_block(&packed, off, g.n()) == g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp;
    use crate::graph::generators;

    #[test]
    fn pack_and_slice_roundtrip() {
        let gs = vec![
            generators::ring(10),
            generators::erdos_renyi(20, 0.4, 1),
            generators::grid(4, 2),
        ];
        assert!(pack_roundtrip_check(&gs, 64));
    }

    #[test]
    fn block_diagonal_solve_is_independent() {
        // solving the packed matrix solves each block independently
        let a = generators::erdos_renyi(12, 0.5, 3);
        let b = generators::ring(9);
        let mut packed = DistMatrix::unconnected(32);
        copy_block(&mut packed, &a, 0);
        copy_block(&mut packed, &b, 12);
        let solved = apsp::naive::solve(&packed);
        assert_eq!(slice_block(&solved, 0, 12), apsp::naive::solve(&a));
        assert_eq!(slice_block(&solved, 12, 9), apsp::naive::solve(&b));
        // cross-block distances remain infinite
        assert!(solved.get(0, 20).is_infinite());
        assert!(solved.get(20, 0).is_infinite());
    }
}

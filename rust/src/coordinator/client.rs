//! Blocking TCP client for the coordinator protocol (examples, benches,
//! and the `fw-stage client` subcommand).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::types::{decode_response, encode_request, Request, Response};
use crate::graph::DistMatrix;
use crate::util::json::Json;

/// One connection to a running `fw-stage serve`.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    fn roundtrip(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Ok(reply)
    }

    /// Solve a graph; returns the full response (distances + metadata).
    pub fn solve(&mut self, graph: &DistMatrix, variant: &str) -> Result<Response> {
        self.request(graph, variant, false)
    }

    /// Solve a graph *with successor tracking*: the response carries the
    /// successor matrix (`Response::succ` is guaranteed present), from
    /// which [`crate::apsp::paths::PathsResult`] reconstructs actual paths.
    pub fn solve_paths(&mut self, graph: &DistMatrix, variant: &str) -> Result<Response> {
        let resp = self.request(graph, variant, true)?;
        if resp.succ.is_none() {
            bail!("server response is missing the successor matrix");
        }
        Ok(resp)
    }

    fn request(&mut self, graph: &DistMatrix, variant: &str, want_paths: bool) -> Result<Response> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request {
            id,
            graph: graph.clone(),
            variant: variant.to_string(),
            no_cache: false,
            want_paths,
        };
        let reply = self.roundtrip(&encode_request(&req))?;
        let resp = decode_response(&reply)?;
        if resp.id != id {
            bail!("response id {} for request {id}", resp.id);
        }
        Ok(resp)
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        let reply = self.roundtrip(r#"{"type":"ping"}"#)?;
        let v = Json::parse(&reply)?;
        if v.get("type").as_str() != Some("pong") {
            bail!("unexpected ping reply: {reply}");
        }
        Ok(())
    }

    /// Server metrics snapshot.
    pub fn stats(&mut self) -> Result<Json> {
        let reply = self.roundtrip(r#"{"type":"stats"}"#)?;
        Ok(Json::parse(&reply)?)
    }

    /// Artifact info (variants, buckets, tile).
    pub fn info(&mut self) -> Result<Json> {
        let reply = self.roundtrip(r#"{"type":"info"}"#)?;
        Ok(Json::parse(&reply)?)
    }
}

//! Blocking TCP client for the coordinator protocol (examples, benches,
//! and the `fw-stage client` subcommand).
//!
//! Replies are demultiplexed by a 4-byte peek: line-JSON starts `{`
//! (0x7B), the binary matrix frame starts with the [`super::frame`]
//! magic — so JSON and binary replies interleave freely on one
//! connection, and typed error lines still arrive as JSON even for
//! `"binary": true` requests.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::cache::graph_fingerprint;
use super::frame;
use super::types::{
    decode_response, encode_request_opts, encode_update_request_opts, Request, Response,
    UpdateRequest, WireOptions, CODE_UPDATE_BASE_MISSING, DEFAULT_OBJECTIVE,
};
use crate::apsp::incremental::{self, EdgeUpdate};
use crate::graph::DistMatrix;
use crate::util::json::Json;

/// What an update request came back as.
pub enum UpdateReply {
    /// Served (incrementally or via a server-side re-baseline).
    Solved(Response),
    /// The base closure is not cached server-side; retry as a full solve
    /// of the mutated graph ([`Client::update_or_solve`] does exactly
    /// that).
    BaseMissing,
}

/// A demultiplexed server reply: one JSON line or one binary frame.
enum Reply {
    Line(String),
    Frame(Box<Response>),
}

/// One connection to a running `fw-stage serve`.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    /// Per-request deadline sent as the wire `"deadline_ms"` field on
    /// every solve/update; `None` leaves the server default in charge,
    /// `Some(0)` disables the deadline for this client's requests.
    deadline_ms: Option<u64>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
            deadline_ms: None,
        })
    }

    /// Set (or clear) the deadline attached to subsequent solve/update
    /// requests.  `Some(0)` explicitly disables the server's default.
    pub fn set_deadline_ms(&mut self, deadline_ms: Option<u64>) {
        self.deadline_ms = deadline_ms;
    }

    fn wire_options(&self, binary: bool) -> WireOptions {
        WireOptions {
            deadline_ms: self.deadline_ms,
            binary,
        }
    }

    fn roundtrip(&mut self, line: &str) -> Result<String> {
        match self.roundtrip_any(line)? {
            Reply::Line(line) => Ok(line),
            Reply::Frame(_) => bail!("unexpected binary frame reply to a control request"),
        }
    }

    /// Send one line, read one reply of either wire form.  The first 4
    /// bytes decide: the frame magic means binary, anything else is the
    /// head of a JSON line.
    fn roundtrip_any(&mut self, line: &str) -> Result<Reply> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut head = [0u8; 4];
        if let Err(e) = self.reader.read_exact(&mut head) {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                bail!("server closed the connection");
            }
            return Err(e.into());
        }
        if head == frame::MAGIC {
            let resp = frame::read_frame_body(&mut self.reader)
                .context("decoding binary frame reply")?;
            return Ok(Reply::Frame(Box::new(resp)));
        }
        let mut rest = String::new();
        let n = self.reader.read_line(&mut rest)?;
        if n == 0 && rest.is_empty() {
            bail!("server closed the connection mid-line");
        }
        let head = std::str::from_utf8(&head).context("reply head is not UTF-8")?;
        Ok(Reply::Line(format!("{head}{rest}")))
    }

    /// Solve a graph; returns the full response (distances + metadata).
    pub fn solve(&mut self, graph: &DistMatrix, variant: &str) -> Result<Response> {
        self.request(graph, variant, false, DEFAULT_OBJECTIVE)
    }

    /// [`Client::solve`], negotiating the length-prefixed binary frame
    /// for the reply (`"binary": true`): raw little-endian `f32` rows
    /// instead of JSON text, bitwise-identical distances.
    pub fn solve_binary(&mut self, graph: &DistMatrix, variant: &str) -> Result<Response> {
        self.request_opts(graph, variant, false, DEFAULT_OBJECTIVE, true)
    }

    /// [`Client::solve_binary`] under an explicit serving objective.
    pub fn solve_binary_objective(
        &mut self,
        graph: &DistMatrix,
        variant: &str,
        objective: &str,
    ) -> Result<Response> {
        self.request_opts(graph, variant, false, objective, true)
    }

    /// [`Client::solve_paths`] over the binary frame: the reply carries
    /// the successor matrix as raw little-endian `u32` rows.
    pub fn solve_paths_binary(&mut self, graph: &DistMatrix, variant: &str) -> Result<Response> {
        let resp = self.request_opts(graph, variant, true, DEFAULT_OBJECTIVE, true)?;
        if resp.succ.is_none() {
            bail!("server response is missing the successor matrix");
        }
        Ok(resp)
    }

    /// Solve a graph under an explicit serving objective (`"shortest"`,
    /// `"bottleneck"`, `"minimax"`, `"reachability"`).  An objective the
    /// server does not serve on this variant comes back as an error
    /// carrying [`super::types::CODE_OBJECTIVE_UNSUPPORTED`].
    pub fn solve_objective(
        &mut self,
        graph: &DistMatrix,
        variant: &str,
        objective: &str,
    ) -> Result<Response> {
        self.request(graph, variant, false, objective)
    }

    /// Solve a graph *with successor tracking*: the response carries the
    /// successor matrix (`Response::succ` is guaranteed present), from
    /// which [`crate::apsp::paths::PathsResult`] reconstructs actual paths.
    pub fn solve_paths(&mut self, graph: &DistMatrix, variant: &str) -> Result<Response> {
        self.solve_paths_objective(graph, variant, DEFAULT_OBJECTIVE)
    }

    /// [`Client::solve_paths`] under an explicit serving objective.
    pub fn solve_paths_objective(
        &mut self,
        graph: &DistMatrix,
        variant: &str,
        objective: &str,
    ) -> Result<Response> {
        let resp = self.request(graph, variant, true, objective)?;
        if resp.succ.is_none() {
            bail!("server response is missing the successor matrix");
        }
        Ok(resp)
    }

    fn request(
        &mut self,
        graph: &DistMatrix,
        variant: &str,
        want_paths: bool,
        objective: &str,
    ) -> Result<Response> {
        self.request_opts(graph, variant, want_paths, objective, false)
    }

    fn request_opts(
        &mut self,
        graph: &DistMatrix,
        variant: &str,
        want_paths: bool,
        objective: &str,
        binary: bool,
    ) -> Result<Response> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request {
            id,
            graph: graph.clone(),
            variant: variant.to_string(),
            no_cache: false,
            want_paths,
            objective: objective.to_string(),
            trace: false,
        };
        let line = encode_request_opts(&req, &self.wire_options(binary));
        let resp = match self.roundtrip_any(&line)? {
            Reply::Frame(resp) => *resp,
            // typed errors (shed, deadline, objective, …) are always
            // JSON lines, even on binary-negotiated requests
            Reply::Line(reply) => decode_response(&reply)?,
        };
        if resp.id != id {
            bail!("response id {} for request {id}", resp.id);
        }
        Ok(resp)
    }

    /// Solve with `"trace": true`: the result line carries the request's
    /// span tree, returned here as raw JSON alongside the response
    /// (`{"name":"request","seconds":…,"spans":[…]}`).
    pub fn solve_traced(&mut self, graph: &DistMatrix, variant: &str) -> Result<(Response, Json)> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request {
            id,
            graph: graph.clone(),
            variant: variant.to_string(),
            no_cache: false,
            want_paths: false,
            objective: DEFAULT_OBJECTIVE.to_string(),
            trace: true,
        };
        // trace echoes are JSON-only (the server rejects binary+trace)
        let reply = self.roundtrip(&encode_request_opts(&req, &self.wire_options(false)))?;
        let v = Json::parse(&reply).context("traced reply is not valid JSON")?;
        let trace = v.get("trace").clone();
        let resp = decode_response(&reply)?;
        if resp.id != id {
            bail!("response id {} for request {id}", resp.id);
        }
        if trace.is_null() {
            bail!("server response is missing the trace echo (tracing disabled server-side?)");
        }
        Ok((resp, trace))
    }

    /// Last `k` journaled request traces (newest first), optionally
    /// filtered by tier source (`"cpu"`, `"superblock"`, …) and/or
    /// objective name.
    pub fn trace(
        &mut self,
        k: usize,
        source: Option<&str>,
        objective: Option<&str>,
    ) -> Result<Json> {
        let mut fields = vec![("type", Json::str("trace")), ("k", Json::num(k as f64))];
        if let Some(s) = source {
            fields.push(("source", Json::str(s)));
        }
        if let Some(o) = objective {
            fields.push(("objective", Json::str(o)));
        }
        let reply = self.roundtrip(&Json::obj(fields).to_string())?;
        Ok(Json::parse(&reply)?)
    }

    /// Prometheus-style metrics text (histograms + counters).
    pub fn exposition(&mut self) -> Result<String> {
        let reply = self.roundtrip(r#"{"type":"exposition"}"#)?;
        let v = Json::parse(&reply)?;
        v.get("text")
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("exposition reply missing text: {reply}"))
    }

    /// Send an edge-delta batch against `base`'s cached closure.  The
    /// fingerprint is computed client-side from the base graph (the same
    /// function the server keys its cache with), so only the deltas
    /// travel.  A typed `update_base_missing` error maps to
    /// [`UpdateReply::BaseMissing`]; every other error is a real failure.
    pub fn update(
        &mut self,
        base: &DistMatrix,
        updates: &[EdgeUpdate],
        variant: &str,
        want_paths: bool,
    ) -> Result<UpdateReply> {
        // fail before encoding: the wire has no rendering for NaN/-inf
        // (null means "+inf, delete"), so a malformed weight must not
        // silently travel as a deletion
        incremental::validate_batch(base.n(), updates)
            .map_err(|e| anyhow::anyhow!("invalid update batch: {e}"))?;
        let id = self.next_id;
        self.next_id += 1;
        let req = UpdateRequest {
            id,
            variant: variant.to_string(),
            n: base.n(),
            base_fingerprint: graph_fingerprint(base),
            updates: updates.to_vec(),
            want_paths,
            objective: DEFAULT_OBJECTIVE.to_string(),
        };
        // updates stay line-JSON (the incremental tier's deltas are the
        // payload, not a matrix) but carry the client's deadline
        let reply =
            self.roundtrip(&encode_update_request_opts(&req, &self.wire_options(false)))?;
        let v = Json::parse(&reply).context("update reply is not valid JSON")?;
        if v.get("type").as_str() == Some("error")
            && v.get("code").as_str() == Some(CODE_UPDATE_BASE_MISSING)
        {
            return Ok(UpdateReply::BaseMissing);
        }
        let resp = decode_response(&reply)?;
        if resp.id != id {
            bail!("response id {} for request {id}", resp.id);
        }
        if want_paths && resp.succ.is_none() {
            bail!("update response is missing the successor matrix");
        }
        Ok(UpdateReply::Solved(resp))
    }

    /// Update with transparent fallback: on a cache miss the mutated graph
    /// is solved from scratch (one extra round trip, and the server caches
    /// the fresh closure — so the *next* delta against it chains).
    pub fn update_or_solve(
        &mut self,
        base: &DistMatrix,
        updates: &[EdgeUpdate],
        variant: &str,
        want_paths: bool,
    ) -> Result<Response> {
        match self.update(base, updates, variant, want_paths)? {
            UpdateReply::Solved(resp) => Ok(resp),
            UpdateReply::BaseMissing => {
                let mutated = incremental::mutated(base, updates)
                    .map_err(|e| anyhow::anyhow!("invalid update batch: {e}"))?;
                self.request(&mutated, variant, want_paths, DEFAULT_OBJECTIVE)
            }
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        let reply = self.roundtrip(r#"{"type":"ping"}"#)?;
        let v = Json::parse(&reply)?;
        if v.get("type").as_str() != Some("pong") {
            bail!("unexpected ping reply: {reply}");
        }
        Ok(())
    }

    /// Server metrics snapshot.
    pub fn stats(&mut self) -> Result<Json> {
        let reply = self.roundtrip(r#"{"type":"stats"}"#)?;
        Ok(Json::parse(&reply)?)
    }

    /// Artifact info (variants, buckets, tile).
    pub fn info(&mut self) -> Result<Json> {
        let reply = self.roundtrip(r#"{"type":"info"}"#)?;
        Ok(Json::parse(&reply)?)
    }
}

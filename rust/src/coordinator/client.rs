//! Blocking TCP client for the coordinator protocol (examples, benches,
//! and the `fw-stage client` subcommand).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::cache::graph_fingerprint;
use super::types::{
    decode_response, encode_request, encode_update_request, Request, Response, UpdateRequest,
    CODE_UPDATE_BASE_MISSING, DEFAULT_OBJECTIVE,
};
use crate::apsp::incremental::{self, EdgeUpdate};
use crate::graph::DistMatrix;
use crate::util::json::Json;

/// What an update request came back as.
pub enum UpdateReply {
    /// Served (incrementally or via a server-side re-baseline).
    Solved(Response),
    /// The base closure is not cached server-side; retry as a full solve
    /// of the mutated graph ([`Client::update_or_solve`] does exactly
    /// that).
    BaseMissing,
}

/// One connection to a running `fw-stage serve`.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    fn roundtrip(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Ok(reply)
    }

    /// Solve a graph; returns the full response (distances + metadata).
    pub fn solve(&mut self, graph: &DistMatrix, variant: &str) -> Result<Response> {
        self.request(graph, variant, false, DEFAULT_OBJECTIVE)
    }

    /// Solve a graph under an explicit serving objective (`"shortest"`,
    /// `"bottleneck"`, `"minimax"`, `"reachability"`).  An objective the
    /// server does not serve on this variant comes back as an error
    /// carrying [`super::types::CODE_OBJECTIVE_UNSUPPORTED`].
    pub fn solve_objective(
        &mut self,
        graph: &DistMatrix,
        variant: &str,
        objective: &str,
    ) -> Result<Response> {
        self.request(graph, variant, false, objective)
    }

    /// Solve a graph *with successor tracking*: the response carries the
    /// successor matrix (`Response::succ` is guaranteed present), from
    /// which [`crate::apsp::paths::PathsResult`] reconstructs actual paths.
    pub fn solve_paths(&mut self, graph: &DistMatrix, variant: &str) -> Result<Response> {
        self.solve_paths_objective(graph, variant, DEFAULT_OBJECTIVE)
    }

    /// [`Client::solve_paths`] under an explicit serving objective.
    pub fn solve_paths_objective(
        &mut self,
        graph: &DistMatrix,
        variant: &str,
        objective: &str,
    ) -> Result<Response> {
        let resp = self.request(graph, variant, true, objective)?;
        if resp.succ.is_none() {
            bail!("server response is missing the successor matrix");
        }
        Ok(resp)
    }

    fn request(
        &mut self,
        graph: &DistMatrix,
        variant: &str,
        want_paths: bool,
        objective: &str,
    ) -> Result<Response> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request {
            id,
            graph: graph.clone(),
            variant: variant.to_string(),
            no_cache: false,
            want_paths,
            objective: objective.to_string(),
            trace: false,
        };
        let reply = self.roundtrip(&encode_request(&req))?;
        let resp = decode_response(&reply)?;
        if resp.id != id {
            bail!("response id {} for request {id}", resp.id);
        }
        Ok(resp)
    }

    /// Solve with `"trace": true`: the result line carries the request's
    /// span tree, returned here as raw JSON alongside the response
    /// (`{"name":"request","seconds":…,"spans":[…]}`).
    pub fn solve_traced(&mut self, graph: &DistMatrix, variant: &str) -> Result<(Response, Json)> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request {
            id,
            graph: graph.clone(),
            variant: variant.to_string(),
            no_cache: false,
            want_paths: false,
            objective: DEFAULT_OBJECTIVE.to_string(),
            trace: true,
        };
        let reply = self.roundtrip(&encode_request(&req))?;
        let v = Json::parse(&reply).context("traced reply is not valid JSON")?;
        let trace = v.get("trace").clone();
        let resp = decode_response(&reply)?;
        if resp.id != id {
            bail!("response id {} for request {id}", resp.id);
        }
        if trace.is_null() {
            bail!("server response is missing the trace echo (tracing disabled server-side?)");
        }
        Ok((resp, trace))
    }

    /// Last `k` journaled request traces (newest first), optionally
    /// filtered by tier source (`"cpu"`, `"superblock"`, …) and/or
    /// objective name.
    pub fn trace(
        &mut self,
        k: usize,
        source: Option<&str>,
        objective: Option<&str>,
    ) -> Result<Json> {
        let mut fields = vec![("type", Json::str("trace")), ("k", Json::num(k as f64))];
        if let Some(s) = source {
            fields.push(("source", Json::str(s)));
        }
        if let Some(o) = objective {
            fields.push(("objective", Json::str(o)));
        }
        let reply = self.roundtrip(&Json::obj(fields).to_string())?;
        Ok(Json::parse(&reply)?)
    }

    /// Prometheus-style metrics text (histograms + counters).
    pub fn exposition(&mut self) -> Result<String> {
        let reply = self.roundtrip(r#"{"type":"exposition"}"#)?;
        let v = Json::parse(&reply)?;
        v.get("text")
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("exposition reply missing text: {reply}"))
    }

    /// Send an edge-delta batch against `base`'s cached closure.  The
    /// fingerprint is computed client-side from the base graph (the same
    /// function the server keys its cache with), so only the deltas
    /// travel.  A typed `update_base_missing` error maps to
    /// [`UpdateReply::BaseMissing`]; every other error is a real failure.
    pub fn update(
        &mut self,
        base: &DistMatrix,
        updates: &[EdgeUpdate],
        variant: &str,
        want_paths: bool,
    ) -> Result<UpdateReply> {
        // fail before encoding: the wire has no rendering for NaN/-inf
        // (null means "+inf, delete"), so a malformed weight must not
        // silently travel as a deletion
        incremental::validate_batch(base.n(), updates)
            .map_err(|e| anyhow::anyhow!("invalid update batch: {e}"))?;
        let id = self.next_id;
        self.next_id += 1;
        let req = UpdateRequest {
            id,
            variant: variant.to_string(),
            n: base.n(),
            base_fingerprint: graph_fingerprint(base),
            updates: updates.to_vec(),
            want_paths,
            objective: DEFAULT_OBJECTIVE.to_string(),
        };
        let reply = self.roundtrip(&encode_update_request(&req))?;
        let v = Json::parse(&reply).context("update reply is not valid JSON")?;
        if v.get("type").as_str() == Some("error")
            && v.get("code").as_str() == Some(CODE_UPDATE_BASE_MISSING)
        {
            return Ok(UpdateReply::BaseMissing);
        }
        let resp = decode_response(&reply)?;
        if resp.id != id {
            bail!("response id {} for request {id}", resp.id);
        }
        if want_paths && resp.succ.is_none() {
            bail!("update response is missing the successor matrix");
        }
        Ok(UpdateReply::Solved(resp))
    }

    /// Update with transparent fallback: on a cache miss the mutated graph
    /// is solved from scratch (one extra round trip, and the server caches
    /// the fresh closure — so the *next* delta against it chains).
    pub fn update_or_solve(
        &mut self,
        base: &DistMatrix,
        updates: &[EdgeUpdate],
        variant: &str,
        want_paths: bool,
    ) -> Result<Response> {
        match self.update(base, updates, variant, want_paths)? {
            UpdateReply::Solved(resp) => Ok(resp),
            UpdateReply::BaseMissing => {
                let mutated = incremental::mutated(base, updates)
                    .map_err(|e| anyhow::anyhow!("invalid update batch: {e}"))?;
                self.request(&mutated, variant, want_paths, DEFAULT_OBJECTIVE)
            }
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        let reply = self.roundtrip(r#"{"type":"ping"}"#)?;
        let v = Json::parse(&reply)?;
        if v.get("type").as_str() != Some("pong") {
            bail!("unexpected ping reply: {reply}");
        }
        Ok(())
    }

    /// Server metrics snapshot.
    pub fn stats(&mut self) -> Result<Json> {
        let reply = self.roundtrip(r#"{"type":"stats"}"#)?;
        Ok(Json::parse(&reply)?)
    }

    /// Artifact info (variants, buckets, tile).
    pub fn info(&mut self) -> Result<Json> {
        let reply = self.roundtrip(r#"{"type":"info"}"#)?;
        Ok(Json::parse(&reply)?)
    }
}

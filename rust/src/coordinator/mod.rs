//! Layer-3 coordinator: the serving system around the AOT kernels.
//!
//! ```text
//!            TCP (JSON lines)                 mpsc (bounded)
//!  clients ───────────────► server ─┬─► router ──► engine thread ─► PJRT
//!                                   │      │          (batcher)
//!                                   │      ├─► CPU fallback
//!                                   │      └─► superblock tier (n larger
//!                                   │          than every bucket; diagonal
//!                                   │          tiles loop back to engine)
//!                                   └─► cache / metrics
//! ```
//!
//! * [`types`] — request/response structs + wire codec
//! * [`frame`] — opt-in length-prefixed binary response frame
//! * [`router`] — CPU-vs-device routing policy
//! * [`batcher`] — block-diagonal packing plans
//! * [`engine`] — the PJRT executor thread
//! * [`cache`] — LRU result cache
//! * [`store`] — persistent content-addressed closure store (warm starts)
//! * [`metrics`] — counters + latency summaries
//! * [`server`] / [`client`] — TCP front end and a blocking client

pub mod batcher;
pub mod cache;
pub mod client;
pub mod engine;
pub mod frame;
pub mod metrics;
pub mod router;
pub mod server;
pub mod store;
pub mod types;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::apsp;
use crate::apsp::semiring::Objective;
use crate::graph::DistMatrix;
use crate::obs::{self, Span};
use crate::runtime::Manifest;
use crate::superblock;

pub use engine::{Engine, EngineConfig};
pub use types::{Request, Response, Source, UpdateRequest};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub artifact_dir: PathBuf,
    pub router: router::RouterConfig,
    pub engine: EngineConfig,
    /// Result-cache capacity (entries); 0 disables.
    pub cache_capacity: usize,
    /// Phase-2/3 pool width for the superblock tier; 0 = one per core.
    pub superblock_workers: usize,
    /// Max incremental updates chained onto one baseline closure before an
    /// update request is served by a full re-solve instead (bounding the
    /// float-association drift a long chain could accumulate at arbitrary
    /// weights; DESIGN.md §Incremental tier).
    pub update_max_chain: u32,
    /// Observability: request tracing and the trace-journal ring
    /// (DESIGN.md §Observability).  Histograms are unconditional.
    pub obs: obs::ObsConfig,
    /// Persistent closure store (DESIGN.md §Closure store): `None` (the
    /// default) serves memory-only, exactly as before.  `Some` makes the
    /// cache read-through/write-behind against the store directory and
    /// warm-starts the LRU from it at boot.
    pub store: Option<store::StoreConfig>,
}

impl Config {
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Self {
        let artifact_dir = artifact_dir.into();
        Config {
            engine: EngineConfig::new(&artifact_dir),
            artifact_dir,
            router: router::RouterConfig::default(),
            cache_capacity: 128,
            superblock_workers: 0,
            update_max_chain: 8,
            obs: obs::ObsConfig::default(),
            store: None,
        }
    }
}

/// Outcome of an `"update"` request: a response, or the one typed miss the
/// client is expected to handle by re-solving the mutated graph from
/// scratch (wire code [`types::CODE_UPDATE_BASE_MISSING`]).
pub enum UpdateOutcome {
    Solved(Response),
    BaseMissing { fingerprint: u64 },
}

/// Outcome of a deadline-carrying solve.  The vendored `anyhow` subset has
/// no downcasting, so "the deadline expired" is a typed success variant
/// rather than an error the server would have to string-match: `Err` still
/// means the request itself was bad or a tier failed.
pub enum SolveOutcome {
    Done(Response),
    /// The deadline passed between solve phases; `phase` names the work
    /// that was about to start (`"solve"`) or had just finished
    /// (`"finish"`).  A `"finish"` expiry already cached the closure, so a
    /// client retry is cheap.
    DeadlineExceeded { phase: &'static str },
}

/// The coordinator: validates, routes, caches, and dispatches solves.
/// `Send + Sync`; server handler threads share one instance.
pub struct Coordinator {
    engine: Engine,
    cache: cache::ResultCache,
    metrics: Arc<metrics::Metrics>,
    router: router::RouterConfig,
    manifest_summary: ManifestSummary,
    /// Full manifest, kept for per-variant bucket lookups (the router's
    /// `device_buckets` is one flattened list; superblock diagonal solves
    /// must use a size the *diagonal variant* was actually lowered at).
    manifest: Manifest,
    /// Device variant used for superblock diagonal solves when the request
    /// names the "superblock" pseudo-variant.
    superblock_variant: String,
    superblock_workers: usize,
    update_max_chain: u32,
    obs: obs::ObsConfig,
    journal: Arc<obs::TraceJournal>,
}

/// What the coordinator knows about the artifacts (for `info` requests and
/// routing) without touching the PJRT client.
#[derive(Clone, Debug)]
pub struct ManifestSummary {
    pub variants: Vec<String>,
    pub buckets: Vec<usize>,
    pub tile: usize,
}

impl Coordinator {
    /// Start the engine thread and load routing metadata.
    pub fn start(mut config: Config) -> Result<Coordinator> {
        let manifest = Manifest::load(&config.artifact_dir)
            .context("coordinator: loading artifact manifest")?;
        // superblock diagonal solves prefer "staged" (the paper's kernel),
        // falling back to whatever the manifest actually lowered
        let variants = manifest.variants();
        let superblock_variant = if variants.iter().any(|v| v == "staged") {
            "staged".to_string()
        } else {
            variants.first().cloned().unwrap_or_default()
        };
        let summary = ManifestSummary {
            buckets: manifest.sizes_for(&superblock_variant),
            variants,
            tile: manifest.tile,
        };
        // the router's variant/bucket tables are derived from the manifest
        // here — RouterConfig::default() is intentionally empty, so new
        // artifact variants are routable without code changes
        config.router.device_variants = summary.variants.clone();
        config.router.device_buckets = summary.buckets.clone();
        let metrics = Arc::new(metrics::Metrics::new());
        let engine = Engine::start(config.engine, metrics.clone())?;
        let cache = match config.store {
            Some(store_config) => {
                let store = Arc::new(
                    store::Store::open(store_config, metrics.clone())
                        .context("coordinator: opening closure store")?,
                );
                // single worker by contract: FIFO persistence order is
                // what makes flush_store a barrier (cache.rs documents it)
                let writer = crate::util::pool::JobPool::new(crate::util::pool::PoolConfig {
                    workers: 1,
                    queue_depth: 256,
                    name: "fw-store".into(),
                });
                let cache = cache::ResultCache::with_store(config.cache_capacity, store, writer);
                let warmed = cache.warm_from_store();
                obs::log::log(
                    obs::log::Level::Info,
                    "store_warm_start",
                    vec![(
                        "entries",
                        crate::util::json::Json::Num(warmed as f64),
                    )],
                );
                cache
            }
            None => cache::ResultCache::new(config.cache_capacity),
        };
        Ok(Coordinator {
            engine,
            cache,
            metrics,
            router: config.router,
            manifest_summary: summary,
            manifest,
            superblock_variant,
            superblock_workers: config.superblock_workers,
            update_max_chain: config.update_max_chain,
            obs: config.obs,
            journal: Arc::new(obs::TraceJournal::new(config.obs.journal_capacity)),
        })
    }

    pub fn metrics(&self) -> &metrics::Metrics {
        &self.metrics
    }

    pub fn obs(&self) -> &obs::ObsConfig {
        &self.obs
    }

    /// The trace journal (the server records finished request traces here
    /// and serves them back for `{"type":"trace"}` requests).
    pub fn journal(&self) -> &obs::TraceJournal {
        &self.journal
    }

    pub fn manifest_summary(&self) -> &ManifestSummary {
        &self.manifest_summary
    }

    /// The persistent closure store, when one was configured.
    pub fn store(&self) -> Option<&store::Store> {
        self.cache.store()
    }

    /// Barrier: wait for every closure persist enqueued so far to reach
    /// disk.  No-op without a store.  Teardown/test helper — the request
    /// path never calls this (persistence is write-behind by design).
    pub fn flush_store(&self) {
        self.cache.flush_store()
    }

    /// Serve one request (blocking). This is the whole request path.
    pub fn solve(&self, req: &Request) -> Result<Response> {
        match self.solve_with_deadline(req, None)? {
            SolveOutcome::Done(resp) => Ok(resp),
            SolveOutcome::DeadlineExceeded { .. } => {
                unreachable!("no deadline was set, so none can expire")
            }
        }
    }

    /// [`Coordinator::solve`] with an optional absolute deadline checked
    /// between solve phases (after a cache miss, before encoding), so work
    /// whose client has given up is abandoned early instead of burning a
    /// worker.  `None` never expires.
    pub fn solve_with_deadline(
        &self,
        req: &Request,
        deadline: Option<Instant>,
    ) -> Result<SolveOutcome> {
        self.metrics.record_request();
        self.solve_impl(req, true, None, deadline)
    }

    /// Serve one request while assembling its span tree: the route
    /// decision (with the router's reason), the tier solve (with
    /// phase/round breakdown from the profiled solver twins), and cache
    /// traffic.  The server journals the returned root and splices in its
    /// own decode/encode spans.  [`Coordinator::solve`] is the span-free
    /// path; tracing never changes solver outputs (bitwise — pinned by the
    /// conformance suite).
    pub fn solve_spanned(&self, req: &Request) -> Result<(Response, Span)> {
        match self.solve_spanned_with_deadline(req, None)? {
            (SolveOutcome::Done(resp), root) => Ok((resp, root)),
            (SolveOutcome::DeadlineExceeded { .. }, _) => {
                unreachable!("no deadline was set, so none can expire")
            }
        }
    }

    /// [`Coordinator::solve_spanned`] with an optional deadline — the
    /// traced twin of [`Coordinator::solve_with_deadline`].
    pub fn solve_spanned_with_deadline(
        &self,
        req: &Request,
        deadline: Option<Instant>,
    ) -> Result<(SolveOutcome, Span)> {
        self.metrics.record_request();
        let t0 = Instant::now();
        let mut root = Span::new("request");
        let out = self.solve_impl(req, true, Some(&mut root), deadline);
        root.seconds = t0.elapsed().as_secs_f64();
        out.map(|outcome| (outcome, root))
    }

    /// The request path, with per-request metrics (request count, solve
    /// counters, latency samples) optionally suppressed — the update
    /// tier's re-baselining runs a full solve *inside* one wire request
    /// and must not double-count it.  Work-level metrics (superblock
    /// rounds/tiles, engine batches) still record: that work really ran.
    fn solve_impl(
        &self,
        req: &Request,
        record: bool,
        span: Option<&mut Span>,
        deadline: Option<Instant>,
    ) -> Result<SolveOutcome> {
        let t0 = Instant::now();
        let traced = span.is_some();
        let objective = router::objective_gate(&req.variant, &req.objective)
            .map_err(|e| anyhow::anyhow!(e))?;
        req.graph
            .validate()
            .map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        // non-shortest objectives rewrite the graph into the semiring's
        // domain (and reject weights outside it) before any solver runs;
        // cache keys stay on the *raw* request graph, with the objective
        // mixed into the fingerprint.  Shortest skips the rewrite — its
        // request path is byte-identical to the pre-semiring stack.
        let prepared = match objective {
            Objective::Shortest => None,
            other => Some(other.prepare(&req.graph).map_err(|e| {
                anyhow::anyhow!("objective {:?}: {e}", other.name())
            })?),
        };

        // cache (paths requests only hit entries that carry successors);
        // a memory miss reads through to the closure store when one is
        // configured — disk hits reply Source::Cache like any other hit
        if !req.no_cache {
            let cache_start = Instant::now();
            let hit = if req.want_paths {
                self.cache
                    .lookup_paths_for(objective, &req.variant, &req.graph)
                    .map(|hit| {
                        let from_disk = hit.from_disk();
                        let (dist, succ) = hit.into_inner();
                        // deep copies happen here, outside the cache lock
                        ((*dist).clone(), Some((*succ).clone()), from_disk)
                    })
            } else {
                self.cache
                    .lookup_for(objective, &req.variant, &req.graph)
                    .map(|hit| {
                        let from_disk = hit.from_disk();
                        ((*hit.into_inner()).clone(), None, from_disk)
                    })
            };
            if let Some((dist, succ, from_disk)) = hit {
                let seconds = t0.elapsed().as_secs_f64();
                if record {
                    self.metrics.record_solve(Source::Cache, objective, seconds);
                }
                if let Some(span) = span {
                    let mut get = Span::new("cache_get");
                    get.seconds = cache_start.elapsed().as_secs_f64();
                    get.note("hit", "true");
                    // span shape is pinned for store-less serving; the
                    // extra note and child only appear with a store
                    if self.cache.has_store() {
                        get.note("from", if from_disk { "store" } else { "memory" });
                    }
                    if from_disk {
                        // the read-through dominated this lookup's time
                        let mut sg = Span::new("store_get");
                        sg.seconds = get.seconds;
                        get.child(sg);
                    }
                    span.child(get);
                }
                return Ok(SolveOutcome::Done(Response {
                    id: req.id,
                    dist,
                    succ,
                    source: Source::Cache,
                    bucket: req.graph.n(),
                    seconds,
                }));
            }
        }

        // phase boundary: a request that missed the cache and has already
        // outlived its deadline is abandoned before the expensive part
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Ok(SolveOutcome::DeadlineExceeded { phase: "solve" });
        }

        // route
        let route_start = Instant::now();
        let (route, route_reason) = router::route_objective_reasoned(
            &self.router,
            &req.variant,
            req.graph.n(),
            req.want_paths,
            objective,
        )
        .map_err(|e| anyhow::anyhow!(e))?;
        let route_seconds = route_start.elapsed().as_secs_f64();

        // solve; traced requests take the profiled solver twins (bitwise
        // identical to the plain ones — timing reads sit between phases)
        // so the solve span carries the phase/round breakdown
        let solve_start = Instant::now();
        let mut phase_prof: Option<apsp::blocked::PhaseProfile> = None;
        let mut pool_prof: Option<(f64, f64, f64, usize, usize)> = None;
        let (dist, succ, source, bucket) = match route {
            router::Route::Cpu { tile } => match &prepared {
                None => {
                    if req.want_paths {
                        let (dist, succ) =
                            apsp::blocked::solve_paths(&req.graph, tile).into_parts();
                        (dist, Some(succ), Source::Cpu, req.graph.n())
                    } else if traced {
                        let (dist, prof) = apsp::blocked::solve_profiled(&req.graph, tile);
                        phase_prof = Some(prof);
                        (dist, None, Source::Cpu, req.graph.n())
                    } else {
                        let dist = apsp::blocked::solve(&req.graph, tile);
                        (dist, None, Source::Cpu, req.graph.n())
                    }
                }
                Some(g) => {
                    if req.want_paths {
                        let (dist, succ) =
                            apsp::semiring::blocked_solve_paths(objective, g, tile).into_parts();
                        (dist, Some(succ), Source::Cpu, req.graph.n())
                    } else if traced {
                        let (dist, prof) =
                            apsp::blocked::solve_profiled_objective(objective, g, tile);
                        phase_prof = Some(prof);
                        (dist, None, Source::Cpu, req.graph.n())
                    } else {
                        let dist = apsp::semiring::blocked_solve(objective, g, tile);
                        (dist, None, Source::Cpu, req.graph.n())
                    }
                }
            },
            router::Route::Johnson => {
                // the router rejects want_paths for johnson before this arm
                let dist = apsp::johnson::solve(&req.graph)
                    .map_err(|e| anyhow::anyhow!("johnson: {e}"))?;
                (dist, None, Source::Cpu, req.graph.n())
            }
            router::Route::Device => {
                if req.want_paths {
                    // distances-only artifacts: CPU path fallback
                    // (Engine::solve_paths documents why)
                    let r = self.engine.solve_paths(&req.graph, self.router.cpu_tile);
                    let (dist, succ) = r.into_parts();
                    (dist, Some(succ), Source::Cpu, req.graph.n())
                } else {
                    let solve = self.engine.solve(&req.variant, req.graph.clone())?;
                    (solve.dist, None, Source::Device, solve.bucket)
                }
            }
            router::Route::SuperBlock { bucket } if prepared.is_some() => {
                // non-shortest objectives: the same three-phase schedule,
                // but diagonal tiles run the CPU semiring kernel — the AOT
                // artifacts bake in (min, +) — so the routed bucket is used
                // as-is (no manifest re-pick for a diagonal variant)
                let g = prepared.as_ref().unwrap();
                let cfg = superblock::SuperBlockConfig {
                    bucket,
                    workers: self.superblock_workers,
                    profile: traced,
                };
                if req.want_paths {
                    let (r, report) = superblock::solve_paths_objective(objective, g, &cfg);
                    self.metrics.record_superblock(
                        report.round_count() as u64,
                        report.total_tiles() as u64,
                    );
                    pool_prof = pool_stats(&report, traced);
                    let (dist, succ) = r.into_parts();
                    (dist, Some(succ), Source::SuperBlock, bucket)
                } else {
                    let (dist, report) = superblock::solve_cpu_objective(objective, g, &cfg);
                    self.metrics.record_superblock(
                        report.round_count() as u64,
                        report.total_tiles() as u64,
                    );
                    pool_prof = pool_stats(&report, traced);
                    (dist, None, Source::SuperBlock, bucket)
                }
            }
            router::Route::SuperBlock { bucket } => {
                // the paper's three-phase schedule over device-bucket
                // super-tiles: diagonal tiles go through the engine, panel
                // and interior min-plus updates stream across the pool
                let diag_variant = if req.variant == "superblock" {
                    self.superblock_variant.as_str()
                } else {
                    req.variant.as_str()
                };
                // the routed bucket came from the flattened bucket list; if
                // the diagonal variant was lowered at different sizes
                // (mixed manifests), re-pick from the sizes it actually
                // has — unless the operator pinned the bucket explicitly,
                // which must fail loudly rather than be silently replaced
                let diag_sizes = self.manifest.sizes_for(diag_variant);
                let bucket = if diag_sizes.contains(&bucket) {
                    bucket
                } else if self.router.superblock_bucket.is_some() {
                    anyhow::bail!(
                        "superblock bucket {bucket} is not a lowered size for \
                         variant {diag_variant:?} (available: {diag_sizes:?})"
                    );
                } else {
                    router::pick_superblock_bucket(&diag_sizes, req.graph.n()).ok_or_else(
                        || anyhow::anyhow!("no artifacts for variant {diag_variant:?}"),
                    )?
                };
                let cfg = superblock::SuperBlockConfig {
                    bucket,
                    workers: self.superblock_workers,
                    profile: traced,
                };
                if req.want_paths {
                    // path mode carries successor tiles through the same
                    // pool; diagonal tiles run the CPU succ kernel (no
                    // successor-tracking artifact exists to dispatch)
                    let (r, report) = superblock::solve_paths(&req.graph, &cfg);
                    self.metrics.record_superblock(
                        report.round_count() as u64,
                        report.total_tiles() as u64,
                    );
                    pool_prof = pool_stats(&report, traced);
                    let (dist, succ) = r.into_parts();
                    (dist, Some(succ), Source::SuperBlock, bucket)
                } else {
                    let (dist, report) = superblock::solve_with(&req.graph, &cfg, |tile| {
                        Ok(self.engine.solve(diag_variant, tile)?.dist)
                    })?;
                    self.metrics.record_superblock(
                        report.round_count() as u64,
                        report.total_tiles() as u64,
                    );
                    pool_prof = pool_stats(&report, traced);
                    (dist, None, Source::SuperBlock, bucket)
                }
            }
        };
        let solve_seconds = solve_start.elapsed().as_secs_f64();

        let put_start = Instant::now();
        if !req.no_cache {
            match &succ {
                Some(succ) => self.cache.put_paths_for(
                    objective,
                    &req.variant,
                    &req.graph,
                    dist.clone(),
                    succ.clone(),
                ),
                None => self.cache.put_for(objective, &req.variant, &req.graph, dist.clone()),
            }
        }
        let put_seconds = put_start.elapsed().as_secs_f64();
        // phase boundary: the closure is computed and cached, but if the
        // deadline passed mid-solve nobody is waiting for the reply — skip
        // encoding and report the typed expiry (a retry now hits the cache)
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Ok(SolveOutcome::DeadlineExceeded { phase: "finish" });
        }
        let seconds = t0.elapsed().as_secs_f64();
        if record {
            self.metrics.record_solve(source, objective, seconds);
        }
        if let Some(span) = span {
            let mut r = Span::new("route");
            r.seconds = route_seconds;
            let decision = match route {
                router::Route::Cpu { .. } => "cpu",
                router::Route::Johnson => "johnson",
                router::Route::Device => "device",
                router::Route::SuperBlock { .. } => "superblock",
            };
            r.note("decision", decision);
            r.note("reason", route_reason);
            span.child(r);
            let mut s = Span::new("solve");
            s.seconds = solve_seconds;
            s.note("source", source.name());
            s.note("bucket", bucket.to_string());
            if let Some(p) = phase_prof {
                s.note("phase1_s", p.phase1_seconds.to_string());
                s.note("phase2_s", p.phase2_seconds.to_string());
                s.note("phase3_s", p.phase3_seconds.to_string());
                s.note("rounds", p.rounds.to_string());
            }
            if let Some((busy, idle, occupancy, critical_path, rounds)) = pool_prof {
                s.note("busy_s", busy.to_string());
                s.note("idle_s", idle.to_string());
                s.note("occupancy", occupancy.to_string());
                s.note("critical_path", critical_path.to_string());
                s.note("rounds", rounds.to_string());
            }
            span.child(s);
            if !req.no_cache {
                let mut put = Span::new("cache_put");
                put.seconds = put_seconds;
                span.child(put);
                if self.cache.has_store() {
                    // the disk write is write-behind: enqueued during
                    // cache_put, performed off the request path.  The span
                    // marks that the persist was scheduled, not its I/O.
                    let mut sp = Span::new("store_put");
                    sp.note("async", "true");
                    span.child(sp);
                }
            }
        }
        Ok(SolveOutcome::Done(Response {
            id: req.id,
            dist,
            succ,
            source,
            bucket,
            seconds,
        }))
    }

    /// Serve one incremental `"update"` request: apply an edge-delta batch
    /// to a cached base closure, addressed by fingerprint.
    ///
    /// The cache chains: the result is stored under the *mutated* graph's
    /// fingerprint with `chain = base.chain + 1`, so a follow-up update
    /// against that fingerprint keeps chaining — and a plain solve of the
    /// mutated graph hits the same entry.  A chain longer than
    /// [`Config::update_max_chain`] re-baselines: the batch is served by a
    /// full solve dispatched through [`Coordinator::solve`] (so device- and
    /// superblock-scale re-baselines still reach their fast tiers, and the
    /// fresh closure is cached with `chain = 0`).  The same full-solve path
    /// serves the two cases the incremental kernels cannot: a paths request
    /// against a successor-less base entry, and an effective *increase*
    /// against one (damage detection needs the stored successor forest).
    pub fn update(&self, req: &types::UpdateRequest) -> Result<UpdateOutcome> {
        let t0 = Instant::now();
        self.metrics.record_request();
        router::objective_gate_update(&req.objective).map_err(|e| anyhow::anyhow!(e))?;
        router::route_update(&self.router, &req.variant, req.n, req.want_paths)
            .map_err(|e| anyhow::anyhow!(e))?;
        let Some(base) = self
            .cache
            .get_base(&req.variant, req.n, req.base_fingerprint)
        else {
            return Ok(UpdateOutcome::BaseMissing {
                fingerprint: req.base_fingerprint,
            });
        };
        let g_new = apsp::incremental::mutated(&base.graph, &req.updates)
            .map_err(|e| anyhow::anyhow!("invalid update batch: {e}"))?;
        let needs_succ_rebaseline = base.succ.is_none()
            && (req.want_paths
                || apsp::incremental::has_effective_increase(&base.graph, &req.updates)
                    .map_err(|e| anyhow::anyhow!("invalid update batch: {e}"))?);
        let rebaseline = base.chain + 1 > self.update_max_chain || needs_succ_rebaseline;

        let ucfg = apsp::incremental::UpdateConfig {
            tile: self.router.cpu_tile,
            ..apsp::incremental::UpdateConfig::default()
        };
        let (dist, succ, recomputed) = if rebaseline {
            // full solve through the normal routing (device/superblock
            // tiers included); it caches the fresh baseline itself.  The
            // per-request metrics stay suppressed — this is still the one
            // wire request recorded as Source::Incremental below
            let resp = match self.solve_impl(
                &Request {
                    id: req.id,
                    graph: g_new,
                    variant: req.variant.clone(),
                    no_cache: false,
                    want_paths: req.want_paths || base.succ.is_some(),
                    objective: types::DEFAULT_OBJECTIVE.into(),
                    trace: false,
                },
                false,
                None,
                None,
            )? {
                SolveOutcome::Done(resp) => resp,
                SolveOutcome::DeadlineExceeded { .. } => {
                    unreachable!("re-baselining solves carry no deadline")
                }
            };
            (resp.dist, resp.succ, true)
        } else if let Some(base_succ) = base.succ {
            // the base payloads are shared with the cache entry; reuse the
            // allocation when this request is the only holder
            let closure = apsp::paths::PathsResult::from_parts(
                Arc::unwrap_or_clone(base.dist),
                Arc::unwrap_or_clone(base_succ),
            );
            let (r, stats) =
                apsp::incremental::update_paths(&base.graph, &closure, &req.updates, &ucfg)
                    .map_err(|e| anyhow::anyhow!("update: {e}"))?;
            let (dist, succ) = r.into_parts();
            let chain = if stats.recomputed { 0 } else { base.chain + 1 };
            self.cache
                .put_chained(&req.variant, &g_new, dist.clone(), Some(succ.clone()), chain);
            (dist, Some(succ), stats.recomputed)
        } else {
            // decrease-only batch against a distance-only entry
            let (dist, stats) =
                apsp::incremental::update_dist(&base.graph, &base.dist, &req.updates, &ucfg)
                    .map_err(|e| anyhow::anyhow!("update: {e}"))?;
            let chain = if stats.recomputed { 0 } else { base.chain + 1 };
            self.cache
                .put_chained(&req.variant, &g_new, dist.clone(), None, chain);
            (dist, None, stats.recomputed)
        };
        self.metrics
            .record_update(req.updates.len() as u64, recomputed);
        let seconds = t0.elapsed().as_secs_f64();
        self.metrics
            .record_solve(Source::Incremental, Objective::Shortest, seconds);
        Ok(UpdateOutcome::Solved(Response {
            id: req.id,
            dist,
            succ: if req.want_paths { succ } else { None },
            source: Source::Incremental,
            bucket: req.n,
            seconds,
        }))
    }

    /// Convenience: solve a bare graph with defaults.
    pub fn solve_graph(&self, graph: &DistMatrix, variant: &str) -> Result<DistMatrix> {
        self.solve_graph_for(graph, variant, types::DEFAULT_OBJECTIVE)
    }

    /// Convenience: solve a bare graph under an explicit serving objective.
    pub fn solve_graph_for(
        &self,
        graph: &DistMatrix,
        variant: &str,
        objective: &str,
    ) -> Result<DistMatrix> {
        let resp = self.solve(&Request {
            id: 0,
            graph: graph.clone(),
            variant: variant.to_string(),
            no_cache: false,
            want_paths: false,
            objective: objective.to_string(),
            trace: false,
        })?;
        Ok(resp.dist)
    }

    /// Convenience: solve a bare graph and reconstruct paths.
    pub fn solve_graph_paths(
        &self,
        graph: &DistMatrix,
        variant: &str,
    ) -> Result<apsp::paths::PathsResult> {
        let resp = self.solve(&Request {
            id: 0,
            graph: graph.clone(),
            variant: variant.to_string(),
            no_cache: false,
            want_paths: true,
            objective: types::DEFAULT_OBJECTIVE.into(),
            trace: false,
        })?;
        let succ = resp
            .succ
            .ok_or_else(|| anyhow::anyhow!("paths requested but response has no successors"))?;
        Ok(apsp::paths::PathsResult::from_parts(resp.dist, succ))
    }
}

/// Pool-occupancy stats for a traced superblock solve, as
/// `(busy_s, idle_s, occupancy, critical_path, rounds)`; `None` when the
/// solve ran unprofiled (untraced requests pay zero accounting cost).
fn pool_stats(
    report: &superblock::Report,
    traced: bool,
) -> Option<(f64, f64, f64, usize, usize)> {
    traced.then(|| {
        (
            report.busy_seconds(),
            report.idle_seconds(),
            report.occupancy(),
            report.max_critical_path(),
            report.round_count(),
        )
    })
}

//! Layer-3 coordinator: the serving system around the AOT kernels.
//!
//! ```text
//!            TCP (JSON lines)                 mpsc (bounded)
//!  clients ───────────────► server ─┬─► router ──► engine thread ─► PJRT
//!                                   │      │          (batcher,
//!                                   │      └─► CPU fallback)
//!                                   └─► cache / metrics
//! ```
//!
//! * [`types`] — request/response structs + wire codec
//! * [`router`] — CPU-vs-device routing policy
//! * [`batcher`] — block-diagonal packing plans
//! * [`engine`] — the PJRT executor thread
//! * [`cache`] — LRU result cache
//! * [`metrics`] — counters + latency summaries
//! * [`server`] / [`client`] — TCP front end and a blocking client

pub mod batcher;
pub mod cache;
pub mod client;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;
pub mod types;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::apsp;
use crate::graph::DistMatrix;
use crate::runtime::Manifest;

pub use engine::{Engine, EngineConfig};
pub use types::{Request, Response, Source};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub artifact_dir: PathBuf,
    pub router: router::RouterConfig,
    pub engine: EngineConfig,
    /// Result-cache capacity (entries); 0 disables.
    pub cache_capacity: usize,
}

impl Config {
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Self {
        let artifact_dir = artifact_dir.into();
        Config {
            engine: EngineConfig::new(&artifact_dir),
            artifact_dir,
            router: router::RouterConfig::default(),
            cache_capacity: 128,
        }
    }
}

/// The coordinator: validates, routes, caches, and dispatches solves.
/// `Send + Sync`; server handler threads share one instance.
pub struct Coordinator {
    engine: Engine,
    cache: cache::ResultCache,
    metrics: Arc<metrics::Metrics>,
    router: router::RouterConfig,
    manifest_summary: ManifestSummary,
}

/// What the coordinator knows about the artifacts (for `info` requests and
/// routing) without touching the PJRT client.
#[derive(Clone, Debug)]
pub struct ManifestSummary {
    pub variants: Vec<String>,
    pub buckets: Vec<usize>,
    pub tile: usize,
}

impl Coordinator {
    /// Start the engine thread and load routing metadata.
    pub fn start(mut config: Config) -> Result<Coordinator> {
        let manifest = Manifest::load(&config.artifact_dir)
            .context("coordinator: loading artifact manifest")?;
        let summary = ManifestSummary {
            variants: manifest.variants(),
            buckets: manifest.sizes_for("staged"),
            tile: manifest.tile,
        };
        config.router.device_variants = summary.variants.clone();
        let metrics = Arc::new(metrics::Metrics::new());
        let engine = Engine::start(config.engine, metrics.clone())?;
        Ok(Coordinator {
            engine,
            cache: cache::ResultCache::new(config.cache_capacity),
            metrics,
            router: config.router,
            manifest_summary: summary,
        })
    }

    pub fn metrics(&self) -> &metrics::Metrics {
        &self.metrics
    }

    pub fn manifest_summary(&self) -> &ManifestSummary {
        &self.manifest_summary
    }

    /// Serve one request (blocking). This is the whole request path.
    pub fn solve(&self, req: &Request) -> Result<Response> {
        let t0 = Instant::now();
        self.metrics.record_request();
        req.graph
            .validate()
            .map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;

        // cache
        if !req.no_cache {
            if let Some(dist) = self.cache.get(&req.variant, &req.graph) {
                let seconds = t0.elapsed().as_secs_f64();
                self.metrics.record_solve(Source::Cache, seconds);
                return Ok(Response {
                    id: req.id,
                    dist,
                    source: Source::Cache,
                    bucket: req.graph.n(),
                    seconds,
                });
            }
        }

        // route
        let route = router::route(&self.router, &req.variant, req.graph.n())
            .map_err(|e| anyhow::anyhow!(e))?;
        let (dist, source, bucket) = match route {
            router::Route::Cpu { tile } => {
                let dist = apsp::blocked::solve(&req.graph, tile);
                (dist, Source::Cpu, req.graph.n())
            }
            router::Route::Johnson => {
                let dist = apsp::johnson::solve(&req.graph)
                    .map_err(|e| anyhow::anyhow!("johnson: {e}"))?;
                (dist, Source::Cpu, req.graph.n())
            }
            router::Route::Device => {
                let solve = self.engine.solve(&req.variant, req.graph.clone())?;
                (solve.dist, Source::Device, solve.bucket)
            }
        };

        if !req.no_cache {
            self.cache.put(&req.variant, &req.graph, dist.clone());
        }
        let seconds = t0.elapsed().as_secs_f64();
        self.metrics.record_solve(source, seconds);
        Ok(Response {
            id: req.id,
            dist,
            source,
            bucket,
            seconds,
        })
    }

    /// Convenience: solve a bare graph with defaults.
    pub fn solve_graph(&self, graph: &DistMatrix, variant: &str) -> Result<DistMatrix> {
        let resp = self.solve(&Request {
            id: 0,
            graph: graph.clone(),
            variant: variant.to_string(),
            no_cache: false,
        })?;
        Ok(resp.dist)
    }
}

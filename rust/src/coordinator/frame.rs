//! Length-prefixed binary response frame (opt-in wire encoding).
//!
//! A JSON result line renders every f32 as shortest-round-trip decimal —
//! readable, diffable, and the right default, but an n=1024 dist+succ
//! response is tens of MB of text and the decode cost dwarfs the solve at
//! serving scale.  Requests that set `"binary": true` get this frame
//! instead: a fixed 40-byte header followed by the raw little-endian
//! matrices.  Decoding is `from_le_bytes` per cell — bitwise exact by
//! construction, no formatting or parsing on either side.
//!
//! ## Layout (all integers little-endian)
//!
//! | offset | size | field                                                |
//! |-------:|-----:|------------------------------------------------------|
//! |      0 |    4 | magic `"FWBF"`                                       |
//! |      4 |    1 | version (currently 1)                                |
//! |      5 |    1 | flags (bit 0: successor matrix present)              |
//! |      6 |    1 | source tag (0 device, 1 cpu, 2 cache, 3 superblock, 4 incremental) |
//! |      7 |    1 | reserved (0)                                         |
//! |      8 |    4 | n (u32)                                              |
//! |     12 |    4 | bucket (u32)                                         |
//! |     16 |    8 | request id (u64)                                     |
//! |     24 |    8 | seconds (f64)                                        |
//! |     32 |    8 | body length in bytes (u64)                           |
//! |     40 | body | n² f32 dist (row-major), then n² u32 succ if flagged  |
//!
//! `+inf` distances travel as their IEEE bits (binary needs no `null`
//! convention); [`NO_PATH`] successors travel as `u32::MAX`.  The body
//! length is redundant with `n` + flags and is validated against them —
//! a cheap corruption check that also lets proxies skip frames blind.
//!
//! A JSON line can never be confused with a frame: lines start with `{`
//! (0x7B) and the magic starts with `F` (0x46), which is how the client
//! demultiplexes replies from servers that ignored the negotiation.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::types::{Response, Source, MAX_N};
use crate::apsp::paths::NO_PATH;
use crate::graph::DistMatrix;

/// Frame magic: the first four bytes of every binary response.
pub const MAGIC: [u8; 4] = *b"FWBF";

/// Current frame version.
pub const VERSION: u8 = 1;

/// Total header size in bytes.
pub const HEADER_LEN: usize = 40;

/// Flags bit 0: the body carries an n² u32 successor matrix after dist.
pub const FLAG_SUCC: u8 = 1;

/// Wire rendering of [`NO_PATH`] in the successor matrix.
const NO_PATH_WIRE: u32 = u32::MAX;

fn source_tag(source: Source) -> u8 {
    match source {
        Source::Device => 0,
        Source::Cpu => 1,
        Source::Cache => 2,
        Source::SuperBlock => 3,
        Source::Incremental => 4,
    }
}

fn source_from_tag(tag: u8) -> Result<Source> {
    Ok(match tag {
        0 => Source::Device,
        1 => Source::Cpu,
        2 => Source::Cache,
        3 => Source::SuperBlock,
        4 => Source::Incremental,
        other => bail!("frame: unknown source tag {other}"),
    })
}

fn body_len(n: usize, with_succ: bool) -> u64 {
    let cells = (n as u64) * (n as u64);
    cells * 4 * if with_succ { 2 } else { 1 }
}

/// Stream a response as one frame.  Rows are staged through a single
/// reused n·4-byte buffer, so peak formatting state is O(n) — the same
/// streaming discipline as [`super::types::write_response`].
pub fn write_frame<W: Write>(out: &mut W, resp: &Response) -> std::io::Result<()> {
    let n = resp.dist.n();
    let with_succ = resp.succ.is_some();
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[5] = if with_succ { FLAG_SUCC } else { 0 };
    header[6] = source_tag(resp.source);
    header[8..12].copy_from_slice(&(n as u32).to_le_bytes());
    header[12..16].copy_from_slice(&(resp.bucket as u32).to_le_bytes());
    header[16..24].copy_from_slice(&resp.id.to_le_bytes());
    header[24..32].copy_from_slice(&resp.seconds.to_le_bytes());
    header[32..40].copy_from_slice(&body_len(n, with_succ).to_le_bytes());
    out.write_all(&header)?;
    let mut row_buf = vec![0u8; n * 4];
    for i in 0..n {
        for (cell, w) in row_buf.chunks_exact_mut(4).zip(resp.dist.row(i)) {
            cell.copy_from_slice(&w.to_le_bytes());
        }
        out.write_all(&row_buf)?;
    }
    if let Some(succ) = &resp.succ {
        debug_assert_eq!(succ.len(), n * n);
        for row in succ.chunks_exact(n) {
            for (cell, &s) in row_buf.chunks_exact_mut(4).zip(row) {
                let wire = if s == NO_PATH { NO_PATH_WIRE } else { s as u32 };
                cell.copy_from_slice(&wire.to_le_bytes());
            }
            out.write_all(&row_buf)?;
        }
    }
    Ok(())
}

/// Encode a response as one in-memory frame (benches, tests, tooling; the
/// server streams via [`write_frame`]).
pub fn encode_frame(resp: &Response) -> Vec<u8> {
    let n = resp.dist.n();
    let mut out = Vec::with_capacity(HEADER_LEN + body_len(n, resp.succ.is_some()) as usize);
    write_frame(&mut out, resp).expect("writing a frame to a Vec cannot fail");
    out
}

/// Read a whole frame, magic included.
pub fn read_frame<R: Read>(input: &mut R) -> Result<Response> {
    let mut magic = [0u8; 4];
    input.read_exact(&mut magic).context("frame: reading magic")?;
    if magic != MAGIC {
        bail!("frame: bad magic {magic:?} (expected {MAGIC:?})");
    }
    read_frame_body(input)
}

/// Read a frame whose 4-byte magic was already consumed (the client peeks
/// the magic to demultiplex frame vs JSON replies on one stream).
pub fn read_frame_body<R: Read>(input: &mut R) -> Result<Response> {
    let mut rest = [0u8; HEADER_LEN - 4];
    input.read_exact(&mut rest).context("frame: reading header")?;
    let version = rest[0];
    if version != VERSION {
        bail!("frame: unsupported version {version} (this build speaks {VERSION})");
    }
    let flags = rest[1];
    if flags & !FLAG_SUCC != 0 {
        bail!("frame: unknown flag bits 0x{:02x}", flags & !FLAG_SUCC);
    }
    let with_succ = flags & FLAG_SUCC != 0;
    let source = source_from_tag(rest[2])?;
    let n = u32::from_le_bytes(rest[4..8].try_into().unwrap()) as usize;
    if n == 0 || n > MAX_N {
        bail!("frame: n={n} outside 1..={MAX_N}");
    }
    let bucket = u32::from_le_bytes(rest[8..12].try_into().unwrap()) as usize;
    let id = u64::from_le_bytes(rest[12..20].try_into().unwrap());
    let seconds = f64::from_le_bytes(rest[20..28].try_into().unwrap());
    let declared = u64::from_le_bytes(rest[28..36].try_into().unwrap());
    let expected = body_len(n, with_succ);
    if declared != expected {
        bail!("frame: body length {declared} does not match n={n} flags=0x{flags:02x} (expected {expected})");
    }
    let mut row_buf = vec![0u8; n * 4];
    let mut data = Vec::with_capacity(n * n);
    for i in 0..n {
        input
            .read_exact(&mut row_buf)
            .with_context(|| format!("frame: reading dist row {i}"))?;
        data.extend(row_buf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())));
    }
    let dist = DistMatrix::from_vec(n, data);
    let succ = if with_succ {
        let mut succ = Vec::with_capacity(n * n);
        for i in 0..n {
            input
                .read_exact(&mut row_buf)
                .with_context(|| format!("frame: reading succ row {i}"))?;
            for cell in row_buf.chunks_exact(4) {
                let wire = u32::from_le_bytes(cell.try_into().unwrap());
                if wire == NO_PATH_WIRE {
                    succ.push(NO_PATH);
                } else {
                    let s = wire as usize;
                    if s >= n {
                        bail!("frame: successor {s} out of range for n={n}");
                    }
                    succ.push(s);
                }
            }
        }
        Some(succ)
    } else {
        None
    };
    Ok(Response { id, dist, succ, source, bucket, seconds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::types::encode_response;
    use crate::INF;

    fn sample(n: usize, with_succ: bool, seed: u64) -> Response {
        // xorshift-filled matrices: negatives, subnormal-ish magnitudes,
        // and a sprinkle of +inf so the null-free encoding is exercised
        let mut state = seed | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut data = Vec::with_capacity(n * n);
        for idx in 0..n * n {
            let v = if idx % 97 == 13 {
                INF
            } else {
                ((next() % 2_000_000) as f32 - 1_000_000.0) / 1024.0
            };
            data.push(v);
        }
        for i in 0..n {
            data[i * n + i] = 0.0;
        }
        let succ = with_succ.then(|| {
            (0..n * n)
                .map(|idx| if idx % 11 == 3 { NO_PATH } else { next() as usize % n })
                .collect()
        });
        Response {
            id: 0x0123_4567_89ab_cdef,
            dist: DistMatrix::from_vec(n, data),
            succ,
            source: Source::SuperBlock,
            bucket: 512,
            seconds: 0.03125,
        }
    }

    #[test]
    fn header_bytes_are_pinned() {
        let resp = Response {
            id: 7,
            dist: DistMatrix::unconnected(2),
            succ: None,
            source: Source::Device,
            bucket: 64,
            seconds: 0.5,
        };
        let frame = encode_frame(&resp);
        assert_eq!(frame.len(), HEADER_LEN + 16);
        assert_eq!(&frame[0..4], b"FWBF");
        assert_eq!(frame[4], 1, "version");
        assert_eq!(frame[5], 0, "no succ flag");
        assert_eq!(frame[6], 0, "device tag");
        assert_eq!(frame[7], 0, "reserved");
        assert_eq!(&frame[8..12], &2u32.to_le_bytes(), "n");
        assert_eq!(&frame[12..16], &64u32.to_le_bytes(), "bucket");
        assert_eq!(&frame[16..24], &7u64.to_le_bytes(), "id");
        assert_eq!(&frame[24..32], &0.5f64.to_le_bytes(), "seconds");
        assert_eq!(&frame[32..40], &16u64.to_le_bytes(), "body length");
        // diagonal 0.0, off-diagonal +inf — raw IEEE bits, no null
        assert_eq!(&frame[40..44], &0.0f32.to_le_bytes());
        assert_eq!(&frame[44..48], &INF.to_le_bytes());
    }

    #[test]
    fn round_trips_bitwise_with_inf_no_path_and_negatives() {
        let resp = sample(23, true, 0x9E37);
        let frame = encode_frame(&resp);
        let back = read_frame(&mut &frame[..]).unwrap();
        assert_eq!(back.id, resp.id);
        assert_eq!(back.source, resp.source);
        assert_eq!(back.bucket, resp.bucket);
        assert_eq!(back.seconds.to_bits(), resp.seconds.to_bits());
        for (a, b) in back.dist.as_slice().iter().zip(resp.dist.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.succ, resp.succ);
    }

    #[test]
    fn large_response_round_trips_and_beats_json_size() {
        // the acceptance-scale payload: n=1024 dist+succ.  The ISSUE's
        // headline asked for ≥5× vs line-JSON; raw LE bytes are 8 per
        // cell-pair vs ~15 for the shortest-round-trip decimal pair, so
        // the honest arithmetic ceiling is ~2×, asserted here at ≥1.7×
        // (the ≥5× win is decode *time*, measured in benches/coordinator).
        let n = 1024;
        let resp = sample(n, true, 0xACE1);
        let frame = encode_frame(&resp);
        assert_eq!(frame.len(), HEADER_LEN + 8 * n * n, "frame size is exactly header + raw body");
        let back = read_frame(&mut &frame[..]).unwrap();
        for (a, b) in back.dist.as_slice().iter().zip(resp.dist.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.succ, resp.succ);
        let json = encode_response(&resp);
        let ratio = json.len() as f64 / frame.len() as f64;
        assert!(
            ratio >= 1.7,
            "binary frame should cut the n={n} dist+succ payload ≥1.7× (got {ratio:.2}×: {} vs {} bytes)",
            json.len(),
            frame.len()
        );
        // dist-only responses cut deeper: no cheap integer succ rows
        // diluting the ratio
        let resp = sample(n, false, 0xACE1);
        let ratio = encode_response(&resp).len() as f64 / encode_frame(&resp).len() as f64;
        assert!(ratio >= 2.2, "dist-only payload cut should be ≥2.2× (got {ratio:.2}×)");
    }

    #[test]
    fn corrupt_frames_are_rejected_not_misread() {
        let good = encode_frame(&sample(4, true, 3));
        let cases: Vec<(&str, Box<dyn Fn(&mut Vec<u8>)>, &str)> = vec![
            ("magic", Box::new(|f| f[0] = b'X'), "bad magic"),
            ("version", Box::new(|f| f[4] = 9), "unsupported version"),
            ("flags", Box::new(|f| f[5] |= 0x80), "unknown flag"),
            ("source", Box::new(|f| f[6] = 200), "source tag"),
            ("n zero", Box::new(|f| f[8..12].copy_from_slice(&0u32.to_le_bytes())), "outside"),
            (
                "n huge",
                Box::new(|f| f[8..12].copy_from_slice(&1_000_000u32.to_le_bytes())),
                "outside",
            ),
            (
                "body length",
                Box::new(|f| f[32..40].copy_from_slice(&7u64.to_le_bytes())),
                "does not match",
            ),
            ("truncated", Box::new(|f| f.truncate(f.len() - 5)), "reading"),
            (
                "succ range",
                Box::new(|f| {
                    let start = HEADER_LEN + 4 * 16; // first succ cell (n=4)
                    f[start..start + 4].copy_from_slice(&99u32.to_le_bytes());
                }),
                "out of range",
            ),
        ];
        for (what, mutate, needle) in cases {
            let mut bad = good.clone();
            mutate(&mut bad);
            let err = read_frame(&mut &bad[..]).expect_err(what).to_string();
            assert!(err.contains(needle), "{what}: {err:?} missing {needle:?}");
        }
    }
}

//! Request/response types and their wire encoding.
//!
//! The server speaks line-delimited JSON over TCP.  Graphs travel as edge
//! lists (sparse graphs dominate real workloads; a dense n×n float matrix
//! would be ~4n² bytes of JSON); distance matrices return as row arrays
//! with `null` for "unreachable".

use anyhow::{bail, Context, Result};

use crate::apsp::incremental::EdgeUpdate;
use crate::apsp::paths::NO_PATH;
use crate::graph::DistMatrix;
use crate::util::json::Json;
use crate::INF;

/// Server-side cap on request sizes (shared by solve and update decoding,
/// and by the binary frame reader in [`super::frame`]).
pub(crate) const MAX_N: usize = 4096;

/// Wire error code for an update whose base closure is not cached — the
/// one failure a client is expected to *handle* (retry as a full solve of
/// the mutated graph) rather than report.
pub const CODE_UPDATE_BASE_MISSING: &str = "update_base_missing";

/// Wire error code for a request naming an objective the server either
/// does not know or cannot serve on the requested tier (incremental
/// updates and the johnson variant are shortest-only).
pub const CODE_OBJECTIVE_UNSUPPORTED: &str = "objective_unsupported";

/// Wire error code for a connection refused at admission because the
/// server is at its concurrent-connection cap.  Sent as the connection's
/// only line, then the socket closes; clients should back off and retry.
pub const CODE_SHED: &str = "shed";

/// Wire error code for a request whose deadline (wire `"deadline_ms"` or
/// the server default) expired before its reply could be delivered — while
/// queued, or between solve phases.  The solve was abandoned (or its
/// result cached but not encoded); retrying is safe and often hits the
/// cache.
pub const CODE_DEADLINE_EXCEEDED: &str = "deadline_exceeded";

/// Wire error code sent as the last line of a connection the server is
/// closing because it sat idle past the configured read timeout.  The
/// client should reconnect; its admission slot has been returned.
pub const CODE_IDLE_TIMEOUT: &str = "idle_timeout";

/// The wire default objective: requests that omit the `"objective"` key
/// (every pre-semiring client) mean shortest path.
pub const DEFAULT_OBJECTIVE: &str = "shortest";

/// A solve request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-chosen id echoed in the response.
    pub id: u64,
    /// The graph to solve.
    pub graph: DistMatrix,
    /// Model variant ("staged" unless overridden).
    pub variant: String,
    /// Skip the result cache when true.
    pub no_cache: bool,
    /// Also compute the successor matrix (wire key `"paths"`); the
    /// response then carries `succ` for path reconstruction.
    pub want_paths: bool,
    /// Serving objective — the closed semiring the closure is taken over
    /// (`"shortest"`, `"bottleneck"`, `"minimax"`, `"reachability"`).
    /// Decoded as a raw string so the server can reject unknown values
    /// with a typed error ([`CODE_OBJECTIVE_UNSUPPORTED`]); absent on the
    /// wire means [`DEFAULT_OBJECTIVE`].
    pub objective: String,
    /// Echo the request's span tree in the response (wire key `"trace"`).
    /// Absent on the wire means false, so untraced request lines are
    /// byte-identical to the pre-observability format.
    pub trace: bool,
}

/// An incremental `"update"` request: an edge-delta batch against a cached
/// base closure, addressed by the base graph's fingerprint
/// ([`crate::coordinator::cache::graph_fingerprint`]).  The graph itself
/// never travels — that is the point of the dynamic tier.
#[derive(Clone, Debug)]
pub struct UpdateRequest {
    /// Client-chosen id echoed in the response.
    pub id: u64,
    /// Variant whose cached closure this chains from.
    pub variant: String,
    /// Vertex count of the base graph (part of the cache key).
    pub n: usize,
    /// Fingerprint of the base graph.  Travels as a 16-hex-digit string:
    /// JSON numbers are f64 and cannot carry 64 bits losslessly.
    pub base_fingerprint: u64,
    /// Edge-delta batch; the last write to an edge wins.
    pub updates: Vec<EdgeUpdate>,
    /// Also return the successor matrix (wire key `"paths"`).
    pub want_paths: bool,
    /// Serving objective.  The dynamic tier only chains shortest-path
    /// closures, so anything but [`DEFAULT_OBJECTIVE`] is rejected with
    /// [`CODE_OBJECTIVE_UNSUPPORTED`] — the field exists so that the
    /// rejection is *typed* rather than a silent wrong answer.
    pub objective: String,
}

/// Where a response was computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// PJRT artifact execution (bucket size attached).
    Device,
    /// CPU fallback (below the routing threshold).
    Cpu,
    /// Served from the result cache.
    Cache,
    /// Super-blocked schedule over device buckets (n larger than every
    /// artifact bucket; the attached bucket is the super-tile size).
    SuperBlock,
    /// Incremental update applied to a cached closure (the dynamic-graph
    /// tier; re-baselining full solves report their own tier instead).
    Incremental,
}

impl Source {
    pub fn name(&self) -> &'static str {
        match self {
            Source::Device => "device",
            Source::Cpu => "cpu",
            Source::Cache => "cache",
            Source::SuperBlock => "superblock",
            Source::Incremental => "incremental",
        }
    }
}

/// A solve response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub dist: DistMatrix,
    /// Row-major successor matrix ([`NO_PATH`] = unreachable), present iff
    /// the request set `want_paths`; travels as `succ` rows with `null`
    /// for "no successor".
    pub succ: Option<Vec<usize>>,
    pub source: Source,
    /// Padding bucket used (device responses), super-tile size (superblock
    /// responses), or n otherwise.
    pub bucket: usize,
    /// Wall-clock service time, seconds.
    pub seconds: f64,
}

/// Per-request *serving* options that ride a solve/update line but never
/// reach the solver: the admission deadline and the response-encoding
/// negotiation.  Kept out of [`Request`]/[`UpdateRequest`] so the
/// solver-facing structs (and every construction site across tests,
/// benches, and tools) are untouched by front-end concerns.  Decoded
/// leniently from the raw line — absent keys mean defaults — so every
/// legacy line behaves exactly as before.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireOptions {
    /// Wire `"deadline_ms"`: per-request deadline in milliseconds,
    /// counted from arrival.  `None` (key absent) means the server's
    /// configured default; an explicit `0` means *no* deadline.
    pub deadline_ms: Option<u64>,
    /// Wire `"binary": true`: reply with the length-prefixed binary frame
    /// ([`super::frame`]) instead of a line-JSON result.
    pub binary: bool,
}

/// Decode the serving options off an already-parsed request line.  Both
/// keys are optional and ignored by older servers (the decoders skip
/// unknown keys), so negotiation degrades gracefully in both directions.
pub fn decode_wire_options(v: &Json) -> WireOptions {
    WireOptions {
        deadline_ms: v.get("deadline_ms").as_f64().map(|ms| ms.max(0.0) as u64),
        binary: v.get("binary").as_bool().unwrap_or(false),
    }
}

fn push_wire_options(fields: &mut Vec<(&str, Json)>, opts: &WireOptions) {
    if let Some(ms) = opts.deadline_ms {
        fields.push(("deadline_ms", Json::num(ms as f64)));
    }
    if opts.binary {
        fields.push(("binary", Json::Bool(true)));
    }
}

// ------------------------------------------------------------------ wire --

/// Encode a request as one JSON line.  Equivalent to
/// [`encode_request_opts`] with default [`WireOptions`] — both keys omit
/// their defaults, so the line is byte-identical either way.
pub fn encode_request(req: &Request) -> String {
    encode_request_opts(req, &WireOptions::default())
}

/// Encode a request as one JSON line, with serving options attached.
pub fn encode_request_opts(req: &Request, opts: &WireOptions) -> String {
    let n = req.graph.n();
    let mut edges = Vec::new();
    for i in 0..n {
        for j in 0..n {
            let w = req.graph.get(i, j);
            if i != j && w.is_finite() {
                edges.push(Json::Arr(vec![
                    Json::num(i as f64),
                    Json::num(j as f64),
                    Json::num(w as f64),
                ]));
            }
        }
    }
    let mut fields = vec![
        ("type", Json::str("solve")),
        ("id", Json::num(req.id as f64)),
        ("n", Json::num(n as f64)),
        ("variant", Json::str(req.variant.clone())),
        ("no_cache", Json::Bool(req.no_cache)),
        ("paths", Json::Bool(req.want_paths)),
        ("edges", Json::Arr(edges)),
    ];
    // the key only travels for non-default objectives, so shortest-path
    // request lines are byte-identical to the pre-semiring wire format
    if req.objective != DEFAULT_OBJECTIVE {
        fields.push(("objective", Json::str(req.objective.clone())));
    }
    // same omit-the-default rule for the trace echo flag
    if req.trace {
        fields.push(("trace", Json::Bool(true)));
    }
    push_wire_options(&mut fields, opts);
    Json::obj(fields).to_string()
}

/// Decode a request line.
pub fn decode_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).context("request is not valid JSON")?;
    let ty = v.get("type").as_str().unwrap_or("solve");
    if ty != "solve" {
        bail!("unsupported request type {ty:?}");
    }
    let id = v.get("id").as_f64().unwrap_or(0.0) as u64;
    let n = v.get("n").as_usize().context("request missing 'n'")?;
    if n == 0 {
        bail!("empty graph");
    }
    if n > MAX_N {
        bail!("n={n} exceeds server limit {MAX_N}");
    }
    let variant = v
        .get("variant")
        .as_str()
        .unwrap_or("staged")
        .to_string();
    let mut graph = DistMatrix::unconnected(n);
    let edges = v.get("edges").as_arr().unwrap_or(&[]);
    for (idx, e) in edges.iter().enumerate() {
        let e = e.as_arr().with_context(|| format!("edge[{idx}] not an array"))?;
        if e.len() != 3 {
            bail!("edge[{idx}] must be [u, v, w]");
        }
        let u = e[0].as_usize().with_context(|| format!("edge[{idx}] bad u"))?;
        let vtx = e[1].as_usize().with_context(|| format!("edge[{idx}] bad v"))?;
        let w = e[2].as_f64().with_context(|| format!("edge[{idx}] bad w"))? as f32;
        if u >= n || vtx >= n {
            bail!("edge[{idx}] endpoint out of range");
        }
        if w.is_nan() {
            bail!("edge[{idx}] weight is NaN");
        }
        if u != vtx {
            graph.set(u, vtx, w);
        }
    }
    Ok(Request {
        id,
        graph,
        variant,
        no_cache: v.get("no_cache").as_bool().unwrap_or(false),
        want_paths: v.get("paths").as_bool().unwrap_or(false),
        objective: v
            .get("objective")
            .as_str()
            .unwrap_or(DEFAULT_OBJECTIVE)
            .to_string(),
        trace: v.get("trace").as_bool().unwrap_or(false),
    })
}

/// Encode an update request as one JSON line.  Edge deltas travel as
/// `[src, dst, w]` triples with `null` for "+inf" (delete the edge) — the
/// same unreachable convention the distance rows use.  Weights must be
/// pre-validated ([`crate::apsp::incremental::validate_batch`];
/// `Client::update` does): NaN and `-inf` have no wire rendering and
/// would otherwise travel as `null`, silently becoming deletions.
/// Equivalent to [`encode_update_request_opts`] with default options.
pub fn encode_update_request(req: &UpdateRequest) -> String {
    encode_update_request_opts(req, &WireOptions::default())
}

/// Encode an update request with serving options attached.
pub fn encode_update_request_opts(req: &UpdateRequest, opts: &WireOptions) -> String {
    let updates = req
        .updates
        .iter()
        .map(|u| {
            Json::Arr(vec![
                Json::num(u.src as f64),
                Json::num(u.dst as f64),
                if u.weight.is_finite() {
                    Json::num(u.weight as f64)
                } else {
                    Json::Null
                },
            ])
        })
        .collect();
    let mut fields = vec![
        ("type", Json::str("update")),
        ("id", Json::num(req.id as f64)),
        ("n", Json::num(req.n as f64)),
        ("variant", Json::str(req.variant.clone())),
        ("base", Json::str(format!("{:016x}", req.base_fingerprint))),
        ("paths", Json::Bool(req.want_paths)),
        ("updates", Json::Arr(updates)),
    ];
    if req.objective != DEFAULT_OBJECTIVE {
        fields.push(("objective", Json::str(req.objective.clone())));
    }
    push_wire_options(&mut fields, opts);
    Json::obj(fields).to_string()
}

/// Decode an update request line.  Unlike solve's edge list (where
/// self-loops are silently dropped — a generator convenience), a self-loop
/// *delta* is rejected: it can only be a client bug.
pub fn decode_update_request(line: &str) -> Result<UpdateRequest> {
    let v = Json::parse(line).context("request is not valid JSON")?;
    if v.get("type").as_str() != Some("update") {
        bail!("not an update request");
    }
    let id = v.get("id").as_f64().unwrap_or(0.0) as u64;
    let n = v.get("n").as_usize().context("update missing 'n'")?;
    if n == 0 {
        bail!("empty graph");
    }
    if n > MAX_N {
        bail!("n={n} exceeds server limit {MAX_N}");
    }
    let base = v
        .get("base")
        .as_str()
        .context("update missing 'base' fingerprint")?;
    let base_fingerprint = u64::from_str_radix(base.trim_start_matches("0x"), 16)
        .ok()
        .with_context(|| format!("bad base fingerprint {base:?} (expected hex)"))?;
    let variant = v.get("variant").as_str().unwrap_or("staged").to_string();
    let arr = v.get("updates").as_arr().context("update missing 'updates'")?;
    let mut updates = Vec::with_capacity(arr.len());
    for (idx, e) in arr.iter().enumerate() {
        let e = e
            .as_arr()
            .with_context(|| format!("updates[{idx}] not an array"))?;
        if e.len() != 3 {
            bail!("updates[{idx}] must be [src, dst, w]");
        }
        let src = e[0]
            .as_usize()
            .with_context(|| format!("updates[{idx}] bad src"))?;
        let dst = e[1]
            .as_usize()
            .with_context(|| format!("updates[{idx}] bad dst"))?;
        let weight = match &e[2] {
            Json::Null => INF,
            other => other
                .as_f64()
                .with_context(|| format!("updates[{idx}] bad weight"))? as f32,
        };
        if src >= n || dst >= n {
            bail!("updates[{idx}] endpoint out of range");
        }
        if src == dst {
            bail!("updates[{idx}] is a self-loop (the diagonal is pinned to 0)");
        }
        if weight.is_nan() {
            bail!("updates[{idx}] weight is NaN");
        }
        updates.push(EdgeUpdate { src, dst, weight });
    }
    Ok(UpdateRequest {
        id,
        variant,
        n,
        base_fingerprint,
        updates,
        want_paths: v.get("paths").as_bool().unwrap_or(false),
        objective: v
            .get("objective")
            .as_str()
            .unwrap_or(DEFAULT_OBJECTIVE)
            .to_string(),
    })
}

/// Encode a response as one JSON line.
///
/// The distance matrix is rendered with a hand-rolled writer: values are
/// f32, and formatting them as f32 (shortest round-trip) instead of going
/// through `Json::Num`'s f64 path halves the payload (e.g. `1.6` instead
/// of `1.5999999940395355`) and with it the client's parse time — measured
/// 2.3× end-to-end on the n=128 response (EXPERIMENTS.md §Perf L3).
/// Parsing the decimal back to f64 and casting to f32 is exact.
///
/// This is the buffering wrapper over [`write_response`]: it renders the
/// whole line into one `String` (trace splicing and in-process callers
/// need that).  The server's hot path streams instead — see
/// [`write_response`] — so a multi-MB matrix line never has to exist in
/// memory at once per connection.
pub fn encode_response(resp: &Response) -> String {
    let n = resp.dist.n();
    let mut out = Vec::with_capacity(16 * n * n + 128);
    write_response(&mut out, resp).expect("writing a response to a Vec cannot fail");
    String::from_utf8(out).expect("the response writer emits ASCII")
}

/// Stream a response as one JSON line (no trailing newline) into any
/// [`std::io::Write`].
///
/// Byte-identical to [`encode_response`] by construction — the `String`
/// encoder *is* this writer over a `Vec<u8>`.  Writing row by row means a
/// server streaming to a buffered socket holds O(n) formatting state per
/// connection instead of the O(n²) fully-rendered line (an n=1024
/// dist+succ response is tens of MB of JSON).
pub fn write_response<W: std::io::Write>(out: &mut W, resp: &Response) -> std::io::Result<()> {
    let n = resp.dist.n();
    write!(out, "{{\"bucket\":{},\"dist\":[", resp.bucket)?;
    for i in 0..n {
        if i > 0 {
            out.write_all(b",")?;
        }
        out.write_all(b"[")?;
        for (j, &w) in resp.dist.row(i).iter().enumerate() {
            if j > 0 {
                out.write_all(b",")?;
            }
            if w.is_finite() {
                write!(out, "{w}")?;
            } else {
                out.write_all(b"null")?;
            }
        }
        out.write_all(b"]")?;
    }
    write!(
        out,
        "],\"id\":{},\"n\":{n},\"seconds\":{},\"source\":\"{}\"",
        resp.id,
        if resp.seconds.is_finite() { resp.seconds } else { 0.0 },
        resp.source.name(),
    )?;
    // successor rows ride the same fast writer; NO_PATH travels as null
    if let Some(succ) = &resp.succ {
        debug_assert_eq!(succ.len(), n * n);
        out.write_all(b",\"succ\":[")?;
        for i in 0..n {
            if i > 0 {
                out.write_all(b",")?;
            }
            out.write_all(b"[")?;
            for (j, &s) in succ[i * n..(i + 1) * n].iter().enumerate() {
                if j > 0 {
                    out.write_all(b",")?;
                }
                if s == NO_PATH {
                    out.write_all(b"null")?;
                } else {
                    write!(out, "{s}")?;
                }
            }
            out.write_all(b"]")?;
        }
        out.write_all(b"]")?;
    }
    out.write_all(b",\"type\":\"result\"}")
}

/// Decode a response line.
pub fn decode_response(line: &str) -> Result<Response> {
    let v = Json::parse(line).context("response is not valid JSON")?;
    match v.get("type").as_str() {
        Some("result") => {}
        Some("error") => bail!(
            "server error: {}",
            v.get("message").as_str().unwrap_or("unknown")
        ),
        other => bail!("unexpected response type {other:?}"),
    }
    let id = v.get("id").as_f64().unwrap_or(0.0) as u64;
    let n = v.get("n").as_usize().context("response missing 'n'")?;
    let rows = v.get("dist").as_arr().context("response missing 'dist'")?;
    if rows.len() != n {
        bail!("dist has {} rows, expected {n}", rows.len());
    }
    let mut dist = DistMatrix::unconnected(n);
    for (i, row) in rows.iter().enumerate() {
        let row = row.as_arr().context("dist row not an array")?;
        if row.len() != n {
            bail!("dist row {i} has {} cols, expected {n}", row.len());
        }
        for (j, cell) in row.iter().enumerate() {
            let w = match cell {
                Json::Null => INF,
                other => other.as_f64().context("bad dist cell")? as f32,
            };
            dist.set(i, j, w);
        }
    }
    let source = match v.get("source").as_str() {
        Some("device") => Source::Device,
        Some("cpu") => Source::Cpu,
        Some("cache") => Source::Cache,
        Some("superblock") => Source::SuperBlock,
        Some("incremental") => Source::Incremental,
        other => bail!("bad source {other:?}"),
    };
    let succ = match v.get("succ").as_arr() {
        None => None,
        Some(rows) => {
            if rows.len() != n {
                bail!("succ has {} rows, expected {n}", rows.len());
            }
            let mut succ = vec![NO_PATH; n * n];
            for (i, row) in rows.iter().enumerate() {
                let row = row.as_arr().context("succ row not an array")?;
                if row.len() != n {
                    bail!("succ row {i} has {} cols, expected {n}", row.len());
                }
                for (j, cell) in row.iter().enumerate() {
                    match cell {
                        Json::Null => {}
                        other => {
                            let s = other.as_usize().context("bad succ cell")?;
                            if s >= n {
                                bail!("succ[{i}][{j}] = {s} out of range for n={n}");
                            }
                            succ[i * n + j] = s;
                        }
                    }
                }
            }
            Some(succ)
        }
    };
    Ok(Response {
        id,
        dist,
        succ,
        source,
        bucket: v.get("bucket").as_usize().unwrap_or(n),
        seconds: v.get("seconds").as_f64().unwrap_or(0.0),
    })
}

/// Splice a trace object into an already-encoded result line.
///
/// The response writer is hand-rolled for payload speed, so the trace
/// echo (requests that set `"trace": true`) is attached by rewriting the
/// fixed tail rather than re-encoding the matrix.  The sorted-key
/// invariant holds: `trace` lands between `succ` and `type`.  Lines that
/// are not result lines (errors) pass through untouched.
pub fn attach_trace(line: &str, trace: &Json) -> String {
    const TAIL: &str = ",\"type\":\"result\"}";
    match line.strip_suffix(TAIL) {
        Some(head) => format!("{head},\"trace\":{trace}{TAIL}"),
        None => line.to_string(),
    }
}

/// Encode a server-side error for a request id.
pub fn encode_error(id: u64, message: &str) -> String {
    Json::obj(vec![
        ("type", Json::str("error")),
        ("id", Json::num(id as f64)),
        ("message", Json::str(message)),
    ])
    .to_string()
}

/// Encode a *typed* error: same shape plus a machine-readable `code` the
/// client can dispatch on (see [`CODE_UPDATE_BASE_MISSING`]).
pub fn encode_error_coded(id: u64, code: &str, message: &str) -> String {
    Json::obj(vec![
        ("type", Json::str("error")),
        ("id", Json::num(id as f64)),
        ("code", Json::str(code)),
        ("message", Json::str(message)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn sample_request() -> Request {
        Request {
            id: 42,
            graph: generators::erdos_renyi(24, 0.3, 5),
            variant: "staged".into(),
            no_cache: false,
            want_paths: false,
            objective: DEFAULT_OBJECTIVE.into(),
            trace: false,
        }
    }

    #[test]
    fn request_roundtrip() {
        let req = sample_request();
        let back = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.variant, "staged");
        assert_eq!(back.graph, req.graph);
        assert!(!back.want_paths);
    }

    #[test]
    fn want_paths_flag_roundtrips() {
        let mut req = sample_request();
        req.want_paths = true;
        let back = decode_request(&encode_request(&req)).unwrap();
        assert!(back.want_paths);
        // absent key defaults to false (older clients)
        let legacy = decode_request(r#"{"type":"solve","n":3,"edges":[]}"#).unwrap();
        assert!(!legacy.want_paths);
    }

    #[test]
    fn objective_roundtrips_and_defaults() {
        // non-default objective travels and comes back
        let mut req = sample_request();
        req.objective = "bottleneck".into();
        let line = encode_request(&req);
        assert!(line.contains("\"objective\":\"bottleneck\""), "{line}");
        assert_eq!(decode_request(&line).unwrap().objective, "bottleneck");
        // default objective is omitted: shortest-path lines are
        // byte-identical to the pre-semiring wire format
        let line = encode_request(&sample_request());
        assert!(!line.contains("objective"), "{line}");
        // absent key decodes as shortest (older clients)
        let legacy = decode_request(r#"{"type":"solve","n":3,"edges":[]}"#).unwrap();
        assert_eq!(legacy.objective, DEFAULT_OBJECTIVE);
        // unknown objectives survive decoding — the server's objective
        // gate rejects them with a typed error, not the parser
        let odd =
            decode_request(r#"{"type":"solve","n":3,"edges":[],"objective":"widest"}"#).unwrap();
        assert_eq!(odd.objective, "widest");
    }

    #[test]
    fn trace_flag_roundtrips_and_defaults() {
        // the flag travels only when set: untraced lines stay byte-identical
        // to the pre-observability wire format
        let line = encode_request(&sample_request());
        assert!(!line.contains("trace"), "{line}");
        let mut req = sample_request();
        req.trace = true;
        let line = encode_request(&req);
        assert!(line.contains("\"trace\":true"), "{line}");
        assert!(decode_request(&line).unwrap().trace);
        // absent key decodes as false (older clients)
        let legacy = decode_request(r#"{"type":"solve","n":3,"edges":[]}"#).unwrap();
        assert!(!legacy.trace);
    }

    #[test]
    fn attach_trace_splices_before_the_type_key() {
        let resp = Response {
            id: 7,
            dist: DistMatrix::unconnected(2),
            succ: None,
            source: Source::Cpu,
            bucket: 2,
            seconds: 0.5,
        };
        let trace = Json::obj(vec![("name", Json::str("request"))]);
        let line = attach_trace(&encode_response(&resp), &trace);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("type").as_str(), Some("result"));
        assert_eq!(v.get("trace").get("name").as_str(), Some("request"));
        // the spliced line still decodes as a normal response
        assert_eq!(decode_response(&line).unwrap().id, 7);
        // sorted-key invariant: re-serializing moves nothing
        assert_eq!(v.to_string(), line);
        // error lines pass through untouched
        let err = encode_error(3, "boom");
        assert_eq!(attach_trace(&err, &trace), err);
    }

    #[test]
    fn update_objective_roundtrips_and_defaults() {
        let mut req = UpdateRequest {
            id: 1,
            variant: "staged".into(),
            n: 4,
            base_fingerprint: 0xff,
            updates: vec![EdgeUpdate { src: 0, dst: 1, weight: 2.0 }],
            want_paths: false,
            objective: DEFAULT_OBJECTIVE.into(),
        };
        let line = encode_update_request(&req);
        assert!(!line.contains("objective"), "{line}");
        assert_eq!(decode_update_request(&line).unwrap().objective, DEFAULT_OBJECTIVE);
        req.objective = "reachability".into();
        let line = encode_update_request(&req);
        assert_eq!(decode_update_request(&line).unwrap().objective, "reachability");
    }

    #[test]
    fn superblock_source_roundtrips() {
        let resp = Response {
            id: 11,
            dist: DistMatrix::unconnected(2),
            succ: None,
            source: Source::SuperBlock,
            bucket: 256,
            seconds: 1.25,
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(back.source, Source::SuperBlock);
        assert_eq!(back.bucket, 256);
        assert_eq!(Source::SuperBlock.name(), "superblock");
    }

    #[test]
    fn response_roundtrip_with_inf() {
        let mut dist = DistMatrix::unconnected(3);
        dist.set(0, 1, 1.5);
        let resp = Response {
            id: 7,
            dist,
            succ: None,
            source: Source::Device,
            bucket: 64,
            seconds: 0.01,
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.bucket, 64);
        assert_eq!(back.source, Source::Device);
        assert_eq!(back.dist, resp.dist);
        assert!(back.succ.is_none());
        assert!(back.dist.get(1, 2).is_infinite());
    }

    #[test]
    fn successors_roundtrip_over_the_wire() {
        // a real solve so the succ matrix is meaningful end to end
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 2, 2.0);
        g.set(2, 1, 3.0);
        let r = crate::apsp::paths::solve(&g);
        let resp = Response {
            id: 9,
            dist: r.dist.clone(),
            succ: Some(r.succ().to_vec()),
            source: Source::Cpu,
            bucket: 3,
            seconds: 0.0,
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        let back_succ = back.succ.expect("succ present");
        assert_eq!(back_succ, r.succ());
        assert_eq!(back.dist, r.dist);
        // NO_PATH travelled as null and came back as NO_PATH
        assert_eq!(back_succ[3], NO_PATH); // (1, 0): unreachable
        assert_eq!(back_succ[2], 2); // (0, 2) → first hop 2
        assert_eq!(back_succ[1], 2); // (0, 1) → via 2
    }

    #[test]
    fn malformed_succ_rejected() {
        // row count mismatch
        let line = r#"{"bucket":2,"dist":[[0,1],[1,0]],"id":1,"n":2,"seconds":0,"source":"cpu","succ":[[null,1]],"type":"result"}"#;
        assert!(decode_response(line).unwrap_err().to_string().contains("succ"));
        // out-of-range successor id
        let line = r#"{"bucket":2,"dist":[[0,1],[1,0]],"id":1,"n":2,"seconds":0,"source":"cpu","succ":[[null,7],[null,null]],"type":"result"}"#;
        assert!(decode_response(line).unwrap_err().to_string().contains("out of range"));
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(decode_request("not json").is_err());
        assert!(decode_request(r#"{"type":"solve"}"#).is_err()); // no n
        assert!(decode_request(r#"{"type":"solve","n":0}"#).is_err());
        assert!(decode_request(r#"{"type":"solve","n":9999999}"#).is_err());
        assert!(
            decode_request(r#"{"type":"solve","n":4,"edges":[[0,9,1.0]]}"#).is_err(),
            "edge out of range"
        );
        assert!(decode_request(r#"{"type":"wat","n":4}"#).is_err());
    }

    #[test]
    fn error_responses_surface_message() {
        let line = encode_error(3, "boom");
        let err = decode_response(&line).unwrap_err();
        assert!(err.to_string().contains("boom"));
    }

    #[test]
    fn update_request_roundtrip() {
        let req = UpdateRequest {
            id: 13,
            variant: "staged".into(),
            n: 24,
            base_fingerprint: 0x4820_083e_b15f_2d0d,
            updates: vec![
                EdgeUpdate { src: 0, dst: 1, weight: 2.5 },
                EdgeUpdate { src: 3, dst: 4, weight: INF }, // deletion → null
            ],
            want_paths: true,
            objective: DEFAULT_OBJECTIVE.into(),
        };
        let line = encode_update_request(&req);
        // the fingerprint travels as a hex string — a JSON f64 would
        // silently round 64-bit fingerprints
        assert!(line.contains("\"4820083eb15f2d0d\""), "{line}");
        let back = decode_update_request(&line).unwrap();
        assert_eq!(back.id, 13);
        assert_eq!(back.n, 24);
        assert_eq!(back.base_fingerprint, req.base_fingerprint);
        assert_eq!(back.updates, req.updates);
        assert!(back.want_paths);
        assert!(back.updates[1].weight.is_infinite());
    }

    #[test]
    fn update_request_rejects_malformed() {
        let ok = r#"{"type":"update","n":4,"base":"00000000000000ff","updates":[[0,1,2.0]]}"#;
        assert_eq!(decode_update_request(ok).unwrap().base_fingerprint, 0xff);
        for (line, needle) in [
            (r#"{"type":"solve","n":4}"#, "not an update"),
            (r#"{"type":"update","n":4,"updates":[]}"#, "base"),
            (r#"{"type":"update","base":"ff","updates":[]}"#, "'n'"),
            (r#"{"type":"update","n":0,"base":"ff","updates":[]}"#, "empty"),
            (r#"{"type":"update","n":4,"base":"zz","updates":[]}"#, "fingerprint"),
            (r#"{"type":"update","n":4,"base":"ff"}"#, "updates"),
            (
                r#"{"type":"update","n":4,"base":"ff","updates":[[0,9,1.0]]}"#,
                "out of range",
            ),
            (
                r#"{"type":"update","n":4,"base":"ff","updates":[[2,2,1.0]]}"#,
                "self-loop",
            ),
            (
                r#"{"type":"update","n":4,"base":"ff","updates":[[0,1]]}"#,
                "must be",
            ),
        ] {
            let err = decode_update_request(line).unwrap_err().to_string();
            assert!(err.contains(needle), "{line}: {err:?} missing {needle:?}");
        }
    }

    #[test]
    fn coded_errors_carry_their_code() {
        let line = encode_error_coded(7, CODE_UPDATE_BASE_MISSING, "base not cached");
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.get("type").as_str(), Some("error"));
        assert_eq!(v.get("code").as_str(), Some(CODE_UPDATE_BASE_MISSING));
        assert_eq!(v.get("id").as_f64(), Some(7.0));
        // still a normal error to a client that ignores codes
        assert!(decode_response(&line).unwrap_err().to_string().contains("base not cached"));
    }

    #[test]
    fn incremental_source_roundtrips() {
        let resp = Response {
            id: 5,
            dist: DistMatrix::unconnected(2),
            succ: None,
            source: Source::Incremental,
            bucket: 2,
            seconds: 0.001,
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(back.source, Source::Incremental);
        assert_eq!(Source::Incremental.name(), "incremental");
    }

    #[test]
    fn self_loops_dropped() {
        let req =
            decode_request(r#"{"type":"solve","n":3,"edges":[[1,1,5.0],[0,1,2.0]]}"#).unwrap();
        assert_eq!(req.graph.get(1, 1), 0.0);
        assert_eq!(req.graph.get(0, 1), 2.0);
    }

    #[test]
    fn wire_options_default_keeps_lines_byte_identical() {
        // the opts-aware encoders with default options are the legacy
        // encoders, byte for byte — every existing client/test line is
        // unchanged by the front-end additions
        let req = sample_request();
        assert_eq!(encode_request(&req), encode_request_opts(&req, &WireOptions::default()));
        let upd = UpdateRequest {
            id: 1,
            variant: "staged".into(),
            n: 4,
            base_fingerprint: 0xff,
            updates: vec![EdgeUpdate { src: 0, dst: 1, weight: 2.0 }],
            want_paths: false,
            objective: DEFAULT_OBJECTIVE.into(),
        };
        assert_eq!(
            encode_update_request(&upd),
            encode_update_request_opts(&upd, &WireOptions::default())
        );
        assert!(!encode_request(&req).contains("deadline_ms"));
        assert!(!encode_request(&req).contains("binary"));
    }

    #[test]
    fn wire_options_roundtrip_and_stay_invisible_to_the_decoders() {
        let req = sample_request();
        let opts = WireOptions { deadline_ms: Some(250), binary: true };
        let line = encode_request_opts(&req, &opts);
        assert!(line.contains("\"deadline_ms\":250"), "{line}");
        assert!(line.contains("\"binary\":true"), "{line}");
        // the request decoder skips the serving keys (an older server
        // simply ignores them) …
        let back = decode_request(&line).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.graph, req.graph);
        // … while the options decoder picks them off the same line
        let v = Json::parse(&line).unwrap();
        assert_eq!(decode_wire_options(&v), opts);
        // absent keys mean defaults
        let legacy = Json::parse(r#"{"type":"solve","n":3,"edges":[]}"#).unwrap();
        assert_eq!(decode_wire_options(&legacy), WireOptions::default());
        // explicit zero is distinct from absent: "no deadline, ever"
        let zero = Json::parse(r#"{"type":"solve","n":3,"edges":[],"deadline_ms":0}"#).unwrap();
        assert_eq!(decode_wire_options(&zero).deadline_ms, Some(0));
    }

    #[test]
    fn streaming_writer_matches_the_string_encoder() {
        // write_response IS encode_response (one delegates to the other);
        // this pins the delegation so a future fork of the two paths
        // cannot silently diverge
        let mut g = DistMatrix::unconnected(5);
        g.set(0, 2, 2.5);
        g.set(2, 1, 0.125);
        let r = crate::apsp::paths::solve(&g);
        let resp = Response {
            id: 77,
            dist: r.dist.clone(),
            succ: Some(r.succ().to_vec()),
            source: Source::SuperBlock,
            bucket: 64,
            seconds: 0.25,
        };
        let mut streamed = Vec::new();
        write_response(&mut streamed, &resp).unwrap();
        assert_eq!(String::from_utf8(streamed).unwrap(), encode_response(&resp));
    }
}

//! Request/response types and their wire encoding.
//!
//! The server speaks line-delimited JSON over TCP.  Graphs travel as edge
//! lists (sparse graphs dominate real workloads; a dense n×n float matrix
//! would be ~4n² bytes of JSON); distance matrices return as row arrays
//! with `null` for "unreachable".

use anyhow::{bail, Context, Result};

use crate::apsp::paths::NO_PATH;
use crate::graph::DistMatrix;
use crate::util::json::Json;
use crate::INF;

/// A solve request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-chosen id echoed in the response.
    pub id: u64,
    /// The graph to solve.
    pub graph: DistMatrix,
    /// Model variant ("staged" unless overridden).
    pub variant: String,
    /// Skip the result cache when true.
    pub no_cache: bool,
    /// Also compute the successor matrix (wire key `"paths"`); the
    /// response then carries `succ` for path reconstruction.
    pub want_paths: bool,
}

/// Where a response was computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// PJRT artifact execution (bucket size attached).
    Device,
    /// CPU fallback (below the routing threshold).
    Cpu,
    /// Served from the result cache.
    Cache,
    /// Super-blocked schedule over device buckets (n larger than every
    /// artifact bucket; the attached bucket is the super-tile size).
    SuperBlock,
}

impl Source {
    pub fn name(&self) -> &'static str {
        match self {
            Source::Device => "device",
            Source::Cpu => "cpu",
            Source::Cache => "cache",
            Source::SuperBlock => "superblock",
        }
    }
}

/// A solve response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub dist: DistMatrix,
    /// Row-major successor matrix ([`NO_PATH`] = unreachable), present iff
    /// the request set `want_paths`; travels as `succ` rows with `null`
    /// for "no successor".
    pub succ: Option<Vec<usize>>,
    pub source: Source,
    /// Padding bucket used (device responses), super-tile size (superblock
    /// responses), or n otherwise.
    pub bucket: usize,
    /// Wall-clock service time, seconds.
    pub seconds: f64,
}

// ------------------------------------------------------------------ wire --

/// Encode a request as one JSON line.
pub fn encode_request(req: &Request) -> String {
    let n = req.graph.n();
    let mut edges = Vec::new();
    for i in 0..n {
        for j in 0..n {
            let w = req.graph.get(i, j);
            if i != j && w.is_finite() {
                edges.push(Json::Arr(vec![
                    Json::num(i as f64),
                    Json::num(j as f64),
                    Json::num(w as f64),
                ]));
            }
        }
    }
    Json::obj(vec![
        ("type", Json::str("solve")),
        ("id", Json::num(req.id as f64)),
        ("n", Json::num(n as f64)),
        ("variant", Json::str(req.variant.clone())),
        ("no_cache", Json::Bool(req.no_cache)),
        ("paths", Json::Bool(req.want_paths)),
        ("edges", Json::Arr(edges)),
    ])
    .to_string()
}

/// Decode a request line.
pub fn decode_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).context("request is not valid JSON")?;
    let ty = v.get("type").as_str().unwrap_or("solve");
    if ty != "solve" {
        bail!("unsupported request type {ty:?}");
    }
    let id = v.get("id").as_f64().unwrap_or(0.0) as u64;
    let n = v.get("n").as_usize().context("request missing 'n'")?;
    if n == 0 {
        bail!("empty graph");
    }
    const MAX_N: usize = 4096;
    if n > MAX_N {
        bail!("n={n} exceeds server limit {MAX_N}");
    }
    let variant = v
        .get("variant")
        .as_str()
        .unwrap_or("staged")
        .to_string();
    let mut graph = DistMatrix::unconnected(n);
    let edges = v.get("edges").as_arr().unwrap_or(&[]);
    for (idx, e) in edges.iter().enumerate() {
        let e = e.as_arr().with_context(|| format!("edge[{idx}] not an array"))?;
        if e.len() != 3 {
            bail!("edge[{idx}] must be [u, v, w]");
        }
        let u = e[0].as_usize().with_context(|| format!("edge[{idx}] bad u"))?;
        let vtx = e[1].as_usize().with_context(|| format!("edge[{idx}] bad v"))?;
        let w = e[2].as_f64().with_context(|| format!("edge[{idx}] bad w"))? as f32;
        if u >= n || vtx >= n {
            bail!("edge[{idx}] endpoint out of range");
        }
        if w.is_nan() {
            bail!("edge[{idx}] weight is NaN");
        }
        if u != vtx {
            graph.set(u, vtx, w);
        }
    }
    Ok(Request {
        id,
        graph,
        variant,
        no_cache: v.get("no_cache").as_bool().unwrap_or(false),
        want_paths: v.get("paths").as_bool().unwrap_or(false),
    })
}

/// Encode a response as one JSON line.
///
/// The distance matrix is rendered with a hand-rolled writer: values are
/// f32, and formatting them as f32 (shortest round-trip) instead of going
/// through `Json::Num`'s f64 path halves the payload (e.g. `1.6` instead
/// of `1.5999999940395355`) and with it the client's parse time — measured
/// 2.3× end-to-end on the n=128 response (EXPERIMENTS.md §Perf L3).
/// Parsing the decimal back to f64 and casting to f32 is exact.
pub fn encode_response(resp: &Response) -> String {
    use std::fmt::Write as _;
    let n = resp.dist.n();
    // header via the generic writer (cheap), matrix via the fast path
    let mut out = String::with_capacity(16 * n * n + 128);
    let _ = write!(
        out,
        "{{\"bucket\":{},\"dist\":[",
        resp.bucket
    );
    for i in 0..n {
        if i > 0 {
            out.push(',');
        }
        out.push('[');
        for (j, &w) in resp.dist.row(i).iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            if w.is_finite() {
                let _ = write!(out, "{w}");
            } else {
                out.push_str("null");
            }
        }
        out.push(']');
    }
    let _ = write!(
        out,
        "],\"id\":{},\"n\":{n},\"seconds\":{},\"source\":\"{}\"",
        resp.id,
        if resp.seconds.is_finite() { resp.seconds } else { 0.0 },
        resp.source.name(),
    );
    // successor rows ride the same fast writer; NO_PATH travels as null
    if let Some(succ) = &resp.succ {
        debug_assert_eq!(succ.len(), n * n);
        out.push_str(",\"succ\":[");
        for i in 0..n {
            if i > 0 {
                out.push(',');
            }
            out.push('[');
            for (j, &s) in succ[i * n..(i + 1) * n].iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                if s == NO_PATH {
                    out.push_str("null");
                } else {
                    let _ = write!(out, "{s}");
                }
            }
            out.push(']');
        }
        out.push(']');
    }
    out.push_str(",\"type\":\"result\"}");
    out
}

/// Decode a response line.
pub fn decode_response(line: &str) -> Result<Response> {
    let v = Json::parse(line).context("response is not valid JSON")?;
    match v.get("type").as_str() {
        Some("result") => {}
        Some("error") => bail!(
            "server error: {}",
            v.get("message").as_str().unwrap_or("unknown")
        ),
        other => bail!("unexpected response type {other:?}"),
    }
    let id = v.get("id").as_f64().unwrap_or(0.0) as u64;
    let n = v.get("n").as_usize().context("response missing 'n'")?;
    let rows = v.get("dist").as_arr().context("response missing 'dist'")?;
    if rows.len() != n {
        bail!("dist has {} rows, expected {n}", rows.len());
    }
    let mut dist = DistMatrix::unconnected(n);
    for (i, row) in rows.iter().enumerate() {
        let row = row.as_arr().context("dist row not an array")?;
        if row.len() != n {
            bail!("dist row {i} has {} cols, expected {n}", row.len());
        }
        for (j, cell) in row.iter().enumerate() {
            let w = match cell {
                Json::Null => INF,
                other => other.as_f64().context("bad dist cell")? as f32,
            };
            dist.set(i, j, w);
        }
    }
    let source = match v.get("source").as_str() {
        Some("device") => Source::Device,
        Some("cpu") => Source::Cpu,
        Some("cache") => Source::Cache,
        Some("superblock") => Source::SuperBlock,
        other => bail!("bad source {other:?}"),
    };
    let succ = match v.get("succ").as_arr() {
        None => None,
        Some(rows) => {
            if rows.len() != n {
                bail!("succ has {} rows, expected {n}", rows.len());
            }
            let mut succ = vec![NO_PATH; n * n];
            for (i, row) in rows.iter().enumerate() {
                let row = row.as_arr().context("succ row not an array")?;
                if row.len() != n {
                    bail!("succ row {i} has {} cols, expected {n}", row.len());
                }
                for (j, cell) in row.iter().enumerate() {
                    match cell {
                        Json::Null => {}
                        other => {
                            let s = other.as_usize().context("bad succ cell")?;
                            if s >= n {
                                bail!("succ[{i}][{j}] = {s} out of range for n={n}");
                            }
                            succ[i * n + j] = s;
                        }
                    }
                }
            }
            Some(succ)
        }
    };
    Ok(Response {
        id,
        dist,
        succ,
        source,
        bucket: v.get("bucket").as_usize().unwrap_or(n),
        seconds: v.get("seconds").as_f64().unwrap_or(0.0),
    })
}

/// Encode a server-side error for a request id.
pub fn encode_error(id: u64, message: &str) -> String {
    Json::obj(vec![
        ("type", Json::str("error")),
        ("id", Json::num(id as f64)),
        ("message", Json::str(message)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn sample_request() -> Request {
        Request {
            id: 42,
            graph: generators::erdos_renyi(24, 0.3, 5),
            variant: "staged".into(),
            no_cache: false,
            want_paths: false,
        }
    }

    #[test]
    fn request_roundtrip() {
        let req = sample_request();
        let back = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.variant, "staged");
        assert_eq!(back.graph, req.graph);
        assert!(!back.want_paths);
    }

    #[test]
    fn want_paths_flag_roundtrips() {
        let mut req = sample_request();
        req.want_paths = true;
        let back = decode_request(&encode_request(&req)).unwrap();
        assert!(back.want_paths);
        // absent key defaults to false (older clients)
        let legacy = decode_request(r#"{"type":"solve","n":3,"edges":[]}"#).unwrap();
        assert!(!legacy.want_paths);
    }

    #[test]
    fn superblock_source_roundtrips() {
        let resp = Response {
            id: 11,
            dist: DistMatrix::unconnected(2),
            succ: None,
            source: Source::SuperBlock,
            bucket: 256,
            seconds: 1.25,
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(back.source, Source::SuperBlock);
        assert_eq!(back.bucket, 256);
        assert_eq!(Source::SuperBlock.name(), "superblock");
    }

    #[test]
    fn response_roundtrip_with_inf() {
        let mut dist = DistMatrix::unconnected(3);
        dist.set(0, 1, 1.5);
        let resp = Response {
            id: 7,
            dist,
            succ: None,
            source: Source::Device,
            bucket: 64,
            seconds: 0.01,
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.bucket, 64);
        assert_eq!(back.source, Source::Device);
        assert_eq!(back.dist, resp.dist);
        assert!(back.succ.is_none());
        assert!(back.dist.get(1, 2).is_infinite());
    }

    #[test]
    fn successors_roundtrip_over_the_wire() {
        // a real solve so the succ matrix is meaningful end to end
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 2, 2.0);
        g.set(2, 1, 3.0);
        let r = crate::apsp::paths::solve(&g);
        let resp = Response {
            id: 9,
            dist: r.dist.clone(),
            succ: Some(r.succ().to_vec()),
            source: Source::Cpu,
            bucket: 3,
            seconds: 0.0,
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        let back_succ = back.succ.expect("succ present");
        assert_eq!(back_succ, r.succ());
        assert_eq!(back.dist, r.dist);
        // NO_PATH travelled as null and came back as NO_PATH
        assert_eq!(back_succ[3], NO_PATH); // (1, 0): unreachable
        assert_eq!(back_succ[2], 2); // (0, 2) → first hop 2
        assert_eq!(back_succ[1], 2); // (0, 1) → via 2
    }

    #[test]
    fn malformed_succ_rejected() {
        // row count mismatch
        let line = r#"{"bucket":2,"dist":[[0,1],[1,0]],"id":1,"n":2,"seconds":0,"source":"cpu","succ":[[null,1]],"type":"result"}"#;
        assert!(decode_response(line).unwrap_err().to_string().contains("succ"));
        // out-of-range successor id
        let line = r#"{"bucket":2,"dist":[[0,1],[1,0]],"id":1,"n":2,"seconds":0,"source":"cpu","succ":[[null,7],[null,null]],"type":"result"}"#;
        assert!(decode_response(line).unwrap_err().to_string().contains("out of range"));
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(decode_request("not json").is_err());
        assert!(decode_request(r#"{"type":"solve"}"#).is_err()); // no n
        assert!(decode_request(r#"{"type":"solve","n":0}"#).is_err());
        assert!(decode_request(r#"{"type":"solve","n":9999999}"#).is_err());
        assert!(
            decode_request(r#"{"type":"solve","n":4,"edges":[[0,9,1.0]]}"#).is_err(),
            "edge out of range"
        );
        assert!(decode_request(r#"{"type":"wat","n":4}"#).is_err());
    }

    #[test]
    fn error_responses_surface_message() {
        let line = encode_error(3, "boom");
        let err = decode_response(&line).unwrap_err();
        assert!(err.to_string().contains("boom"));
    }

    #[test]
    fn self_loops_dropped() {
        let req =
            decode_request(r#"{"type":"solve","n":3,"edges":[[1,1,5.0],[0,1,2.0]]}"#).unwrap();
        assert_eq!(req.graph.get(1, 1), 0.0);
        assert_eq!(req.graph.get(0, 1), 2.0);
    }
}

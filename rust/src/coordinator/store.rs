//! Persistent content-addressed closure store: crash-safe warm starts.
//!
//! The solved closures are the expensive artifact this system exists to
//! produce, and until this module they died with the process — a restart
//! under load re-solved everything from scratch.  The store persists each
//! cache entry `(graph, dist, succ, chain)` as one checksummed binary
//! file, keyed by the same objective-mixed fingerprint the in-memory
//! cache uses ([`super::cache::objective_fingerprint`]), so a rebooted
//! coordinator serves yesterday's closures bitwise-identical from disk.
//! It is also the persistence substrate the out-of-core superblock tier
//! (ROADMAP item 4) will spill tiles into.
//!
//! ## Entry layout (all integers little-endian)
//!
//! The byte discipline is [`super::frame`]'s — magic + version +
//! length-validated LE body — extended with a trailing integrity seal:
//!
//! | offset | size | field                                            |
//! |-------:|-----:|--------------------------------------------------|
//! |      0 |    4 | magic `"FWCS"`                                   |
//! |      4 |    1 | version (currently 1)                            |
//! |      5 |    1 | flags (bit 0: successor matrix present)          |
//! |      6 |    2 | variant byte length (u16)                        |
//! |      8 |    4 | n (u32)                                          |
//! |     12 |    4 | chain depth (u32)                                |
//! |     16 |    8 | objective-mixed fingerprint (u64)                |
//! |     24 |    8 | body length in bytes (u64)                       |
//! |     32 | body | variant UTF-8, n² f32 graph, n² f32 dist, then n² u32 succ if flagged |
//! |    end |    8 | FNV-1a 64 over every preceding byte ([`crate::util::checksum`]) |
//!
//! [`crate::apsp::paths::NO_PATH`] successors travel as `u32::MAX`, and
//! `+inf` weights as raw IEEE bits — the frame's conventions.  The body
//! length is redundant with `n` + flags + variant length and is validated
//! against them; the file length must match exactly (a longer file is as
//! corrupt as a shorter one).
//!
//! ## Atomicity and corruption
//!
//! Entries are published by write-to-temp → `sync_all` → `rename`: the
//! rename is atomic on POSIX filesystems, so a reader can never observe a
//! half-written `.fwc` file — a crash mid-write leaves only a `.tmp`
//! orphan, which [`Store::open`] sweeps (and counts) on the next boot.
//! Every load re-verifies the full checksum; any defect (bad magic,
//! version skew, short read, length mismatch, checksum mismatch, identity
//! mismatch) **quarantines** the file — renamed to `*.quarantine`, a
//! typed `store_corrupt` log event, the `store_corrupt` metric — and the
//! request falls through to a clean re-solve.  A damaged entry is never
//! served and never silently deleted: the quarantined bytes stay on disk
//! for a post-mortem.
//!
//! ## Eviction
//!
//! `max_bytes > 0` bounds the directory: after each put, oldest-mtime
//! entries (reads touch mtime, so this is disk LRU) are deleted until the
//! total fits, never evicting the entry just written.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::SystemTime;

use anyhow::{bail, Context, Result};

use super::metrics::Metrics;
use super::types::MAX_N;
use crate::apsp::paths::NO_PATH;
use crate::graph::DistMatrix;
use crate::obs::log::{log, Level};
use crate::util::checksum::{fnv64, Fnv64};
use crate::util::json::Json;

/// Entry-file magic: the first four bytes of every `.fwc` file.
pub const MAGIC: [u8; 4] = *b"FWCS";

/// Current on-disk entry version.
pub const VERSION: u8 = 1;

/// Fixed header size in bytes (the checksum trails the body).
pub const HEADER_LEN: usize = 32;

/// Flags bit 0: the body carries an n² u32 successor matrix after dist.
pub const FLAG_SUCC: u8 = 1;

/// Wire rendering of [`NO_PATH`] in the successor matrix (the frame's).
const NO_PATH_WIRE: u32 = u32::MAX;

/// Store shape: where entries live and how many bytes they may total.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Directory holding the `.fwc` entries (created if missing).
    pub dir: PathBuf,
    /// Disk budget in bytes; `0` = unbounded.  Enforced after each put by
    /// deleting oldest-mtime entries until the directory fits.
    pub max_bytes: u64,
}

/// One persisted closure, exactly what the in-memory cache holds per key.
#[derive(Clone, Debug, PartialEq)]
pub struct StoreEntry {
    pub variant: String,
    /// Objective-mixed fingerprint — the cache key's hash half.  Stored
    /// (not recomputed from `graph`) because the objective tag is mixed in
    /// and the raw graph alone cannot reproduce it.
    pub fingerprint: u64,
    pub graph: DistMatrix,
    pub dist: DistMatrix,
    pub succ: Option<Vec<usize>>,
    pub chain: u32,
}

/// One row of the store index (warm-start ordering, eviction, CI dumps).
#[derive(Clone, Debug)]
pub struct IndexEntry {
    pub path: PathBuf,
    pub bytes: u64,
    pub modified: SystemTime,
}

/// The on-disk closure store.  All methods are `&self`; the filesystem is
/// the shared state (atomic renames make concurrent puts safe).
pub struct Store {
    dir: PathBuf,
    max_bytes: u64,
    metrics: Arc<Metrics>,
}

impl Store {
    /// Open (creating the directory if needed), sweeping `.tmp` orphans a
    /// crash mid-write may have left behind.
    pub fn open(config: StoreConfig, metrics: Arc<Metrics>) -> Result<Store> {
        fs::create_dir_all(&config.dir)
            .with_context(|| format!("store: creating {}", config.dir.display()))?;
        let store = Store {
            dir: config.dir,
            max_bytes: config.max_bytes,
            metrics,
        };
        store.sweep_stale_tmp()?;
        let index = store.index();
        log(
            Level::Info,
            "store_open",
            vec![
                ("dir", Json::str(store.dir.display().to_string())),
                ("entries", Json::num(index.len() as f64)),
                (
                    "bytes",
                    Json::num(index.iter().map(|e| e.bytes).sum::<u64>() as f64),
                ),
            ],
        );
        Ok(store)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path an entry for this key lives at (or would).  Content-addressed:
    /// fingerprint + n + variant *are* the filename, so lookup is one
    /// `open`, no index file to maintain or corrupt.  The decoded body
    /// repeats the identity and [`Store::get`] cross-checks it, so a
    /// renamed or collided file can never serve the wrong closure.
    pub fn entry_path(&self, variant: &str, n: usize, fingerprint: u64) -> PathBuf {
        let safe: String = variant
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        self.dir.join(format!("{fingerprint:016x}-{n}-{safe}.fwc"))
    }

    /// Load one entry, verifying the checksum and identity.  Any defect
    /// quarantines the file and reads as a miss — corrupt bytes are never
    /// served.  A hit touches the file's mtime (disk-LRU for eviction).
    pub fn get(&self, variant: &str, n: usize, fingerprint: u64) -> Option<StoreEntry> {
        let path = self.entry_path(variant, n, fingerprint);
        let bytes = match fs::read(&path) {
            Ok(bytes) => bytes,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                self.metrics.record_store_miss();
                return None;
            }
            Err(e) => {
                log(
                    Level::Warn,
                    "store_read_error",
                    vec![
                        ("path", Json::str(path.display().to_string())),
                        ("error", Json::str(e.to_string())),
                    ],
                );
                self.metrics.record_store_miss();
                return None;
            }
        };
        match decode_entry(&bytes) {
            Ok(entry)
                if entry.variant == variant
                    && entry.graph.n() == n
                    && entry.fingerprint == fingerprint =>
            {
                self.touch(&path);
                self.metrics.record_store_hit();
                Some(entry)
            }
            // decoded clean but answers a different key than the filename
            // claims (renamed file, sanitize collision): as unservable as
            // a bad checksum
            Ok(_) => {
                self.quarantine(&path, "entry identity does not match its filename");
                self.metrics.record_store_miss();
                None
            }
            Err(e) => {
                self.quarantine(&path, &e.to_string());
                self.metrics.record_store_miss();
                None
            }
        }
    }

    /// Durably publish one entry: encode, write `.tmp`, `sync_all`,
    /// rename into place.  Then enforce the size budget (never evicting
    /// the entry just written).
    pub fn put(
        &self,
        variant: &str,
        fingerprint: u64,
        graph: &DistMatrix,
        dist: &DistMatrix,
        succ: Option<&[usize]>,
        chain: u32,
    ) -> Result<()> {
        let bytes = encode_entry(variant, fingerprint, graph, dist, succ, chain)?;
        let path = self.entry_path(variant, graph.n(), fingerprint);
        let tmp = path.with_extension("tmp");
        let mut file = fs::File::create(&tmp)
            .with_context(|| format!("store: creating {}", tmp.display()))?;
        file.write_all(&bytes)
            .with_context(|| format!("store: writing {}", tmp.display()))?;
        // the rename only publishes durable bytes: without the sync, a
        // power loss after the rename could expose a hole-y file under
        // the *final* name, defeating the whole temp dance
        file.sync_all()
            .with_context(|| format!("store: syncing {}", tmp.display()))?;
        drop(file);
        fs::rename(&tmp, &path)
            .with_context(|| format!("store: publishing {}", path.display()))?;
        self.metrics.record_store_write();
        if self.max_bytes > 0 {
            self.enforce_budget(&path);
        }
        Ok(())
    }

    /// All `.fwc` entries, oldest-mtime first (ties broken by path, so
    /// eviction order is deterministic under coarse filesystem clocks).
    pub fn index(&self) -> Vec<IndexEntry> {
        let mut out = Vec::new();
        let Ok(dir) = fs::read_dir(&self.dir) else {
            return out;
        };
        for entry in dir.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("fwc") {
                continue;
            }
            if let Ok(meta) = entry.metadata() {
                out.push(IndexEntry {
                    path,
                    bytes: meta.len(),
                    modified: meta.modified().unwrap_or(SystemTime::UNIX_EPOCH),
                });
            }
        }
        out.sort_by(|a, b| (a.modified, &a.path).cmp(&(b.modified, &b.path)));
        out
    }

    /// Load the newest `limit` entries for a boot-time warm start,
    /// returned **oldest-first** so inserting them in order leaves the
    /// newest entry most-recently-used in the cache's LRU.  Corrupt
    /// entries are quarantined and skipped, exactly as in [`Store::get`].
    pub fn warm(&self, limit: usize) -> Vec<StoreEntry> {
        let index = self.index();
        let skip = index.len().saturating_sub(limit);
        let mut out = Vec::new();
        for row in index.into_iter().skip(skip) {
            let bytes = match fs::read(&row.path) {
                Ok(bytes) => bytes,
                Err(_) => continue,
            };
            match decode_entry(&bytes) {
                Ok(entry) => {
                    self.metrics.record_store_hit();
                    out.push(entry);
                }
                Err(e) => self.quarantine(&row.path, &e.to_string()),
            }
        }
        out
    }

    /// Index as JSON (the CI persistence-smoke artifact).
    pub fn index_json(&self) -> Json {
        Json::Arr(
            self.index()
                .into_iter()
                .map(|e| {
                    let age = e
                        .modified
                        .duration_since(SystemTime::UNIX_EPOCH)
                        .map(|d| d.as_secs_f64())
                        .unwrap_or(0.0);
                    Json::obj(vec![
                        ("file", Json::str(e.path.display().to_string())),
                        ("bytes", Json::num(e.bytes as f64)),
                        ("modified_epoch_s", Json::num(age)),
                    ])
                })
                .collect(),
        )
    }

    fn sweep_stale_tmp(&self) -> Result<()> {
        for entry in fs::read_dir(&self.dir)
            .with_context(|| format!("store: listing {}", self.dir.display()))?
            .flatten()
        {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) == Some("tmp") {
                // a crash between create and rename: never published, so
                // nothing was lost — but its presence is recorded like any
                // other damage
                let _ = fs::remove_file(&path);
                self.metrics.record_store_corrupt();
                log(
                    Level::Warn,
                    "store_stale_tmp",
                    vec![("path", Json::str(path.display().to_string()))],
                );
            }
        }
        Ok(())
    }

    /// Move a damaged entry aside (`*.quarantine`), keeping the bytes for
    /// a post-mortem; emit the typed log event and metric.
    fn quarantine(&self, path: &Path, reason: &str) {
        self.metrics.record_store_corrupt();
        let mut target = path.as_os_str().to_os_string();
        target.push(".quarantine");
        let renamed = fs::rename(path, &target).is_ok();
        if !renamed {
            // fall back to deletion: a corrupt entry must not stay
            // loadable under its content address
            let _ = fs::remove_file(path);
        }
        log(
            Level::Warn,
            "store_corrupt",
            vec![
                ("path", Json::str(path.display().to_string())),
                ("reason", Json::str(reason)),
                ("quarantined", Json::Bool(renamed)),
            ],
        );
    }

    /// Best-effort mtime bump on a hit, so disk eviction is LRU rather
    /// than insertion-order.  Failure is harmless (eviction degrades to
    /// FIFO for that entry).
    fn touch(&self, path: &Path) {
        let times = fs::FileTimes::new().set_modified(SystemTime::now());
        let _ = fs::File::options()
            .append(true)
            .open(path)
            .and_then(|f| f.set_times(times));
    }

    /// Delete oldest-mtime entries until the directory fits `max_bytes`,
    /// never deleting `protect` (the entry just written — evicting it
    /// would make the put a silent no-op).  If `protect` alone exceeds
    /// the budget, everything else goes and it stays: an over-budget
    /// store beats a put that never persists.
    fn enforce_budget(&self, protect: &Path) {
        let index = self.index();
        let mut total: u64 = index.iter().map(|e| e.bytes).sum();
        let mut evicted = 0u64;
        let mut freed = 0u64;
        for entry in &index {
            if total <= self.max_bytes {
                break;
            }
            if entry.path == protect {
                continue;
            }
            if fs::remove_file(&entry.path).is_ok() {
                total -= entry.bytes;
                freed += entry.bytes;
                evicted += 1;
            }
        }
        if evicted > 0 {
            self.metrics.record_store_evictions(evicted);
            log(
                Level::Info,
                "store_evict",
                vec![
                    ("evicted", Json::num(evicted as f64)),
                    ("freed_bytes", Json::num(freed as f64)),
                    ("resident_bytes", Json::num(total as f64)),
                ],
            );
        }
    }
}

fn body_len(n: usize, variant_len: usize, with_succ: bool) -> u64 {
    let cells = (n as u64) * (n as u64);
    variant_len as u64 + cells * 8 + if with_succ { cells * 4 } else { 0 }
}

/// Serialize one entry, checksum included.  In-memory: entries are cache
/// payloads (bounded by cache capacity), not superblock-scale matrices.
pub fn encode_entry(
    variant: &str,
    fingerprint: u64,
    graph: &DistMatrix,
    dist: &DistMatrix,
    succ: Option<&[usize]>,
    chain: u32,
) -> Result<Vec<u8>> {
    let n = graph.n();
    if dist.n() != n {
        bail!("store: graph n={n} but dist n={}", dist.n());
    }
    if let Some(succ) = succ {
        if succ.len() != n * n {
            bail!("store: succ length {} but n²={}", succ.len(), n * n);
        }
    }
    if variant.len() > u16::MAX as usize {
        bail!("store: variant name longer than {} bytes", u16::MAX);
    }
    let with_succ = succ.is_some();
    let body = body_len(n, variant.len(), with_succ);
    let mut out = Vec::with_capacity(HEADER_LEN + body as usize + 8);
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(if with_succ { FLAG_SUCC } else { 0 });
    out.extend_from_slice(&(variant.len() as u16).to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&chain.to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&body.to_le_bytes());
    out.extend_from_slice(variant.as_bytes());
    for &w in graph.as_slice() {
        out.extend_from_slice(&w.to_le_bytes());
    }
    for &w in dist.as_slice() {
        out.extend_from_slice(&w.to_le_bytes());
    }
    if let Some(succ) = succ {
        for &s in succ {
            let wire = if s == NO_PATH { NO_PATH_WIRE } else { s as u32 };
            out.extend_from_slice(&wire.to_le_bytes());
        }
    }
    let seal = fnv64(&out);
    out.extend_from_slice(&seal.to_le_bytes());
    Ok(out)
}

/// Decode one entry, validating structure and the trailing checksum.
/// Every failure mode gets its own typed message (the quarantine log's
/// `reason`); none ever yields a partially-decoded entry.
pub fn decode_entry(bytes: &[u8]) -> Result<StoreEntry> {
    if bytes.len() < HEADER_LEN + 8 {
        bail!("store: short read ({} bytes, header needs {})", bytes.len(), HEADER_LEN + 8);
    }
    if bytes[0..4] != MAGIC {
        bail!("store: bad magic {:?} (expected {MAGIC:?})", &bytes[0..4]);
    }
    let version = bytes[4];
    if version != VERSION {
        bail!("store: unsupported version {version} (this build speaks {VERSION})");
    }
    let flags = bytes[5];
    if flags & !FLAG_SUCC != 0 {
        bail!("store: unknown flag bits 0x{:02x}", flags & !FLAG_SUCC);
    }
    let with_succ = flags & FLAG_SUCC != 0;
    let variant_len = u16::from_le_bytes(bytes[6..8].try_into().unwrap()) as usize;
    let n = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    if n == 0 || n > MAX_N {
        bail!("store: n={n} outside 1..={MAX_N}");
    }
    let chain = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let fingerprint = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let declared = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
    let expected = body_len(n, variant_len, with_succ);
    if declared != expected {
        bail!(
            "store: body length {declared} does not match n={n} \
             variant_len={variant_len} flags=0x{flags:02x} (expected {expected})"
        );
    }
    let total = HEADER_LEN + expected as usize + 8;
    if bytes.len() != total {
        bail!("store: file length {} does not match entry length {total}", bytes.len());
    }
    // the seal covers header + body; verify before trusting any of it
    let declared_seal = u64::from_le_bytes(bytes[total - 8..].try_into().unwrap());
    let mut seal = Fnv64::new();
    seal.update(&bytes[..total - 8]);
    if seal.finish() != declared_seal {
        bail!(
            "store: checksum mismatch (sealed {declared_seal:016x}, computed {:016x})",
            seal.finish()
        );
    }
    let mut at = HEADER_LEN;
    let variant = std::str::from_utf8(&bytes[at..at + variant_len])
        .context("store: variant is not UTF-8")?
        .to_string();
    at += variant_len;
    let cells = n * n;
    let mut read_matrix = |at: &mut usize| {
        let data: Vec<f32> = bytes[*at..*at + cells * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        *at += cells * 4;
        DistMatrix::from_vec(n, data)
    };
    let graph = read_matrix(&mut at);
    let dist = read_matrix(&mut at);
    let succ = if with_succ {
        let mut succ = Vec::with_capacity(cells);
        for cell in bytes[at..at + cells * 4].chunks_exact(4) {
            let wire = u32::from_le_bytes(cell.try_into().unwrap());
            if wire == NO_PATH_WIRE {
                succ.push(NO_PATH);
            } else {
                let s = wire as usize;
                if s >= n {
                    bail!("store: successor {s} out of range for n={n}");
                }
                succ.push(s);
            }
        }
        Some(succ)
    } else {
        None
    };
    Ok(StoreEntry {
        variant,
        fingerprint,
        graph,
        dist,
        succ,
        chain,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Unique per-test scratch dir, removed on drop.
    struct TempDir(PathBuf);

    impl TempDir {
        fn new(tag: &str) -> TempDir {
            static SEQ: AtomicUsize = AtomicUsize::new(0);
            let path = std::env::temp_dir().join(format!(
                "fw-store-unit-{tag}-{}-{}",
                std::process::id(),
                SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            let _ = fs::remove_dir_all(&path);
            TempDir(path)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    fn open(dir: &TempDir, max_bytes: u64) -> (Store, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let store = Store::open(
            StoreConfig { dir: dir.0.clone(), max_bytes },
            metrics.clone(),
        )
        .expect("store opens");
        (store, metrics)
    }

    fn counter(metrics: &Metrics, key: &str) -> usize {
        metrics.snapshot().get(key).as_usize().unwrap()
    }

    fn sample(n: usize) -> (DistMatrix, DistMatrix, Vec<usize>) {
        let g = generators::ring(n);
        let r = crate::apsp::paths::solve(&g);
        let succ = r.succ().to_vec();
        (g, r.dist, succ)
    }

    #[test]
    fn header_bytes_are_pinned() {
        // the layout is an on-disk contract: freeze the exact bytes
        let g = DistMatrix::unconnected(1);
        let bytes = encode_entry("v", 0x1122_3344_5566_7788, &g, &g, None, 3).unwrap();
        assert_eq!(bytes.len(), HEADER_LEN + 1 + 8 + 8);
        assert_eq!(&bytes[0..4], b"FWCS");
        assert_eq!(bytes[4], 1, "version");
        assert_eq!(bytes[5], 0, "no succ flag");
        assert_eq!(&bytes[6..8], &1u16.to_le_bytes(), "variant length");
        assert_eq!(&bytes[8..12], &1u32.to_le_bytes(), "n");
        assert_eq!(&bytes[12..16], &3u32.to_le_bytes(), "chain");
        assert_eq!(&bytes[16..24], &0x1122_3344_5566_7788u64.to_le_bytes(), "fingerprint");
        assert_eq!(&bytes[24..32], &9u64.to_le_bytes(), "body length");
        assert_eq!(bytes[32], b'v');
        // graph then dist: the 1×1 unconnected matrix is one 0.0 diagonal
        assert_eq!(&bytes[33..37], &0.0f32.to_le_bytes());
        assert_eq!(&bytes[37..41], &0.0f32.to_le_bytes());
        let seal = u64::from_le_bytes(bytes[41..49].try_into().unwrap());
        assert_eq!(seal, fnv64(&bytes[..41]), "trailing seal covers header + body");
    }

    #[test]
    fn round_trips_bitwise_with_and_without_succ() {
        let dir = TempDir::new("roundtrip");
        let (store, metrics) = open(&dir, 0);
        let (g, dist, succ) = sample(9);
        let fp = 0xDEAD_BEEF_u64;
        store.put("staged", fp, &g, &dist, Some(&succ), 2).unwrap();
        let back = store.get("staged", 9, fp).expect("hit");
        assert_eq!(back.variant, "staged");
        assert_eq!(back.fingerprint, fp);
        assert_eq!(back.chain, 2);
        for (a, b) in back.dist.as_slice().iter().zip(dist.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "dist must round-trip bitwise");
        }
        for (a, b) in back.graph.as_slice().iter().zip(g.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.succ.as_deref(), Some(&succ[..]));
        // dist-only entry under another key
        store.put("staged", fp ^ 1, &g, &dist, None, 0).unwrap();
        let back = store.get("staged", 9, fp ^ 1).expect("dist-only hit");
        assert!(back.succ.is_none());
        assert_eq!(counter(&metrics, "store_writes"), 2);
        assert_eq!(counter(&metrics, "store_hits"), 2);
        assert_eq!(counter(&metrics, "store_corrupt"), 0);
    }

    #[test]
    fn missing_entry_is_a_counted_miss() {
        let dir = TempDir::new("miss");
        let (store, metrics) = open(&dir, 0);
        assert!(store.get("staged", 8, 42).is_none());
        assert_eq!(counter(&metrics, "store_misses"), 1);
        assert_eq!(counter(&metrics, "store_corrupt"), 0);
    }

    #[test]
    fn no_path_successors_round_trip() {
        let dir = TempDir::new("nopath");
        let (store, _metrics) = open(&dir, 0);
        let g = DistMatrix::unconnected(3);
        let succ: Vec<usize> = vec![0, NO_PATH, NO_PATH, NO_PATH, 1, NO_PATH, NO_PATH, NO_PATH, 2];
        store.put("v", 7, &g, &g, Some(&succ), 0).unwrap();
        let back = store.get("v", 3, 7).unwrap();
        assert_eq!(back.succ.as_deref(), Some(&succ[..]));
    }

    #[test]
    fn corrupt_entries_are_quarantined_not_served() {
        // the frame.rs table-driven corruption test, against the disk:
        // every mutation must read as a miss, quarantine the file, and
        // bump store_corrupt — and a fresh put must then serve cleanly
        let (g, dist, succ) = sample(4);
        let fp = 0xABCD_u64;
        let cases: Vec<(&str, Box<dyn Fn(&mut Vec<u8>)>)> = vec![
            ("bad magic", Box::new(|f| f[0] = b'X')),
            ("version skew", Box::new(|f| f[4] = 9)),
            ("unknown flags", Box::new(|f| f[5] |= 0x80)),
            ("n zero", Box::new(|f| f[8..12].copy_from_slice(&0u32.to_le_bytes()))),
            ("body length", Box::new(|f| f[24..32].copy_from_slice(&7u64.to_le_bytes()))),
            ("truncated mid-body", Box::new(|f| f.truncate(HEADER_LEN + 20))),
            ("checksum bit flip", Box::new(|f| { let last = f.len() - 1; f[last] ^= 0x01; })),
            ("body bit flip", Box::new(|f| f[HEADER_LEN + 3] ^= 0x40)),
            ("trailing garbage", Box::new(|f| f.push(0))),
            (
                "succ out of range",
                Box::new(|f| {
                    // first succ cell: after variant (1 byte) + 2 matrices
                    let at = HEADER_LEN + 1 + 2 * 4 * 16;
                    f[at..at + 4].copy_from_slice(&99u32.to_le_bytes());
                }),
            ),
        ];
        for (i, (what, mutate)) in cases.iter().enumerate() {
            let dir = TempDir::new("corrupt");
            let (store, metrics) = open(&dir, 0);
            store.put("v", fp, &g, &dist, Some(&succ), 0).unwrap();
            let path = store.entry_path("v", 4, fp);
            let mut bytes = fs::read(&path).unwrap();
            mutate(&mut bytes);
            fs::write(&path, &bytes).unwrap();
            assert!(store.get("v", 4, fp).is_none(), "case {i} ({what}) must not serve");
            assert_eq!(counter(&metrics, "store_corrupt"), 1, "case {i} ({what})");
            assert!(!path.exists(), "case {i} ({what}): file must be moved aside");
            let mut quarantined = path.as_os_str().to_os_string();
            quarantined.push(".quarantine");
            assert!(
                PathBuf::from(&quarantined).exists(),
                "case {i} ({what}): quarantine keeps the bytes"
            );
            // the key is servable again after a clean re-solve re-puts it
            store.put("v", fp, &g, &dist, Some(&succ), 0).unwrap();
            assert!(store.get("v", 4, fp).is_some(), "case {i} ({what}): clean re-put serves");
        }
    }

    #[test]
    fn renamed_entry_fails_the_identity_check() {
        let dir = TempDir::new("identity");
        let (store, metrics) = open(&dir, 0);
        let (g, dist, _) = sample(5);
        store.put("v", 11, &g, &dist, None, 0).unwrap();
        // an entry copied to another key's address decodes clean but
        // answers the wrong question — it must quarantine, not serve
        let from = store.entry_path("v", 5, 11);
        let to = store.entry_path("v", 5, 12);
        fs::copy(&from, &to).unwrap();
        assert!(store.get("v", 5, 12).is_none());
        assert_eq!(counter(&metrics, "store_corrupt"), 1);
        // the honest copy still serves
        assert!(store.get("v", 5, 11).is_some());
    }

    #[test]
    fn stale_tmp_is_swept_and_counted_at_open() {
        let dir = TempDir::new("staletmp");
        {
            let (store, _metrics) = open(&dir, 0);
            let (g, dist, _) = sample(4);
            store.put("v", 5, &g, &dist, None, 0).unwrap();
        }
        // simulate a crash mid-write: a half-entry under the temp name
        let orphan = dir.0.join("deadbeef-4-v.tmp");
        fs::write(&orphan, b"FWCS partial...").unwrap();
        let (store, metrics) = open(&dir, 0);
        assert!(!orphan.exists(), "open sweeps the orphan");
        assert_eq!(counter(&metrics, "store_corrupt"), 1);
        // the published entry survived untouched
        assert!(store.get("v", 4, 5).is_some());
    }

    #[test]
    fn eviction_is_lru_by_mtime_and_never_the_fresh_write() {
        let dir = TempDir::new("evict");
        let (store, metrics) = open(&dir, 0);
        let (g, dist, _) = sample(6);
        // three entries with explicit, strictly increasing mtimes (the
        // filesystem clock is too coarse to rely on between writes)
        for (i, fp) in [1u64, 2, 3].iter().enumerate() {
            store.put("v", *fp, &g, &dist, None, 0).unwrap();
            let t = SystemTime::UNIX_EPOCH + std::time::Duration::from_secs(1000 + i as u64);
            let f = fs::File::options()
                .append(true)
                .open(store.entry_path("v", 6, *fp))
                .unwrap();
            f.set_times(fs::FileTimes::new().set_modified(t)).unwrap();
        }
        let entry_bytes = fs::metadata(store.entry_path("v", 6, 1)).unwrap().len();
        // budget fits two entries: the next put must evict the two oldest
        // (fp=1, fp=2), keep fp=3, and keep itself
        let store = Store {
            dir: store.dir.clone(),
            max_bytes: entry_bytes * 2 + entry_bytes / 2,
            metrics: metrics.clone(),
        };
        store.put("v", 4, &g, &dist, None, 0).unwrap();
        assert!(store.get("v", 6, 1).is_none(), "oldest evicted");
        assert!(store.get("v", 6, 2).is_none(), "second-oldest evicted");
        assert!(store.get("v", 6, 3).is_some(), "newest survivor kept");
        assert!(store.get("v", 6, 4).is_some(), "fresh write never evicted");
        assert_eq!(counter(&metrics, "store_evictions"), 2);
        assert_eq!(counter(&metrics, "store_corrupt"), 0);
    }

    #[test]
    fn warm_returns_newest_entries_oldest_first() {
        let dir = TempDir::new("warm");
        let (store, metrics) = open(&dir, 0);
        let (g, dist, _) = sample(4);
        for (i, fp) in [10u64, 20, 30].iter().enumerate() {
            store.put("v", *fp, &g, &dist, None, 0).unwrap();
            let t = SystemTime::UNIX_EPOCH + std::time::Duration::from_secs(2000 + i as u64);
            let f = fs::File::options()
                .append(true)
                .open(store.entry_path("v", 4, *fp))
                .unwrap();
            f.set_times(fs::FileTimes::new().set_modified(t)).unwrap();
        }
        let warmed = store.warm(2);
        let fps: Vec<u64> = warmed.iter().map(|e| e.fingerprint).collect();
        assert_eq!(fps, vec![20, 30], "newest two, oldest of them first");
        assert_eq!(counter(&metrics, "store_hits"), 2, "warm loads count as hits");
        // a limit beyond the population returns everything
        assert_eq!(store.warm(10).len(), 3);
    }

    #[test]
    fn variant_names_are_sanitized_into_filenames() {
        let dir = TempDir::new("sanitize");
        let (store, _metrics) = open(&dir, 0);
        let path = store.entry_path("sta/ged..x", 8, 0xFF);
        let name = path.file_name().unwrap().to_str().unwrap();
        assert_eq!(name, "00000000000000ff-8-sta_ged__x.fwc");
        // and a put under such a variant still round-trips (identity is
        // checked from the body, not the sanitized filename)
        let (g, dist, _) = sample(8);
        store.put("sta/ged..x", 0xFF, &g, &dist, None, 1).unwrap();
        let back = store.get("sta/ged..x", 8, 0xFF).unwrap();
        assert_eq!(back.variant, "sta/ged..x");
        assert_eq!(back.chain, 1);
    }
}

//! TCP front end: a fixed worker pool behind a bounded request queue.
//!
//! Request types:
//! * `{"type":"solve", "id", "n", "variant", "edges": [[u,v,w],…]}` →
//!   `{"type":"result", …}` (see [`super::types`]); add `"trace": true`
//!   and the result line carries the request's span tree under `"trace"`;
//!   add `"binary": true` and the result comes back as the
//!   length-prefixed binary frame ([`super::frame`]) instead of JSON
//! * `{"type":"update", "id", "n", "variant", "base": "<hex fingerprint>",
//!   "updates": [[u,v,w],…]}` → `{"type":"result", …}` from the
//!   incremental tier, or a typed `{"type":"error",
//!   "code":"update_base_missing"}` the client retries as a full solve
//! * `{"type":"ping"}` → `{"type":"pong"}`
//! * `{"type":"stats"}` → metrics snapshot
//! * `{"type":"trace", "k", "source", "objective"}` → last `k` journaled
//!   request traces, newest first, optionally filtered by tier source
//!   and/or objective
//! * `{"type":"exposition"}` → Prometheus-style metrics text (as a JSON
//!   string field; the wire stays line-delimited JSON)
//! * `{"type":"info"}` → artifact variants/buckets
//!
//! Malformed input gets a `{"type":"error"}` line and the connection stays
//! open.  Connection failures and malformed requests emit one structured
//! stderr line each ([`crate::obs::log`]) instead of being silently
//! dropped.
//!
//! **Threading model.**  Connection threads do blocking socket I/O only;
//! all solve/update work funnels through one fixed-width
//! [`crate::util::pool::JobPool`] (`workers` threads, `queue_depth`
//! pending requests), so CPU concurrency is bounded by configuration, not
//! by client count.  Control-plane requests (ping/stats/trace/…) answer
//! inline on the connection thread: they are cheap and must keep working
//! while the solve queue is saturated — that is when an operator needs
//! `stats` most.
//!
//! **Admission control.**  Two bounds, two typed sheds:
//! * connections past [`ServerConfig::max_connections`] get one
//!   `{"type":"error","code":"shed"}` line at accept time and close
//!   (`connections_shed` metric);
//! * data requests arriving with the worker queue full get the same typed
//!   `shed` line — but the connection stays open, because the *request*
//!   was refused, not the client (`requests_shed` metric).
//!
//! **Deadlines.**  Every data request carries a deadline: the wire
//! `"deadline_ms"` if present, else [`ServerConfig::deadline_ms`] (0
//! disables either way).  It is checked at dequeue — a request that
//! expired while queued never reaches a solver — and between solve phases
//! ([`super::Coordinator::solve_with_deadline`]).  Expiry is a typed
//! `{"code":"deadline_exceeded"}` error, and *is* counted as a request
//! error: the server accepted the work and failed to deliver it in time.
//!
//! **Idle timeout.**  A connection that sends nothing for
//! [`ServerConfig::idle_timeout_ms`] gets one typed
//! `{"code":"idle_timeout"}` line and is closed, returning its admission
//! slot (`idle_timeouts` metric).  Before this existed an idle client
//! held a `ConnGuard` slot forever.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::types::{
    attach_trace, decode_request, decode_update_request, decode_wire_options, encode_error,
    encode_error_coded, encode_response, write_response, Response, WireOptions,
    CODE_DEADLINE_EXCEEDED, CODE_IDLE_TIMEOUT, CODE_OBJECTIVE_UNSUPPORTED, CODE_SHED,
    CODE_UPDATE_BASE_MISSING,
};
use super::{frame, router, Coordinator, SolveOutcome, UpdateOutcome};
use crate::obs::log::{log, Level};
use crate::obs::{Span, TraceRecord};
use crate::util::json::Json;
use crate::util::pool::{JobPool, PoolConfig};

/// Error-code key for requests that failed to decode (counted in
/// `errors_by_code` alongside the typed wire codes).
const CODE_MALFORMED: &str = "malformed";
/// Error-code key for solve/update failures with no dedicated wire code.
const CODE_GENERIC: &str = "error";

/// Front-end limits: admission, worker pool, deadlines.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Hard cap on concurrently served connections.  Connections past the
    /// cap receive one typed shed line and are closed at accept time —
    /// they never get a handler thread.
    pub max_connections: usize,
    /// Worker threads solving data requests; 0 = one per core.  Control
    /// requests bypass the pool entirely.
    pub workers: usize,
    /// Bounded depth of the request queue feeding the workers; a data
    /// request arriving with the queue full is shed with the typed
    /// [`CODE_SHED`] error (the connection stays open).
    pub queue_depth: usize,
    /// Default per-request deadline in milliseconds; 0 = no deadline.
    /// Requests override it with the wire `"deadline_ms"` field.
    pub deadline_ms: u64,
    /// Per-connection idle read timeout in milliseconds; 0 = none.  An
    /// idle connection gets one typed [`CODE_IDLE_TIMEOUT`] line and is
    /// closed, freeing its admission slot.
    pub idle_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            // generous, but finite: a flood saturates here instead of at
            // process limits
            max_connections: 1024,
            workers: 0,
            // deep enough that bursty-but-under-capacity traffic never
            // sheds; overload still hits the bound in well under a second
            queue_depth: 256,
            // a minute covers the largest superblock solves by a wide
            // margin while still unsticking abandoned work eventually
            deadline_ms: 60_000,
            // five minutes idle before the slot is reclaimed
            idle_timeout_ms: 300_000,
        }
    }
}

/// Decrements the live-connection count when a handler thread finishes by
/// any path (clean EOF, error, panic unwind).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Everything a connection thread needs, shared by all of them.
struct ConnCtx {
    coord: Arc<Coordinator>,
    pool: JobPool,
    config: ServerConfig,
}

/// Refuse an over-cap connection: one typed `shed` error line, then drop
/// the socket.  Bounded write timeout so a hostile client that never
/// reads cannot wedge the accept thread.
fn shed_connection(mut stream: TcpStream, cap: usize) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let line = encode_error_coded(
        0,
        CODE_SHED,
        &format!("server at connection capacity ({cap}); back off and retry"),
    );
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.write_all(b"\n");
}

/// A running server (owns the accept thread; connection threads share the
/// worker pool through it).
pub struct Server {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    workers: usize,
    queue_depth: usize,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve on background threads
    /// with default limits.
    pub fn spawn(coordinator: Arc<Coordinator>, addr: &str) -> Result<Server> {
        Server::spawn_with(coordinator, addr, ServerConfig::default())
    }

    /// [`Server::spawn`] with explicit limits.
    pub fn spawn_with(
        coordinator: Arc<Coordinator>,
        addr: &str,
        config: ServerConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept_shutdown = shutdown.clone();
        let active: Arc<AtomicUsize> = Arc::new(AtomicUsize::new(0));
        let cap = config.max_connections.max(1);
        let pool = JobPool::new(PoolConfig {
            workers: config.workers,
            queue_depth: config.queue_depth,
            name: "fw-stage-worker".into(),
        });
        let (workers, queue_depth) = (pool.workers(), pool.queue_depth());
        let ctx = Arc::new(ConnCtx { coord: coordinator, pool, config });
        let handle = std::thread::Builder::new()
            .name("fw-stage-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(stream) => {
                            // claim a slot before spawning; the handler's
                            // guard releases it however the thread exits
                            let claimed = active
                                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |c| {
                                    if c < cap {
                                        Some(c + 1)
                                    } else {
                                        None
                                    }
                                })
                                .is_ok();
                            let peer = stream
                                .peer_addr()
                                .map(|a| a.to_string())
                                .unwrap_or_else(|_| "?".into());
                            if !claimed {
                                ctx.coord.metrics().record_shed();
                                log(
                                    Level::Warn,
                                    "connection_shed",
                                    vec![
                                        ("addr", Json::str(peer)),
                                        ("cap", Json::num(cap as f64)),
                                    ],
                                );
                                shed_connection(stream, cap);
                                continue;
                            }
                            let guard = ConnGuard(active.clone());
                            let ctx = ctx.clone();
                            let spawned = std::thread::Builder::new()
                                .name("fw-stage-conn".into())
                                .spawn(move || {
                                    let _guard = guard;
                                    if let Err(e) = handle_connection(&ctx, stream) {
                                        log(
                                            Level::Warn,
                                            "conn_error",
                                            vec![
                                                ("addr", Json::str(peer)),
                                                ("error", Json::str(format!("{e:#}"))),
                                            ],
                                        );
                                    }
                                });
                            if let Err(e) = spawned {
                                // a failed spawn drops the unrun closure —
                                // and with it the guard, releasing the slot
                                log(
                                    Level::Error,
                                    "conn_spawn_error",
                                    vec![("error", Json::str(format!("{e:#}")))],
                                );
                            }
                        }
                        Err(e) => {
                            log(
                                Level::Error,
                                "accept_error",
                                vec![("error", Json::str(format!("{e:#}")))],
                            );
                            break;
                        }
                    }
                }
            })?;
        Ok(Server {
            addr: local,
            shutdown,
            accept_handle: Some(handle),
            workers,
            queue_depth,
        })
    }

    /// The bound address (use with port 0 to discover the chosen port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Resolved worker-pool width (after the `0 = per-core` default).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Resolved request-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Ask the accept loop to stop (in-flight connections drain naturally).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the listener so `incoming()` returns
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // the worker pool itself drains when the last connection thread
        // drops its ConnCtx reference
    }
}

fn handle_connection(ctx: &ConnCtx, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    if ctx.config.idle_timeout_ms > 0 {
        stream
            .set_read_timeout(Some(Duration::from_millis(ctx.config.idle_timeout_ms)))
            .context("setting idle read timeout")?;
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // clean EOF
            Ok(_) => {}
            // read timeout: the connection sat idle past the limit (any
            // partially received line is abandoned with it) — send one
            // typed line and reclaim the admission slot
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                ctx.coord.metrics().record_idle_timeout();
                let reply = encode_error_coded(
                    0,
                    CODE_IDLE_TIMEOUT,
                    &format!(
                        "connection idle for more than {}ms; closing to free the slot",
                        ctx.config.idle_timeout_ms
                    ),
                );
                let _ = writer.set_write_timeout(Some(Duration::from_millis(250)));
                let _ = writer.write_all(reply.as_bytes());
                let _ = writer.write_all(b"\n");
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        serve_line(ctx, line.trim(), &mut writer)?;
    }
}

/// Resolve a request's absolute deadline: the wire `deadline_ms`
/// overrides the server default; 0 (either way) means none.
fn effective_deadline(config: &ServerConfig, opts: &WireOptions) -> Option<Instant> {
    let ms = opts.deadline_ms.unwrap_or(config.deadline_ms);
    (ms > 0).then(|| Instant::now() + Duration::from_millis(ms))
}

/// Serve one request line on a connection thread.  Control-plane types
/// answer inline (they must keep responding while the solve queue is
/// saturated); data-plane types (solve/update) go through the bounded
/// queue to the worker pool, and their replies are encoded back on this
/// thread so matrices stream straight to the socket.
fn serve_line(ctx: &ConnCtx, line: &str, writer: &mut TcpStream) -> Result<()> {
    let parsed = Json::parse(line).ok();
    let is_data = matches!(
        parsed.as_ref().map(|v| v.get("type").as_str().unwrap_or("solve")),
        Some("solve") | Some("update")
    );
    let Some(parsed) = parsed.filter(|_| is_data) else {
        // control plane, unknown types, and unparseable lines: cheap,
        // answered inline via the shared dispatcher, never queued
        let reply = handle_line(&ctx.coord, line);
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        return Ok(());
    };
    let id = parsed.get("id").as_f64().unwrap_or(0.0) as u64;
    let opts = decode_wire_options(&parsed);
    let deadline = effective_deadline(&ctx.config, &opts);
    let (tx, rx) = mpsc::channel();
    let coord = ctx.coord.clone();
    let job_line = line.to_string();
    let enqueued = Instant::now();
    let submitted = ctx.pool.try_submit(move || {
        let queue_wait = enqueued.elapsed().as_secs_f64();
        // dequeue-time deadline check: a request that expired while
        // queued is answered without ever reaching a solver
        let reply = if deadline.is_some_and(|d| Instant::now() >= d) {
            coord.metrics().record_error(CODE_DEADLINE_EXCEEDED);
            DataReply::Line(encode_error_coded(
                id,
                CODE_DEADLINE_EXCEEDED,
                "deadline expired while queued; solve abandoned",
            ))
        } else {
            handle_data(&coord, &job_line, &opts, deadline)
        };
        let _ = tx.send((reply, queue_wait));
    });
    if submitted.is_err() {
        // bounded-queue admission control: one typed shed line; the
        // connection stays open and the client backs off
        ctx.coord.metrics().record_queue_shed();
        log(
            Level::Warn,
            "request_shed",
            vec![
                ("id", Json::num(id as f64)),
                ("queue_depth", Json::num(ctx.pool.queue_depth() as f64)),
            ],
        );
        let reply = encode_error_coded(
            id,
            CODE_SHED,
            &format!(
                "request queue full (depth {}); back off and retry",
                ctx.pool.queue_depth()
            ),
        );
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        return Ok(());
    }
    match rx.recv() {
        Ok((reply, queue_wait)) => {
            ctx.coord.metrics().record_queue_wait(queue_wait);
            write_reply(&ctx.coord, reply, writer)
        }
        Err(_) => {
            // the worker job died mid-flight (a panic unwound through a
            // solver); the pool survives, this request reports generically
            ctx.coord.metrics().record_error(CODE_GENERIC);
            let reply = encode_error(id, "internal: request worker failed");
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")?;
            Ok(())
        }
    }
}

/// A solved data-plane reply before wire encoding; boxed so the queue and
/// channels move a pointer, not a matrix-bearing struct.
struct SolvedReply {
    resp: Response,
    /// Echo the span tree in the reply (JSON responses only).
    trace: bool,
    /// Reply with the binary frame instead of line-JSON.
    binary: bool,
    /// Span tree under assembly when tracing is enabled: the decode span
    /// already leads; the reply writer appends the encode span and
    /// journals the finished trace.
    obs: Option<Span>,
    objective: String,
}

enum DataReply {
    /// An already-encoded JSON line (errors — nothing big ever rides here).
    Line(String),
    Solved(Box<SolvedReply>),
}

/// Decode + solve one data-plane line (runs on a pool worker).  Returns
/// the pre-encoding reply so the connection thread owns serialization.
fn handle_data(
    coord: &Coordinator,
    line: &str,
    opts: &WireOptions,
    deadline: Option<Instant>,
) -> DataReply {
    let ty = Json::parse(line)
        .ok()
        .and_then(|v| v.get("type").as_str().map(str::to_string))
        .unwrap_or_else(|| "solve".to_string());
    match ty.as_str() {
        "update" => handle_update(coord, line, opts),
        _ => handle_solve(coord, line, opts, deadline),
    }
}

fn deadline_reply(coord: &Coordinator, id: u64, phase: &str) -> DataReply {
    coord.metrics().record_error(CODE_DEADLINE_EXCEEDED);
    DataReply::Line(encode_error_coded(
        id,
        CODE_DEADLINE_EXCEEDED,
        &format!("deadline expired at the {phase} phase; solve abandoned"),
    ))
}

fn handle_solve(
    coord: &Coordinator,
    line: &str,
    opts: &WireOptions,
    deadline: Option<Instant>,
) -> DataReply {
    let decode_start = Instant::now();
    let req = match decode_request(line) {
        Ok(req) => req,
        Err(e) => {
            coord.metrics().record_error(CODE_MALFORMED);
            log(
                Level::Warn,
                "malformed_request",
                vec![
                    ("kind", Json::str("solve")),
                    ("error", Json::str(format!("{e:#}"))),
                ],
            );
            return DataReply::Line(encode_error(0, &format!("{e:#}")));
        }
    };
    if opts.binary && req.trace {
        // the trace echo is a JSON splice; it has no binary rendering
        coord.metrics().record_error(CODE_MALFORMED);
        return DataReply::Line(encode_error(
            req.id,
            "\"binary\" responses cannot carry a \"trace\" echo; request one or the other",
        ));
    }
    // objective policy is pre-checked so the rejection is *typed* (wire
    // code, not a free-text message): unknown objectives and
    // johnson-with-non-shortest can be dispatched on by clients
    if let Err(msg) = router::objective_gate(&req.variant, &req.objective) {
        coord.metrics().record_error(CODE_OBJECTIVE_UNSUPPORTED);
        return DataReply::Line(encode_error_coded(req.id, CODE_OBJECTIVE_UNSUPPORTED, &msg));
    }
    if coord.obs().enabled {
        let decode_seconds = decode_start.elapsed().as_secs_f64();
        match coord.solve_spanned_with_deadline(&req, deadline) {
            Ok((SolveOutcome::Done(resp), mut root)) => {
                // the server owns the wire edges of the trace: decode
                // leads, encode trails (appended by the reply writer)
                let mut decode = Span::new("decode");
                decode.seconds = decode_seconds;
                root.children.insert(0, decode);
                DataReply::Solved(Box::new(SolvedReply {
                    resp,
                    trace: req.trace,
                    binary: opts.binary,
                    obs: Some(root),
                    objective: req.objective.clone(),
                }))
            }
            Ok((SolveOutcome::DeadlineExceeded { phase }, _)) => {
                deadline_reply(coord, req.id, phase)
            }
            Err(e) => {
                coord.metrics().record_error(CODE_GENERIC);
                DataReply::Line(encode_error(req.id, &format!("{e:#}")))
            }
        }
    } else {
        match coord.solve_with_deadline(&req, deadline) {
            Ok(SolveOutcome::Done(resp)) => DataReply::Solved(Box::new(SolvedReply {
                resp,
                trace: req.trace,
                binary: opts.binary,
                obs: None,
                objective: req.objective.clone(),
            })),
            Ok(SolveOutcome::DeadlineExceeded { phase }) => deadline_reply(coord, req.id, phase),
            Err(e) => {
                coord.metrics().record_error(CODE_GENERIC);
                DataReply::Line(encode_error(req.id, &format!("{e:#}")))
            }
        }
    }
}

fn handle_update(coord: &Coordinator, line: &str, opts: &WireOptions) -> DataReply {
    match decode_update_request(line) {
        // the dynamic tier chains (min, +) closures only — any other
        // objective is a typed policy rejection, same code as solve
        Ok(req) if router::objective_gate_update(&req.objective).is_err() => {
            coord.metrics().record_error(CODE_OBJECTIVE_UNSUPPORTED);
            let msg = router::objective_gate_update(&req.objective).unwrap_err();
            DataReply::Line(encode_error_coded(req.id, CODE_OBJECTIVE_UNSUPPORTED, &msg))
        }
        Ok(req) => match coord.update(&req) {
            Ok(UpdateOutcome::Solved(resp)) => DataReply::Solved(Box::new(SolvedReply {
                resp,
                trace: false,
                binary: opts.binary,
                obs: None,
                objective: req.objective.clone(),
            })),
            // the one *typed* error: the client retries as a full solve
            // of the mutated graph (not an operator-visible failure, so
            // it does not count as an error metric)
            Ok(UpdateOutcome::BaseMissing { fingerprint }) => {
                DataReply::Line(encode_error_coded(
                    req.id,
                    CODE_UPDATE_BASE_MISSING,
                    &format!(
                        "base closure {fingerprint:016x} is not cached \
                         (evicted or never solved here); re-solve the mutated graph"
                    ),
                ))
            }
            Err(e) => {
                coord.metrics().record_error(CODE_GENERIC);
                DataReply::Line(encode_error(req.id, &format!("{e:#}")))
            }
        },
        Err(e) => {
            coord.metrics().record_error(CODE_MALFORMED);
            log(
                Level::Warn,
                "malformed_request",
                vec![
                    ("kind", Json::str("update")),
                    ("error", Json::str(format!("{e:#}"))),
                ],
            );
            DataReply::Line(encode_error(0, &format!("{e:#}")))
        }
    }
}

/// Append the encode span to a finished trace and journal it.
fn journal_with_encode(
    coord: &Coordinator,
    mut root: Span,
    resp: &Response,
    objective: &str,
    encode_seconds: f64,
) -> Arc<TraceRecord> {
    let mut encode = Span::new("encode");
    encode.seconds = encode_seconds;
    root.child(encode);
    coord.journal().record(TraceRecord {
        id: resp.id,
        source: resp.source.name().into(),
        objective: objective.to_string(),
        n: resp.dist.n(),
        root,
    })
}

/// Encode a data reply as one JSON line — the all-in-one path used by
/// [`handle_line`] (tests and in-process tooling), which by contract
/// always yields the JSON rendering.  The TCP path streams instead
/// ([`write_reply`]).
fn finalize_json(coord: &Coordinator, reply: DataReply) -> String {
    let solved = match reply {
        DataReply::Line(line) => return line,
        DataReply::Solved(s) => *s,
    };
    let encode_start = Instant::now();
    let encoded = encode_response(&solved.resp);
    let encode_seconds = encode_start.elapsed().as_secs_f64();
    match solved.obs {
        Some(root) => {
            let record = journal_with_encode(
                coord,
                root,
                &solved.resp,
                &solved.objective,
                encode_seconds,
            );
            if solved.trace {
                attach_trace(&encoded, &record.root.to_json())
            } else {
                encoded
            }
        }
        None => encoded,
    }
}

/// Write a data reply to the socket.  Untraced JSON results stream
/// row-by-row through a buffered writer (peak memory O(n) per connection,
/// never the O(n²) rendered line); binary results stream the frame the
/// same way.  Trace-echo replies take the String path — the splice needs
/// the whole line.  On the streaming paths the encode span covers
/// serialization *and* the socket write: they are one fused pass.
fn write_reply(coord: &Coordinator, reply: DataReply, writer: &mut TcpStream) -> Result<()> {
    let solved = match reply {
        DataReply::Line(line) => {
            writer.write_all(line.as_bytes())?;
            writer.write_all(b"\n")?;
            return Ok(());
        }
        DataReply::Solved(s) => s,
    };
    if solved.trace {
        // JSON only: binary+trace was rejected at decode time
        let line = finalize_json(coord, DataReply::Solved(solved));
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        return Ok(());
    }
    let encode_start = Instant::now();
    {
        let mut out = BufWriter::with_capacity(64 * 1024, &mut *writer);
        if solved.binary {
            frame::write_frame(&mut out, &solved.resp)?;
        } else {
            write_response(&mut out, &solved.resp)?;
            out.write_all(b"\n")?;
        }
        out.flush()?;
    }
    if let Some(root) = solved.obs {
        journal_with_encode(
            coord,
            root,
            &solved.resp,
            &solved.objective,
            encode_start.elapsed().as_secs_f64(),
        );
    }
    Ok(())
}

/// Process one request line → one response line (shared with tests and
/// in-process tooling).  Data-plane lines run the same decode/solve path
/// as the TCP front end but without a queue or deadline, and always
/// render to JSON (binary negotiation applies to the socket path only).
pub fn handle_line(coord: &Coordinator, line: &str) -> String {
    let ty = Json::parse(line)
        .ok()
        .and_then(|v| v.get("type").as_str().map(str::to_string))
        .unwrap_or_else(|| "solve".to_string());
    match ty.as_str() {
        "ping" => Json::obj(vec![("type", Json::str("pong"))]).to_string(),
        "stats" => {
            let mut snap = coord.metrics().snapshot();
            if let Json::Obj(map) = &mut snap {
                map.insert("type".into(), Json::str("stats"));
            }
            snap.to_string()
        }
        "exposition" => Json::obj(vec![
            ("type", Json::str("exposition")),
            ("text", Json::str(coord.metrics().exposition())),
        ])
        .to_string(),
        "trace" => {
            let v = Json::parse(line).unwrap_or(Json::Null);
            let k = v.get("k").as_usize().unwrap_or(16);
            let traces: Vec<Json> = coord
                .journal()
                .last(k, v.get("source").as_str(), v.get("objective").as_str())
                .iter()
                .map(|r| r.to_json())
                .collect();
            Json::obj(vec![
                ("type", Json::str("trace")),
                ("count", Json::num(traces.len() as f64)),
                ("traces", Json::Arr(traces)),
            ])
            .to_string()
        }
        "info" => {
            let s = coord.manifest_summary();
            let mut fields = vec![
                ("type", Json::str("info")),
                (
                    "variants",
                    Json::Arr(s.variants.iter().map(|v| Json::str(v.clone())).collect()),
                ),
                (
                    "buckets",
                    Json::Arr(s.buckets.iter().map(|&b| Json::num(b as f64)).collect()),
                ),
                ("tile", Json::num(s.tile as f64)),
                // the CPU tiers' active SIMD lane ISA (see apsp::simd)
                ("kernel", Json::str(crate::apsp::simd::active().name())),
            ];
            // persistent closure store, when configured (key absent when
            // serving memory-only, so store-less replies are unchanged)
            if let Some(store) = coord.store() {
                fields.push(("store_dir", Json::str(store.dir().display().to_string())));
            }
            Json::obj(fields).to_string()
        }
        "solve" | "update" => {
            let opts = Json::parse(line)
                .ok()
                .map(|v| decode_wire_options(&v))
                .unwrap_or_default();
            finalize_json(coord, handle_data(coord, line, &opts, None))
        }
        other => {
            coord.metrics().record_error(CODE_MALFORMED);
            encode_error(0, &format!("unknown request type {other:?}"))
        }
    }
}

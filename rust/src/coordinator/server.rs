//! TCP front end: line-delimited JSON over a thread-per-connection server.
//!
//! Request types:
//! * `{"type":"solve", "id", "n", "variant", "edges": [[u,v,w],…]}` →
//!   `{"type":"result", …}` (see [`super::types`]); add `"trace": true`
//!   and the result line carries the request's span tree under `"trace"`
//! * `{"type":"update", "id", "n", "variant", "base": "<hex fingerprint>",
//!   "updates": [[u,v,w],…]}` → `{"type":"result", …}` from the
//!   incremental tier, or a typed `{"type":"error",
//!   "code":"update_base_missing"}` the client retries as a full solve
//! * `{"type":"ping"}` → `{"type":"pong"}`
//! * `{"type":"stats"}` → metrics snapshot
//! * `{"type":"trace", "k", "source", "objective"}` → last `k` journaled
//!   request traces, newest first, optionally filtered by tier source
//!   and/or objective
//! * `{"type":"exposition"}` → Prometheus-style metrics text (as a JSON
//!   string field; the wire stays line-delimited JSON)
//! * `{"type":"info"}` → artifact variants/buckets
//!
//! Malformed input gets a `{"type":"error"}` line and the connection stays
//! open; handler threads share the coordinator (the engine serializes
//! device work internally).  Connection failures and malformed requests
//! emit one structured stderr line each ([`crate::obs::log`]) instead of
//! being silently dropped.
//!
//! **Admission control.**  Handler threads are capped
//! ([`ServerConfig::max_connections`]): a connection arriving at the cap
//! gets one typed `{"type":"error","code":"shed"}` line and an immediate
//! close instead of an unbounded thread spawn, so a connection flood
//! degrades (clients back off and retry) rather than exhausting process
//! threads/memory.  Sheds are counted (`connections_shed` in stats /
//! `fw_connections_shed_total` in the exposition).  The full worker-pool
//! front end remains ROADMAP item 2; this is the minimal overload fix.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::router;
use super::types::{
    attach_trace, decode_request, decode_update_request, encode_error, encode_error_coded,
    encode_response, CODE_OBJECTIVE_UNSUPPORTED, CODE_SHED, CODE_UPDATE_BASE_MISSING,
};
use super::{Coordinator, UpdateOutcome};
use crate::obs::log::{log, Level};
use crate::obs::{Span, TraceRecord};
use crate::util::json::Json;

/// Error-code key for requests that failed to decode (counted in
/// `errors_by_code` alongside the typed wire codes).
const CODE_MALFORMED: &str = "malformed";
/// Error-code key for solve/update failures with no dedicated wire code.
const CODE_GENERIC: &str = "error";

/// Front-end admission limits.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Hard cap on concurrently served connections.  Connections past the
    /// cap receive one typed shed line and are closed at accept time —
    /// they never get a handler thread.
    pub max_connections: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            // generous for a thread-per-connection server, but finite: a
            // flood saturates here instead of at process limits
            max_connections: 1024,
        }
    }
}

/// Decrements the live-connection count when a handler thread finishes by
/// any path (clean EOF, error, panic unwind).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Refuse an over-cap connection: one typed `shed` error line, then drop
/// the socket.  Bounded write timeout so a hostile client that never
/// reads cannot wedge the accept thread.
fn shed_connection(mut stream: TcpStream, cap: usize) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let line = encode_error_coded(
        0,
        CODE_SHED,
        &format!("server at connection capacity ({cap}); back off and retry"),
    );
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.write_all(b"\n");
}

/// A running server (owns the accept thread).
pub struct Server {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve on background threads
    /// with default admission limits.
    pub fn spawn(coordinator: Arc<Coordinator>, addr: &str) -> Result<Server> {
        Server::spawn_with(coordinator, addr, ServerConfig::default())
    }

    /// [`Server::spawn`] with explicit admission limits.
    pub fn spawn_with(
        coordinator: Arc<Coordinator>,
        addr: &str,
        config: ServerConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept_shutdown = shutdown.clone();
        let active: Arc<AtomicUsize> = Arc::new(AtomicUsize::new(0));
        let cap = config.max_connections.max(1);
        let handle = std::thread::Builder::new()
            .name("fw-stage-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(stream) => {
                            // claim a slot before spawning; the handler's
                            // guard releases it however the thread exits
                            let claimed = active
                                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |c| {
                                    if c < cap {
                                        Some(c + 1)
                                    } else {
                                        None
                                    }
                                })
                                .is_ok();
                            let peer = stream
                                .peer_addr()
                                .map(|a| a.to_string())
                                .unwrap_or_else(|_| "?".into());
                            if !claimed {
                                coordinator.metrics().record_shed();
                                log(
                                    Level::Warn,
                                    "connection_shed",
                                    vec![
                                        ("addr", Json::str(peer)),
                                        ("cap", Json::num(cap as f64)),
                                    ],
                                );
                                shed_connection(stream, cap);
                                continue;
                            }
                            let guard = ConnGuard(active.clone());
                            let coord = coordinator.clone();
                            let spawned = std::thread::Builder::new()
                                .name("fw-stage-conn".into())
                                .spawn(move || {
                                    let _guard = guard;
                                    if let Err(e) = handle_connection(&coord, stream) {
                                        log(
                                            Level::Warn,
                                            "conn_error",
                                            vec![
                                                ("addr", Json::str(peer)),
                                                ("error", Json::str(format!("{e:#}"))),
                                            ],
                                        );
                                    }
                                });
                            if let Err(e) = spawned {
                                // a failed spawn drops the unrun closure —
                                // and with it the guard, releasing the slot
                                log(
                                    Level::Error,
                                    "conn_spawn_error",
                                    vec![("error", Json::str(format!("{e:#}")))],
                                );
                            }
                        }
                        Err(e) => {
                            log(
                                Level::Error,
                                "accept_error",
                                vec![("error", Json::str(format!("{e:#}")))],
                            );
                            break;
                        }
                    }
                }
            })?;
        Ok(Server {
            addr: local,
            shutdown,
            accept_handle: Some(handle),
        })
    }

    /// The bound address (use with port 0 to discover the chosen port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Ask the accept loop to stop (in-flight connections drain naturally).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the listener so `incoming()` returns
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_connection(coord: &Coordinator, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    let peer_reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in peer_reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(coord, &line);
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// Process one request line → one response line (shared with tests).
pub fn handle_line(coord: &Coordinator, line: &str) -> String {
    let ty = Json::parse(line)
        .ok()
        .and_then(|v| v.get("type").as_str().map(str::to_string))
        .unwrap_or_else(|| "solve".to_string());
    match ty.as_str() {
        "ping" => Json::obj(vec![("type", Json::str("pong"))]).to_string(),
        "stats" => {
            let mut snap = coord.metrics().snapshot();
            if let Json::Obj(map) = &mut snap {
                map.insert("type".into(), Json::str("stats"));
            }
            snap.to_string()
        }
        "exposition" => Json::obj(vec![
            ("type", Json::str("exposition")),
            ("text", Json::str(coord.metrics().exposition())),
        ])
        .to_string(),
        "trace" => {
            let v = Json::parse(line).unwrap_or(Json::Null);
            let k = v.get("k").as_usize().unwrap_or(16);
            let traces: Vec<Json> = coord
                .journal()
                .last(k, v.get("source").as_str(), v.get("objective").as_str())
                .iter()
                .map(|r| r.to_json())
                .collect();
            Json::obj(vec![
                ("type", Json::str("trace")),
                ("count", Json::num(traces.len() as f64)),
                ("traces", Json::Arr(traces)),
            ])
            .to_string()
        }
        "info" => {
            let s = coord.manifest_summary();
            Json::obj(vec![
                ("type", Json::str("info")),
                (
                    "variants",
                    Json::Arr(s.variants.iter().map(|v| Json::str(v.clone())).collect()),
                ),
                (
                    "buckets",
                    Json::Arr(s.buckets.iter().map(|&b| Json::num(b as f64)).collect()),
                ),
                ("tile", Json::num(s.tile as f64)),
                // the CPU tiers' active SIMD lane ISA (see apsp::simd)
                ("kernel", Json::str(crate::apsp::simd::active().name())),
            ])
            .to_string()
        }
        "solve" => {
            let decode_start = Instant::now();
            match decode_request(line) {
                // objective policy is pre-checked so the rejection is
                // *typed* (wire code, not a free-text message): unknown
                // objectives and johnson-with-non-shortest can be
                // dispatched on by clients
                Ok(req) => match router::objective_gate(&req.variant, &req.objective) {
                    Err(msg) => {
                        coord.metrics().record_error(CODE_OBJECTIVE_UNSUPPORTED);
                        encode_error_coded(req.id, CODE_OBJECTIVE_UNSUPPORTED, &msg)
                    }
                    Ok(_) if coord.obs().enabled => {
                        let decode_seconds = decode_start.elapsed().as_secs_f64();
                        match coord.solve_spanned(&req) {
                            Ok((resp, mut root)) => {
                                // the server owns the wire edges of the
                                // trace: decode leads, encode trails
                                let mut decode = Span::new("decode");
                                decode.seconds = decode_seconds;
                                root.children.insert(0, decode);
                                let encode_start = Instant::now();
                                let reply = encode_response(&resp);
                                let mut encode = Span::new("encode");
                                encode.seconds = encode_start.elapsed().as_secs_f64();
                                root.child(encode);
                                let record = coord.journal().record(TraceRecord {
                                    id: resp.id,
                                    source: resp.source.name().into(),
                                    objective: req.objective.clone(),
                                    n: req.graph.n(),
                                    root,
                                });
                                if req.trace {
                                    attach_trace(&reply, &record.root.to_json())
                                } else {
                                    reply
                                }
                            }
                            Err(e) => {
                                coord.metrics().record_error(CODE_GENERIC);
                                encode_error(req.id, &format!("{e:#}"))
                            }
                        }
                    }
                    Ok(_) => match coord.solve(&req) {
                        Ok(resp) => encode_response(&resp),
                        Err(e) => {
                            coord.metrics().record_error(CODE_GENERIC);
                            encode_error(req.id, &format!("{e:#}"))
                        }
                    },
                },
                Err(e) => {
                    coord.metrics().record_error(CODE_MALFORMED);
                    log(
                        Level::Warn,
                        "malformed_request",
                        vec![
                            ("kind", Json::str("solve")),
                            ("error", Json::str(format!("{e:#}"))),
                        ],
                    );
                    encode_error(0, &format!("{e:#}"))
                }
            }
        }
        "update" => match decode_update_request(line) {
            // the dynamic tier chains (min, +) closures only — any other
            // objective is a typed policy rejection, same code as solve
            Ok(req) if router::objective_gate_update(&req.objective).is_err() => {
                coord.metrics().record_error(CODE_OBJECTIVE_UNSUPPORTED);
                let msg = router::objective_gate_update(&req.objective).unwrap_err();
                encode_error_coded(req.id, CODE_OBJECTIVE_UNSUPPORTED, &msg)
            }
            Ok(req) => match coord.update(&req) {
                Ok(UpdateOutcome::Solved(resp)) => encode_response(&resp),
                // the one *typed* error: the client retries as a full
                // solve of the mutated graph (not an operator-visible
                // failure, so it does not count as an error metric)
                Ok(UpdateOutcome::BaseMissing { fingerprint }) => encode_error_coded(
                    req.id,
                    CODE_UPDATE_BASE_MISSING,
                    &format!(
                        "base closure {fingerprint:016x} is not cached \
                         (evicted or never solved here); re-solve the mutated graph"
                    ),
                ),
                Err(e) => {
                    coord.metrics().record_error(CODE_GENERIC);
                    encode_error(req.id, &format!("{e:#}"))
                }
            },
            Err(e) => {
                coord.metrics().record_error(CODE_MALFORMED);
                log(
                    Level::Warn,
                    "malformed_request",
                    vec![
                        ("kind", Json::str("update")),
                        ("error", Json::str(format!("{e:#}"))),
                    ],
                );
                encode_error(0, &format!("{e:#}"))
            }
        },
        other => {
            coord.metrics().record_error(CODE_MALFORMED);
            encode_error(0, &format!("unknown request type {other:?}"))
        }
    }
}

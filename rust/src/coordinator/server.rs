//! TCP front end: line-delimited JSON over a thread-per-connection server.
//!
//! Request types:
//! * `{"type":"solve", "id", "n", "variant", "edges": [[u,v,w],…]}` →
//!   `{"type":"result", …}` (see [`super::types`])
//! * `{"type":"update", "id", "n", "variant", "base": "<hex fingerprint>",
//!   "updates": [[u,v,w],…]}` → `{"type":"result", …}` from the
//!   incremental tier, or a typed `{"type":"error",
//!   "code":"update_base_missing"}` the client retries as a full solve
//! * `{"type":"ping"}` → `{"type":"pong"}`
//! * `{"type":"stats"}` → metrics snapshot
//! * `{"type":"info"}` → artifact variants/buckets
//!
//! Malformed input gets a `{"type":"error"}` line and the connection stays
//! open; handler threads share the coordinator (the engine serializes
//! device work internally).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::router;
use super::types::{
    decode_request, decode_update_request, encode_error, encode_error_coded, encode_response,
    CODE_OBJECTIVE_UNSUPPORTED, CODE_UPDATE_BASE_MISSING,
};
use super::{Coordinator, UpdateOutcome};
use crate::util::json::Json;

/// A running server (owns the accept thread).
pub struct Server {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve on background threads.
    pub fn spawn(coordinator: Arc<Coordinator>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept_shutdown = shutdown.clone();
        let handle = std::thread::Builder::new()
            .name("fw-stage-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(stream) => {
                            let coord = coordinator.clone();
                            let _ = std::thread::Builder::new()
                                .name("fw-stage-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(&coord, stream);
                                });
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server {
            addr: local,
            shutdown,
            accept_handle: Some(handle),
        })
    }

    /// The bound address (use with port 0 to discover the chosen port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Ask the accept loop to stop (in-flight connections drain naturally).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the listener so `incoming()` returns
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_connection(coord: &Coordinator, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    let peer_reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in peer_reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(coord, &line);
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// Process one request line → one response line (shared with tests).
pub fn handle_line(coord: &Coordinator, line: &str) -> String {
    let ty = Json::parse(line)
        .ok()
        .and_then(|v| v.get("type").as_str().map(str::to_string))
        .unwrap_or_else(|| "solve".to_string());
    match ty.as_str() {
        "ping" => Json::obj(vec![("type", Json::str("pong"))]).to_string(),
        "stats" => {
            let mut snap = coord.metrics().snapshot();
            if let Json::Obj(map) = &mut snap {
                map.insert("type".into(), Json::str("stats"));
            }
            snap.to_string()
        }
        "info" => {
            let s = coord.manifest_summary();
            Json::obj(vec![
                ("type", Json::str("info")),
                (
                    "variants",
                    Json::Arr(s.variants.iter().map(|v| Json::str(v.clone())).collect()),
                ),
                (
                    "buckets",
                    Json::Arr(s.buckets.iter().map(|&b| Json::num(b as f64)).collect()),
                ),
                ("tile", Json::num(s.tile as f64)),
            ])
            .to_string()
        }
        "solve" => match decode_request(line) {
            // objective policy is pre-checked so the rejection is *typed*
            // (wire code, not a free-text message): unknown objectives and
            // johnson-with-non-shortest can be dispatched on by clients
            Ok(req) => match router::objective_gate(&req.variant, &req.objective) {
                Err(msg) => {
                    coord.metrics().record_error();
                    encode_error_coded(req.id, CODE_OBJECTIVE_UNSUPPORTED, &msg)
                }
                Ok(_) => match coord.solve(&req) {
                    Ok(resp) => encode_response(&resp),
                    Err(e) => {
                        coord.metrics().record_error();
                        encode_error(req.id, &format!("{e:#}"))
                    }
                },
            },
            Err(e) => {
                coord.metrics().record_error();
                encode_error(0, &format!("{e:#}"))
            }
        },
        "update" => match decode_update_request(line) {
            // the dynamic tier chains (min, +) closures only — any other
            // objective is a typed policy rejection, same code as solve
            Ok(req) if router::objective_gate_update(&req.objective).is_err() => {
                coord.metrics().record_error();
                let msg = router::objective_gate_update(&req.objective).unwrap_err();
                encode_error_coded(req.id, CODE_OBJECTIVE_UNSUPPORTED, &msg)
            }
            Ok(req) => match coord.update(&req) {
                Ok(UpdateOutcome::Solved(resp)) => encode_response(&resp),
                // the one *typed* error: the client retries as a full
                // solve of the mutated graph (not an operator-visible
                // failure, so it does not count as an error metric)
                Ok(UpdateOutcome::BaseMissing { fingerprint }) => encode_error_coded(
                    req.id,
                    CODE_UPDATE_BASE_MISSING,
                    &format!(
                        "base closure {fingerprint:016x} is not cached \
                         (evicted or never solved here); re-solve the mutated graph"
                    ),
                ),
                Err(e) => {
                    coord.metrics().record_error();
                    encode_error(req.id, &format!("{e:#}"))
                }
            },
            Err(e) => {
                coord.metrics().record_error();
                encode_error(0, &format!("{e:#}"))
            }
        },
        other => encode_error(0, &format!("unknown request type {other:?}")),
    }
}

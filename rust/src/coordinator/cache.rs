//! Result cache: LRU over (objective, variant, graph-content hash).
//!
//! APSP is expensive and deterministic — identical graphs recur in routing
//! workloads (topology changes are much rarer than queries).  Keyed by an
//! FNV-1a hash of the matrix bytes plus n and variant, with the serving
//! objective mixed into the hash ([`objective_fingerprint`]) so a closure
//! taken over one semiring can never answer a request for another;
//! collisions are guarded by storing the full key (n, variant, hash) and
//! verifying n.
//!
//! ## Hot-path discipline
//!
//! Payloads are `Arc`'d and every O(n²) copy happens **outside** the
//! global mutex: a hit snapshots three `Arc` pointers under the lock and
//! deep-clones (when the caller needs ownership) after releasing it, so a
//! superblock-scale hit no longer serializes every other request behind a
//! multi-MB memcpy.  Eviction is O(log capacity) via a `BTreeMap` keyed
//! by the monotone touch clock (clock values are unique under the lock,
//! so the map is a faithful LRU order) — not a full-map scan.  The lock
//! itself recovers from poisoning ([`crate::util::sync`]): one panicking
//! request must not turn into a permanent all-requests panic.
//!
//! ## Backing store
//!
//! [`ResultCache::with_store`] attaches the persistent closure store
//! ([`super::store`]): lookups that miss memory consult disk **after**
//! releasing the lock (read-through; disk hits are re-inserted so the
//! next hit is a memory hit), and every insert that changes an entry is
//! persisted asynchronously through a single-worker [`JobPool`] —
//! write-behind off the request path, FIFO so chained re-baselines land
//! in order.  A full writer queue drops the write (the entry stays
//! correct in memory; the store is an optimization, never a dependency).

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::apsp::semiring::Objective;
use crate::graph::DistMatrix;
use crate::obs::log::{log, Level};
use crate::util::json::Json;
use crate::util::pool::JobPool;

use super::store::Store;

const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const PRIME: u64 = 0x0000_0100_0000_01B3;

/// FNV-1a-style hash over the matrix's raw f32 bits (stable across runs).
///
/// Folds **8 bytes (two f32 words) per multiply** instead of the textbook
/// byte-at-a-time FNV-1a: superblock-tier graphs are 16× bigger than the
/// largest device bucket, which put hashing on the request hot path — the
/// chunked fold does n²/2 multiplies instead of 4n², same avalanche-by-
/// prime construction.  An odd trailing word is folded on its own.  The
/// pinned-value tests below freeze the exact function.
pub fn graph_fingerprint(g: &DistMatrix) -> u64 {
    let mut h = OFFSET;
    h ^= g.n() as u64;
    h = h.wrapping_mul(PRIME);
    let mut chunks = g.as_slice().chunks_exact(2);
    for pair in chunks.by_ref() {
        let word = pair[0].to_bits() as u64 | ((pair[1].to_bits() as u64) << 32);
        h ^= word;
        h = h.wrapping_mul(PRIME);
    }
    if let [tail] = chunks.remainder() {
        h ^= tail.to_bits() as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// [`graph_fingerprint`] with the serving objective folded in: one extra
/// xor-multiply round over the objective tag.  `Objective::Shortest` is
/// the **identity** — tag 0 would xor nothing, so the round is skipped
/// outright — which keeps every pre-semiring fingerprint (pinned values,
/// `"update"` wire `base` fields, persisted client state) valid verbatim.
/// The pinned-value tests below freeze the mixing.
pub fn objective_fingerprint(g: &DistMatrix, objective: Objective) -> u64 {
    let h = graph_fingerprint(g);
    match objective.tag() {
        0 => h,
        tag => (h ^ tag).wrapping_mul(PRIME),
    }
}

/// The cache key every lookup and insert shares:
/// (variant, n, objective-mixed fingerprint).
fn make_key(objective: Objective, variant: &str, g: &DistMatrix) -> Key {
    Key {
        variant: variant.to_string(),
        n: g.n(),
        fingerprint: objective_fingerprint(g, objective),
    }
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct Key {
    variant: String,
    n: usize,
    fingerprint: u64,
}

struct Entry {
    /// The solved graph itself.  Kept so `"update"` requests can chain:
    /// an edge-delta batch needs the base weights to classify deltas and
    /// to fall back to a full solve (roughly triples the entry footprint;
    /// capacity bounds total memory as before).
    graph: Arc<DistMatrix>,
    dist: Arc<DistMatrix>,
    /// Successor matrix, present once a path-carrying solve has been
    /// cached for this key (same fingerprint — the key contract is shared
    /// with distance-only entries; paths *upgrade* an entry in place).
    succ: Option<Arc<Vec<usize>>>,
    /// Incremental updates applied since the last from-scratch solve of
    /// this closure (0 = a baseline).  The coordinator re-baselines when a
    /// chain exceeds its cap.
    chain: u32,
    /// Monotone counter value at last touch (LRU eviction order; doubles
    /// as this entry's key in `Inner::order`).
    last_used: u64,
}

/// A cached base closure an `"update"` request chains from — an atomic
/// snapshot of one entry (graph, closure, chain depth), taken under the
/// cache lock so a concurrent put can never hand out a split pair.  The
/// payloads are shared (`Arc`), not copied: snapshotting is O(1).
pub struct CachedBase {
    pub graph: Arc<DistMatrix>,
    pub dist: Arc<DistMatrix>,
    pub succ: Option<Arc<Vec<usize>>>,
    pub chain: u32,
}

/// Where a cache hit came from: the in-memory LRU, or the backing store
/// on disk (read-through).  Both are verified closures; the distinction
/// feeds the `store_get` span and the store metrics.
#[derive(Debug)]
pub enum CacheHit<T> {
    Memory(T),
    Disk(T),
}

impl<T> CacheHit<T> {
    pub fn into_inner(self) -> T {
        match self {
            CacheHit::Memory(v) | CacheHit::Disk(v) => v,
        }
    }

    pub fn from_disk(&self) -> bool {
        matches!(self, CacheHit::Disk(_))
    }
}

/// A thread-safe LRU result cache, optionally backed by the persistent
/// closure store.
pub struct ResultCache {
    inner: Mutex<Inner>,
    capacity: usize,
    store: Option<Arc<Store>>,
    /// Async persistence lane.  **Single worker by contract**: FIFO order
    /// is what makes [`ResultCache::flush_store`]'s sentinel a barrier and
    /// keeps chained re-baselines landing on disk in cache order.
    writer: Option<JobPool>,
}

struct Inner {
    map: HashMap<Key, Entry>,
    /// LRU order: touch-clock → key.  The clock is bumped once per
    /// operation under the lock, so values are unique and `pop_first`
    /// yields the least-recently-used key in O(log capacity).
    order: BTreeMap<u64, Key>,
    clock: u64,
    hits: u64,
    misses: u64,
}

impl ResultCache {
    /// `capacity` = max cached results (0 disables caching).
    pub fn new(capacity: usize) -> Self {
        Self::build(capacity, None, None)
    }

    /// A cache backed by the on-disk closure store: read-through on miss,
    /// async write-through on insert, warm-startable via
    /// [`ResultCache::warm_from_store`].  `writer` must be a
    /// **single-worker** pool (FIFO persistence order).  Capacity 0 still
    /// disables everything, store included.
    pub fn with_store(capacity: usize, store: Arc<Store>, writer: JobPool) -> Self {
        debug_assert_eq!(writer.workers(), 1, "store writer must be single-worker (FIFO)");
        Self::build(capacity, Some(store), Some(writer))
    }

    fn build(capacity: usize, store: Option<Arc<Store>>, writer: Option<JobPool>) -> Self {
        ResultCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: BTreeMap::new(),
                clock: 0,
                hits: 0,
                misses: 0,
            }),
            capacity,
            store,
            writer,
        }
    }

    pub fn has_store(&self) -> bool {
        self.store.is_some()
    }

    pub fn store(&self) -> Option<&Store> {
        self.store.as_deref()
    }

    pub fn get(&self, variant: &str, g: &DistMatrix) -> Option<DistMatrix> {
        self.get_for(Objective::Shortest, variant, g)
    }

    /// [`ResultCache::get`] under an explicit serving objective.  The
    /// returned matrix is deep-cloned *outside* the lock.
    pub fn get_for(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
    ) -> Option<DistMatrix> {
        self.lookup_for(objective, variant, g)
            .map(|hit| (*hit.into_inner()).clone())
    }

    /// Distance lookup returning the shared payload and its origin
    /// (memory vs disk read-through).  This is the request path's entry
    /// point; `get_for` wraps it for callers that need ownership.
    pub fn lookup_for(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
    ) -> Option<CacheHit<Arc<DistMatrix>>> {
        if self.capacity == 0 {
            return None;
        }
        let key = make_key(objective, variant, g);
        {
            let mut inner = crate::recover_lock!(&self.inner, "cache.inner");
            inner.clock += 1;
            let clock = inner.clock;
            match inner.map.get_mut(&key) {
                Some(entry) => {
                    let prev = entry.last_used;
                    entry.last_used = clock;
                    let dist = entry.dist.clone(); // Arc clone: O(1), no matrix copy
                    inner.order.remove(&prev);
                    inner.order.insert(clock, key);
                    inner.hits += 1;
                    return Some(CacheHit::Memory(dist));
                }
                None => inner.misses += 1,
            }
        }
        // memory miss: consult the store with the lock *released* — disk
        // latency must never serialize other requests
        let entry = self.store.as_ref()?.get(&key.variant, key.n, key.fingerprint)?;
        let dist = Arc::new(entry.dist);
        self.insert_shared(
            key,
            Arc::new(entry.graph),
            dist.clone(),
            entry.succ.map(Arc::new),
            entry.chain,
            false, // came *from* disk; writing it back would be churn
        );
        Some(CacheHit::Disk(dist))
    }

    /// Closure + successor lookup: hits only entries a path-carrying solve
    /// has populated (a distance-only entry cannot serve a paths request).
    pub fn get_paths(&self, variant: &str, g: &DistMatrix) -> Option<(DistMatrix, Vec<usize>)> {
        self.get_paths_for(Objective::Shortest, variant, g)
    }

    /// [`ResultCache::get_paths`] under an explicit serving objective.
    pub fn get_paths_for(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
    ) -> Option<(DistMatrix, Vec<usize>)> {
        self.lookup_paths_for(objective, variant, g).map(|hit| {
            let (dist, succ) = hit.into_inner();
            ((*dist).clone(), (*succ).clone())
        })
    }

    /// Paths lookup returning shared payloads and their origin.  A
    /// distance-only entry (memory or disk) reads as a miss, exactly as
    /// before — but a distance-only *disk* entry is still pulled into
    /// memory, so the follow-up solve can chain updates from its graph.
    pub fn lookup_paths_for(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
    ) -> Option<CacheHit<(Arc<DistMatrix>, Arc<Vec<usize>>)>> {
        if self.capacity == 0 {
            return None;
        }
        let key = make_key(objective, variant, g);
        {
            let mut inner = crate::recover_lock!(&self.inner, "cache.inner");
            inner.clock += 1;
            let clock = inner.clock;
            match inner.map.get_mut(&key) {
                Some(Entry { dist, succ: Some(succ), last_used, .. }) => {
                    let prev = *last_used;
                    *last_used = clock;
                    let hit = (dist.clone(), succ.clone()); // Arc clones
                    inner.order.remove(&prev);
                    inner.order.insert(clock, key);
                    inner.hits += 1;
                    return Some(CacheHit::Memory(hit));
                }
                _ => inner.misses += 1,
            }
        }
        let entry = self.store.as_ref()?.get(&key.variant, key.n, key.fingerprint)?;
        let dist = Arc::new(entry.dist);
        let succ = entry.succ.map(Arc::new);
        self.insert_shared(key, Arc::new(entry.graph), dist.clone(), succ.clone(), entry.chain, false);
        let succ = succ?; // dist-only disk entry: warmed memory, still a paths miss
        Some(CacheHit::Disk((dist, succ)))
    }

    pub fn put(&self, variant: &str, g: &DistMatrix, dist: DistMatrix) {
        self.insert(Objective::Shortest, variant, g, dist, None, 0);
    }

    /// [`ResultCache::put`] under an explicit serving objective.
    pub fn put_for(&self, objective: Objective, variant: &str, g: &DistMatrix, dist: DistMatrix) {
        self.insert(objective, variant, g, dist, None, 0);
    }

    /// Cache a path-carrying solve: the distance closure plus the successor
    /// matrix, under the same fingerprint key distance entries use.
    pub fn put_paths(&self, variant: &str, g: &DistMatrix, dist: DistMatrix, succ: Vec<usize>) {
        self.insert(Objective::Shortest, variant, g, dist, Some(succ), 0);
    }

    /// [`ResultCache::put_paths`] under an explicit serving objective.
    pub fn put_paths_for(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
        dist: DistMatrix,
        succ: Vec<usize>,
    ) {
        self.insert(objective, variant, g, dist, Some(succ), 0);
    }

    /// Cache an incrementally updated closure for the *mutated* graph `g`,
    /// recording how many updates separate it from its baseline.  A chain
    /// of updates is itself cache-hittable: the coordinator keys each link
    /// by the mutated graph's fingerprint, so replaying the same deltas —
    /// or solving the mutated graph outright — hits this entry.
    /// Chained closures are shortest-only, like the dynamic tier itself.
    pub fn put_chained(
        &self,
        variant: &str,
        g: &DistMatrix,
        dist: DistMatrix,
        succ: Option<Vec<usize>>,
        chain: u32,
    ) {
        self.insert(Objective::Shortest, variant, g, dist, succ, chain);
    }

    /// Atomic base-closure lookup for an `"update"` request, addressed by
    /// fingerprint (the request carries no graph — that is the point).
    /// Misses when the closure was never solved here or has been evicted
    /// — though with a backing store, an evicted (or pre-restart) closure
    /// is read through from disk, which is exactly what makes delta
    /// chains survive a process death.  On a true miss the caller
    /// surfaces a typed error the client retries as a full solve.  Like
    /// every lookup, trusts the 64-bit fingerprint not to collide (the
    /// request-path `get` makes the same bet).
    pub fn get_base(&self, variant: &str, n: usize, fingerprint: u64) -> Option<CachedBase> {
        if self.capacity == 0 {
            return None;
        }
        let key = Key {
            variant: variant.to_string(),
            n,
            fingerprint,
        };
        {
            let mut inner = crate::recover_lock!(&self.inner, "cache.inner");
            inner.clock += 1;
            let clock = inner.clock;
            match inner.map.get_mut(&key) {
                Some(entry) => {
                    let prev = entry.last_used;
                    entry.last_used = clock;
                    let base = CachedBase {
                        graph: entry.graph.clone(),
                        dist: entry.dist.clone(),
                        succ: entry.succ.clone(),
                        chain: entry.chain,
                    };
                    inner.order.remove(&prev);
                    inner.order.insert(clock, key);
                    inner.hits += 1;
                    return Some(base);
                }
                None => inner.misses += 1,
            }
        }
        let entry = self.store.as_ref()?.get(&key.variant, key.n, key.fingerprint)?;
        let graph = Arc::new(entry.graph);
        let dist = Arc::new(entry.dist);
        let succ = entry.succ.map(Arc::new);
        self.insert_shared(key, graph.clone(), dist.clone(), succ.clone(), entry.chain, false);
        Some(CachedBase {
            graph,
            dist,
            succ,
            chain: entry.chain,
        })
    }

    /// Preload the LRU from the store's newest entries (boot warm-start).
    /// Returns how many entries were loaded.  Inserted oldest-first (the
    /// store hands them back that way), so the newest entry on disk ends
    /// up most-recently-used.  Nothing is written back.
    pub fn warm_from_store(&self) -> usize {
        let Some(store) = &self.store else {
            return 0;
        };
        if self.capacity == 0 {
            return 0;
        }
        let entries = store.warm(self.capacity);
        let count = entries.len();
        for e in entries {
            let key = Key {
                variant: e.variant,
                n: e.graph.n(),
                fingerprint: e.fingerprint,
            };
            self.insert_shared(
                key,
                Arc::new(e.graph),
                Arc::new(e.dist),
                e.succ.map(Arc::new),
                e.chain,
                false,
            );
        }
        count
    }

    /// Block until every persistence job enqueued so far has completed.
    /// Correct because the writer is single-worker FIFO: a sentinel job's
    /// completion implies all prior jobs ran.  Admission waits (the queue
    /// may be momentarily full) — this is a teardown/test barrier, never
    /// the request path.
    pub fn flush_store(&self) {
        let Some(writer) = &self.writer else {
            return;
        };
        let (tx, rx) = std::sync::mpsc::channel();
        loop {
            let tx = tx.clone();
            if writer.try_submit(move || drop(tx.send(()))).is_ok() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let _ = rx.recv();
    }

    fn insert(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
        dist: DistMatrix,
        succ: Option<Vec<usize>>,
        chain: u32,
    ) {
        let key = make_key(objective, variant, g);
        // Arc allocation (and the one graph copy an insert inherently
        // needs) happens before the lock — nothing O(n²) inside it
        self.insert_shared(
            key,
            Arc::new(g.clone()),
            Arc::new(dist),
            succ.map(Arc::new),
            chain,
            true,
        );
    }

    /// The one insert path.  Merges under the lock, snapshots the merged
    /// entry (Arc clones), and — when the merge changed anything and a
    /// store is attached — enqueues the async persist after unlocking.
    fn insert_shared(
        &self,
        key: Key,
        graph: Arc<DistMatrix>,
        dist: Arc<DistMatrix>,
        succ: Option<Arc<Vec<usize>>>,
        chain: u32,
        persist: bool,
    ) {
        if self.capacity == 0 {
            return;
        }
        let persist = persist && self.store.is_some();
        let mut to_persist = None;
        {
            let mut inner = crate::recover_lock!(&self.inner, "cache.inner");
            inner.clock += 1;
            let clock = inner.clock;
            if let Some(entry) = inner.map.get_mut(&key) {
                // refresh in place.  A distance-only insert must neither
                // discard successors a paths solve already paid for NOR
                // overwrite their paired distances: different tiers can
                // produce bitwise-different (equally valid) closures, and a
                // (dist, succ) pair must stay internally consistent — so a
                // succ-less put against a succ-carrying entry only bumps LRU
                // (the surviving pair keeps its own chain depth; re-baselining
                // then happens at the pair's cadence, never against a mix).
                let changed = if succ.is_some() {
                    entry.graph = graph;
                    entry.dist = dist;
                    entry.succ = succ;
                    entry.chain = chain;
                    true
                } else if entry.succ.is_none() {
                    entry.graph = graph;
                    entry.dist = dist;
                    entry.chain = chain;
                    true
                } else {
                    false
                };
                let prev = entry.last_used;
                entry.last_used = clock;
                if changed && persist {
                    // persist what the cache now *holds* (the merged
                    // entry), not what the caller offered
                    to_persist =
                        Some((entry.graph.clone(), entry.dist.clone(), entry.succ.clone(), entry.chain));
                }
                inner.order.remove(&prev);
                inner.order.insert(clock, key.clone());
            } else {
                if inner.map.len() >= self.capacity {
                    // evict the least-recently-used entry: O(log capacity)
                    if let Some((_, victim)) = inner.order.pop_first() {
                        inner.map.remove(&victim);
                    }
                }
                if persist {
                    to_persist = Some((graph.clone(), dist.clone(), succ.clone(), chain));
                }
                inner.map.insert(
                    key.clone(),
                    Entry {
                        graph,
                        dist,
                        succ,
                        chain,
                        last_used: clock,
                    },
                );
                inner.order.insert(clock, key.clone());
            }
        }
        if let Some((graph, dist, succ, chain)) = to_persist {
            self.enqueue_persist(key, graph, dist, succ, chain);
        }
    }

    /// Hand the entry to the writer pool.  `QueueFull` drops the write
    /// with a debug line: persistence is write-behind and best-effort —
    /// shedding a disk write under burst must never block or fail the
    /// request that produced the closure.
    fn enqueue_persist(
        &self,
        key: Key,
        graph: Arc<DistMatrix>,
        dist: Arc<DistMatrix>,
        succ: Option<Arc<Vec<usize>>>,
        chain: u32,
    ) {
        let (Some(store), Some(writer)) = (&self.store, &self.writer) else {
            return;
        };
        let store = Arc::clone(store);
        let fingerprint = key.fingerprint;
        let submitted = writer.try_submit(move || {
            let succ = succ.as_ref().map(|s| s.as_slice());
            if let Err(e) = store.put(&key.variant, key.fingerprint, &graph, &dist, succ, chain) {
                log(
                    Level::Warn,
                    "store_write_error",
                    vec![
                        ("fingerprint", Json::str(format!("{:016x}", key.fingerprint))),
                        ("error", Json::str(e.to_string())),
                    ],
                );
            }
        });
        if submitted.is_err() {
            log(
                Level::Debug,
                "store_write_dropped",
                vec![("fingerprint", Json::str(format!("{fingerprint:016x}")))],
            );
        }
    }

    pub fn len(&self) -> usize {
        crate::recover_lock!(&self.inner, "cache.inner").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses) since construction — memory-cache traffic only (the
    /// store keeps its own `store_*` counters in the metrics).
    pub fn stats(&self) -> (u64, u64) {
        let inner = crate::recover_lock!(&self.inner, "cache.inner");
        (inner.hits, inner.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::super::metrics::Metrics;
    use super::super::store::StoreConfig;
    use super::*;
    use crate::graph::generators;
    use crate::util::pool::PoolConfig;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn hit_after_put() {
        let cache = ResultCache::new(4);
        let g = generators::ring(8);
        let d = crate::apsp::naive::solve(&g);
        assert!(cache.get("staged", &g).is_none());
        cache.put("staged", &g, d.clone());
        assert_eq!(cache.get("staged", &g), Some(d));
        assert_eq!(cache.stats(), (1, 1));
    }

    #[test]
    fn variant_is_part_of_key() {
        let cache = ResultCache::new(4);
        let g = generators::ring(8);
        cache.put("staged", &g, crate::apsp::naive::solve(&g));
        assert!(cache.get("blocked", &g).is_none());
    }

    #[test]
    fn lru_evicts_oldest() {
        let cache = ResultCache::new(2);
        let g1 = generators::ring(4);
        let g2 = generators::ring(5);
        let g3 = generators::ring(6);
        cache.put("v", &g1, g1.clone());
        cache.put("v", &g2, g2.clone());
        assert!(cache.get("v", &g1).is_some()); // touch g1: g2 is now LRU
        cache.put("v", &g3, g3.clone()); // evicts g2
        assert!(cache.get("v", &g2).is_none());
        assert!(cache.get("v", &g1).is_some());
        assert!(cache.get("v", &g3).is_some());
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn insert_storm_at_capacity_keeps_exactly_the_newest() {
        // the O(capacity)-scan eviction this replaced was quadratic under
        // exactly this load: capacity-1024 cache, thousands of distinct
        // inserts.  Pin the LRU discipline at that scale — only the
        // newest `capacity` keys survive, in insertion order.
        let capacity = 1024;
        let total = 4096;
        let cache = ResultCache::new(capacity);
        let g = generators::ring(4);
        for i in 0..total {
            cache.put(&format!("v{i}"), &g, g.clone());
        }
        assert_eq!(cache.len(), capacity);
        for i in 0..total - capacity {
            assert!(cache.get(&format!("v{i}"), &g).is_none(), "v{i} should be evicted");
        }
        for i in total - capacity..total {
            assert!(cache.get(&format!("v{i}"), &g).is_some(), "v{i} should survive");
        }
    }

    #[test]
    fn hits_share_one_allocation_no_matrix_copy_under_the_lock() {
        // the hot-path contract: a hit hands out the *same* Arc, proving
        // the payload is snapshotted by pointer under the lock and any
        // deep copy happens outside it (get_for clones after release)
        let cache = ResultCache::new(4);
        let g = generators::erdos_renyi(64, 0.3, 7);
        cache.put("staged", &g, crate::apsp::naive::solve(&g));
        let a = cache
            .lookup_for(Objective::Shortest, "staged", &g)
            .expect("hit")
            .into_inner();
        let b = cache
            .lookup_for(Objective::Shortest, "staged", &g)
            .expect("hit")
            .into_inner();
        assert!(Arc::ptr_eq(&a, &b), "repeated hits must alias one allocation");
        // paths pairs too
        let r = crate::apsp::paths::solve(&g);
        cache.put_paths("staged", &g, r.dist.clone(), r.succ().to_vec());
        let (d1, s1) = cache
            .lookup_paths_for(Objective::Shortest, "staged", &g)
            .expect("paths hit")
            .into_inner();
        let (d2, s2) = cache
            .lookup_paths_for(Objective::Shortest, "staged", &g)
            .expect("paths hit")
            .into_inner();
        assert!(Arc::ptr_eq(&d1, &d2) && Arc::ptr_eq(&s1, &s2));
        // and the base snapshot shares the same allocations as lookups
        let base = cache.get_base("staged", g.n(), graph_fingerprint(&g)).unwrap();
        assert!(Arc::ptr_eq(&base.dist, &d1));
    }

    #[test]
    fn concurrent_lookups_and_inserts_share_payloads_without_tearing() {
        // lookups running against concurrent large inserts: every hit
        // must be a whole (untorn) closure, and hits between inserts
        // alias rather than copy.  This is the concurrency half of the
        // "no clones under the lock" fix — structural, not timing-based.
        let cache = ResultCache::new(8);
        let graphs: Vec<_> = (0..4).map(|i| generators::erdos_renyi(48, 0.4, i)).collect();
        let solved: Vec<_> = graphs.iter().map(crate::apsp::naive::solve).collect();
        std::thread::scope(|scope| {
            for t in 0..3 {
                let (cache, graphs, solved) = (&cache, &graphs, &solved);
                scope.spawn(move || {
                    for round in 0..50 {
                        let gi = (t + round) % graphs.len();
                        cache.put("v", &graphs[gi], solved[gi].clone());
                    }
                });
            }
            for _ in 0..3 {
                let (cache, graphs, solved) = (&cache, &graphs, &solved);
                scope.spawn(move || {
                    for round in 0..200 {
                        let gi = round % graphs.len();
                        if let Some(hit) = cache.lookup_for(Objective::Shortest, "v", &graphs[gi]) {
                            let dist = hit.into_inner();
                            assert_eq!(*dist, solved[gi], "torn or foreign closure served");
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn a_poisoning_panic_leaves_the_cache_serviceable() {
        // one panic while holding the lock must not turn every later
        // request into a panic: the guard recovers and state survives
        let cache = ResultCache::new(4);
        let g = generators::ring(6);
        let d = crate::apsp::naive::solve(&g);
        cache.put("staged", &g, d.clone());
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let _guard = cache.inner.lock().unwrap();
            panic!("poisoning the cache lock (expected by this test)");
        }));
        assert!(caught.is_err());
        assert!(cache.inner.is_poisoned());
        assert_eq!(cache.get("staged", &g), Some(d), "hit after poison");
        let g2 = generators::ring(7);
        cache.put("staged", &g2, crate::apsp::naive::solve(&g2));
        assert!(cache.get("staged", &g2).is_some(), "insert after poison");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn distance_entry_cannot_serve_paths() {
        let cache = ResultCache::new(4);
        let g = generators::ring(6);
        cache.put("staged", &g, crate::apsp::naive::solve(&g));
        assert!(cache.get_paths("staged", &g).is_none());
        // ...but the distance half still hits
        assert!(cache.get("staged", &g).is_some());
    }

    #[test]
    fn paths_entry_serves_both_and_survives_distance_put() {
        let cache = ResultCache::new(4);
        let g = generators::ring(6);
        let r = crate::apsp::paths::solve(&g);
        cache.put_paths("staged", &g, r.dist.clone(), r.succ().to_vec());
        let (dist, succ) = cache.get_paths("staged", &g).expect("paths hit");
        assert_eq!(dist, r.dist);
        assert_eq!(succ, r.succ());
        assert_eq!(cache.get("staged", &g), Some(r.dist.clone()));
        // a later distance-only put must not discard the successors — nor
        // replace their paired distances with a different (equally valid)
        // closure, which would make the stored (dist, succ) inconsistent
        let mut other_dist = r.dist.clone();
        other_dist.set(0, 1, other_dist.get(0, 1) + 1e-4);
        cache.put("staged", &g, other_dist);
        let (dist2, succ2) = cache.get_paths("staged", &g).expect("pair intact");
        assert_eq!(dist2, r.dist, "distance-only put must not split the pair");
        assert_eq!(succ2, r.succ());
        assert_eq!(cache.len(), 1, "same fingerprint key, one entry");
    }

    #[test]
    fn get_base_roundtrips_graph_closure_and_chain() {
        let cache = ResultCache::new(4);
        let g = generators::ring(6);
        let r = crate::apsp::paths::solve(&g);
        cache.put_paths("staged", &g, r.dist.clone(), r.succ().to_vec());
        let fp = graph_fingerprint(&g);
        let base = cache.get_base("staged", g.n(), fp).expect("base hit");
        assert_eq!(*base.graph, g);
        assert_eq!(*base.dist, r.dist);
        assert_eq!(base.succ.as_ref().map(|s| s.as_slice()), Some(r.succ()));
        assert_eq!(base.chain, 0);
        // unknown fingerprint misses; n is part of the key
        assert!(cache.get_base("staged", g.n(), fp ^ 1).is_none());
        assert!(cache.get_base("staged", g.n() + 1, fp).is_none());
        // chained put records depth under the mutated graph's own key
        let mut g2 = g.clone();
        g2.set(0, 3, 1.5);
        let r2 = crate::apsp::paths::solve(&g2);
        cache.put_chained("staged", &g2, r2.dist.clone(), Some(r2.succ().to_vec()), 3);
        let b2 = cache
            .get_base("staged", g2.n(), graph_fingerprint(&g2))
            .expect("chained hit");
        assert_eq!(b2.chain, 3);
        assert_eq!(*b2.graph, g2);
        // ...and the ordinary lookups see the chained closure too
        assert_eq!(cache.get("staged", &g2), Some(r2.dist.clone()));
        let (d, s) = cache.get_paths("staged", &g2).expect("paths hit");
        assert_eq!(d, r2.dist);
        assert_eq!(s, r2.succ());
    }

    #[test]
    fn chained_dist_only_put_never_splits_a_pair() {
        let cache = ResultCache::new(4);
        let g = generators::ring(5);
        let r = crate::apsp::paths::solve(&g);
        cache.put_paths("v", &g, r.dist.clone(), r.succ().to_vec());
        // dist-only chained put against the succ-carrying entry: the pair
        // survives intact, chain depth included
        let mut other = r.dist.clone();
        other.set(0, 1, other.get(0, 1) + 1e-3);
        cache.put_chained("v", &g, other, None, 5);
        let base = cache.get_base("v", g.n(), graph_fingerprint(&g)).unwrap();
        assert_eq!(*base.dist, r.dist);
        assert_eq!(base.succ.as_ref().map(|s| s.as_slice()), Some(r.succ()));
        assert_eq!(base.chain, 0, "surviving pair keeps its own chain depth");
    }

    #[test]
    fn zero_capacity_disables() {
        let cache = ResultCache::new(0);
        let g = generators::ring(4);
        cache.put("v", &g, g.clone());
        assert!(cache.get("v", &g).is_none());
        assert!(cache.is_empty());
    }

    // ------------------------------------------------- backing store --

    /// Unique per-test scratch dir, removed on drop.
    struct TempDir(PathBuf);

    impl TempDir {
        fn new(tag: &str) -> TempDir {
            static SEQ: AtomicUsize = AtomicUsize::new(0);
            let path = std::env::temp_dir().join(format!(
                "fw-cache-unit-{tag}-{}-{}",
                std::process::id(),
                SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            let _ = std::fs::remove_dir_all(&path);
            TempDir(path)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    fn stored_cache(dir: &TempDir, capacity: usize) -> (ResultCache, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let store = Arc::new(
            Store::open(StoreConfig { dir: dir.0.clone(), max_bytes: 0 }, metrics.clone())
                .expect("store opens"),
        );
        let writer = JobPool::new(PoolConfig {
            workers: 1,
            queue_depth: 64,
            name: "test-store-writer".into(),
        });
        (ResultCache::with_store(capacity, store, writer), metrics)
    }

    #[test]
    fn write_through_then_read_through_after_memory_eviction() {
        let dir = TempDir::new("readthrough");
        let (cache, _metrics) = stored_cache(&dir, 1);
        let g1 = generators::ring(6);
        let g2 = generators::ring(7);
        let d1 = crate::apsp::naive::solve(&g1);
        let d2 = crate::apsp::naive::solve(&g2);
        cache.put("staged", &g1, d1.clone());
        cache.put("staged", &g2, d2.clone()); // evicts g1 from memory
        cache.flush_store();
        let hit = cache
            .lookup_for(Objective::Shortest, "staged", &g1)
            .expect("disk read-through");
        assert!(hit.from_disk(), "evicted entry must come back from the store");
        let dist = hit.into_inner();
        for (a, b) in dist.as_slice().iter().zip(d1.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "disk round-trip must be bitwise");
        }
        // the read-through re-inserted it: next hit is memory (g2 evicted)
        assert!(matches!(
            cache.lookup_for(Objective::Shortest, "staged", &g1),
            Some(CacheHit::Memory(_))
        ));
    }

    #[test]
    fn restart_warm_start_round_trips_pairs_bitwise() {
        let dir = TempDir::new("warmstart");
        let g = generators::ring(9);
        let r = crate::apsp::paths::solve(&g);
        {
            let (cache, _metrics) = stored_cache(&dir, 4);
            cache.put_paths("staged", &g, r.dist.clone(), r.succ().to_vec());
            cache.flush_store();
        } // "process death": cache dropped, store directory survives
        let (cache, metrics) = stored_cache(&dir, 4);
        assert_eq!(cache.warm_from_store(), 1);
        let (dist, succ) = cache.get_paths("staged", &g).expect("warm-started pair");
        for (a, b) in dist.as_slice().iter().zip(r.dist.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(succ, r.succ());
        // the warm hit was served from memory, and warm loads counted as
        // store hits
        assert_eq!(cache.stats().0, 1);
        assert!(metrics.snapshot().get("store_hits").as_usize().unwrap() >= 1);
    }

    #[test]
    fn chained_entries_rebaseline_to_disk() {
        let dir = TempDir::new("chain");
        let g = generators::ring(8);
        let r = crate::apsp::paths::solve(&g);
        let fp = graph_fingerprint(&g);
        {
            let (cache, _metrics) = stored_cache(&dir, 4);
            cache.put_chained("staged", &g, r.dist.clone(), Some(r.succ().to_vec()), 5);
            cache.flush_store();
        }
        let (cache, _metrics) = stored_cache(&dir, 4);
        let base = cache.get_base("staged", g.n(), fp).expect("chained base from disk");
        assert_eq!(base.chain, 5, "chain depth survives the restart");
        assert_eq!(*base.dist, r.dist);
        assert_eq!(base.succ.as_ref().map(|s| s.as_slice()), Some(r.succ()));
    }

    #[test]
    fn lru_only_bump_does_not_rewrite_disk() {
        // a succ-less put against a succ-carrying entry changes nothing
        // (merge semantics) — so nothing should be re-persisted
        let dir = TempDir::new("nobump");
        let (cache, metrics) = stored_cache(&dir, 4);
        let g = generators::ring(6);
        let r = crate::apsp::paths::solve(&g);
        cache.put_paths("staged", &g, r.dist.clone(), r.succ().to_vec());
        cache.flush_store();
        assert_eq!(metrics.snapshot().get("store_writes").as_usize(), Some(1));
        let mut other = r.dist.clone();
        other.set(0, 1, other.get(0, 1) + 1e-3);
        cache.put("staged", &g, other); // LRU bump only
        cache.flush_store();
        assert_eq!(
            metrics.snapshot().get("store_writes").as_usize(),
            Some(1),
            "an unchanged entry must not be rewritten"
        );
    }

    #[test]
    fn fingerprint_values_pinned() {
        // The chunked fold is part of the cache-key contract: changing it
        // silently invalidates every cached closure.  Values computed
        // independently (f32 bit patterns folded 8 bytes per multiply).
        assert_eq!(
            graph_fingerprint(&DistMatrix::unconnected(2)),
            0x4820_083e_b15f_2d0d
        );
        // odd element count exercises the trailing-word fold
        let g = DistMatrix::from_vec(
            3,
            vec![0.0, 1.5, 2.25, crate::INF, 0.0, -1.0, 0.5, crate::INF, 0.0],
        );
        assert_eq!(graph_fingerprint(&g), 0xc0ce_0e24_0b9f_3776);
        // single-element matrix is tail-only
        assert_eq!(
            graph_fingerprint(&DistMatrix::unconnected(1)),
            0x082f_2207_b4e8_8cc4
        );
    }

    #[test]
    fn objective_fingerprint_values_pinned() {
        // The objective mixing is part of the cache-key contract too.
        // Shortest is the identity — pre-semiring fingerprints (including
        // every wire `base` field) stay valid verbatim.
        let g = DistMatrix::unconnected(2);
        assert_eq!(
            objective_fingerprint(&g, Objective::Shortest),
            graph_fingerprint(&g)
        );
        // Values computed independently: (h ^ tag) * PRIME mod 2^64.
        assert_eq!(
            objective_fingerprint(&g, Objective::Bottleneck),
            0xed9b_0e87_64b9_8b64
        );
        assert_eq!(
            objective_fingerprint(&g, Objective::Minimax),
            0xed9b_1187_64b9_907d
        );
        assert_eq!(
            objective_fingerprint(&g, Objective::Reachability),
            0xed9b_1087_64b9_8eca
        );
    }

    #[test]
    fn objective_fingerprints_all_distinct() {
        for g in [DistMatrix::unconnected(2), generators::erdos_renyi(16, 0.5, 1)] {
            let fps: Vec<u64> = Objective::ALL
                .iter()
                .map(|&o| objective_fingerprint(&g, o))
                .collect();
            for i in 0..fps.len() {
                for j in i + 1..fps.len() {
                    assert_ne!(
                        fps[i], fps[j],
                        "{:?} vs {:?} collide on the same graph",
                        Objective::ALL[i],
                        Objective::ALL[j]
                    );
                }
            }
        }
    }

    #[test]
    fn objectives_never_share_cache_entries() {
        // a closure cached under one objective must not answer another:
        // the numbers would be algebra-correct for the wrong question
        let cache = ResultCache::new(8);
        let g = generators::ring(6);
        let shortest = crate::apsp::naive::solve(&g);
        cache.put("staged", &g, shortest.clone());
        for o in [Objective::Bottleneck, Objective::Minimax, Objective::Reachability] {
            assert!(cache.get_for(o, "staged", &g).is_none(), "{o:?} hit shortest entry");
        }
        // and the reverse: a bottleneck entry is invisible to shortest
        let widest = crate::apsp::naive::solve_semiring::<crate::apsp::semiring::MaxMin>(
            &Objective::Bottleneck.prepare(&g).unwrap(),
        );
        cache.put_for(Objective::Bottleneck, "staged", &g, widest.clone());
        assert_eq!(cache.get("staged", &g), Some(shortest));
        assert_eq!(cache.get_for(Objective::Bottleneck, "staged", &g), Some(widest));
        assert_eq!(cache.len(), 2, "distinct keys, distinct entries");
    }

    #[test]
    fn paths_pair_cached_under_one_objective_stays_there() {
        let cache = ResultCache::new(8);
        let g = generators::ring(6);
        let prepared = Objective::Bottleneck.prepare(&g).unwrap();
        let r = crate::apsp::paths::solve_semiring::<crate::apsp::semiring::MaxMin>(&prepared);
        cache.put_paths_for(Objective::Bottleneck, "staged", &g, r.dist.clone(), r.succ().to_vec());
        // the pair serves its own objective...
        let (d, s) = cache
            .get_paths_for(Objective::Bottleneck, "staged", &g)
            .expect("bottleneck paths hit");
        assert_eq!(d, r.dist);
        assert_eq!(s, r.succ());
        // ...and no other — neither paths nor plain distance lookups
        assert!(cache.get_paths("staged", &g).is_none());
        assert!(cache.get("staged", &g).is_none());
        for o in [Objective::Minimax, Objective::Reachability] {
            assert!(cache.get_paths_for(o, "staged", &g).is_none());
        }
    }

    #[test]
    fn fingerprint_sensitive_to_order_within_chunk() {
        // both halves of the 8-byte chunk must contribute
        let a = DistMatrix::from_vec(2, vec![0.0, 1.0, 2.0, 0.0]);
        let b = DistMatrix::from_vec(2, vec![1.0, 0.0, 2.0, 0.0]);
        let c = DistMatrix::from_vec(2, vec![0.0, 1.0, 0.0, 2.0]);
        assert_ne!(graph_fingerprint(&a), graph_fingerprint(&b));
        assert_ne!(graph_fingerprint(&a), graph_fingerprint(&c));
    }

    #[test]
    fn fingerprint_sensitive_to_content() {
        let g1 = generators::erdos_renyi(16, 0.5, 1);
        let mut g2 = g1.clone();
        assert_eq!(graph_fingerprint(&g1), graph_fingerprint(&g2));
        g2.set(3, 4, 0.123);
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&g2));
    }

    #[test]
    fn fingerprint_sensitive_to_size() {
        assert_ne!(
            graph_fingerprint(&generators::ring(8)),
            graph_fingerprint(&generators::ring(9))
        );
    }
}

//! Result cache: LRU over (objective, variant, graph-content hash).
//!
//! APSP is expensive and deterministic — identical graphs recur in routing
//! workloads (topology changes are much rarer than queries).  Keyed by an
//! FNV-1a hash of the matrix bytes plus n and variant, with the serving
//! objective mixed into the hash ([`objective_fingerprint`]) so a closure
//! taken over one semiring can never answer a request for another;
//! collisions are guarded by storing the full key (n, variant, hash) and
//! verifying n.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::apsp::semiring::Objective;
use crate::graph::DistMatrix;

const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const PRIME: u64 = 0x0000_0100_0000_01B3;

/// FNV-1a-style hash over the matrix's raw f32 bits (stable across runs).
///
/// Folds **8 bytes (two f32 words) per multiply** instead of the textbook
/// byte-at-a-time FNV-1a: superblock-tier graphs are 16× bigger than the
/// largest device bucket, which put hashing on the request hot path — the
/// chunked fold does n²/2 multiplies instead of 4n², same avalanche-by-
/// prime construction.  An odd trailing word is folded on its own.  The
/// pinned-value tests below freeze the exact function.
pub fn graph_fingerprint(g: &DistMatrix) -> u64 {
    let mut h = OFFSET;
    h ^= g.n() as u64;
    h = h.wrapping_mul(PRIME);
    let mut chunks = g.as_slice().chunks_exact(2);
    for pair in chunks.by_ref() {
        let word = pair[0].to_bits() as u64 | ((pair[1].to_bits() as u64) << 32);
        h ^= word;
        h = h.wrapping_mul(PRIME);
    }
    if let [tail] = chunks.remainder() {
        h ^= tail.to_bits() as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// [`graph_fingerprint`] with the serving objective folded in: one extra
/// xor-multiply round over the objective tag.  `Objective::Shortest` is
/// the **identity** — tag 0 would xor nothing, so the round is skipped
/// outright — which keeps every pre-semiring fingerprint (pinned values,
/// `"update"` wire `base` fields, persisted client state) valid verbatim.
/// The pinned-value tests below freeze the mixing.
pub fn objective_fingerprint(g: &DistMatrix, objective: Objective) -> u64 {
    let h = graph_fingerprint(g);
    match objective.tag() {
        0 => h,
        tag => (h ^ tag).wrapping_mul(PRIME),
    }
}

/// The cache key every lookup and insert shares:
/// (variant, n, objective-mixed fingerprint).
fn make_key(objective: Objective, variant: &str, g: &DistMatrix) -> Key {
    Key {
        variant: variant.to_string(),
        n: g.n(),
        fingerprint: objective_fingerprint(g, objective),
    }
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct Key {
    variant: String,
    n: usize,
    fingerprint: u64,
}

struct Entry {
    /// The solved graph itself.  Kept so `"update"` requests can chain:
    /// an edge-delta batch needs the base weights to classify deltas and
    /// to fall back to a full solve (roughly triples the entry footprint;
    /// capacity bounds total memory as before).
    graph: DistMatrix,
    dist: DistMatrix,
    /// Successor matrix, present once a path-carrying solve has been
    /// cached for this key (same fingerprint — the key contract is shared
    /// with distance-only entries; paths *upgrade* an entry in place).
    succ: Option<Vec<usize>>,
    /// Incremental updates applied since the last from-scratch solve of
    /// this closure (0 = a baseline).  The coordinator re-baselines when a
    /// chain exceeds its cap.
    chain: u32,
    /// Monotone counter value at last touch (LRU eviction order).
    last_used: u64,
}

/// A cached base closure an `"update"` request chains from — an atomic
/// snapshot of one entry (graph, closure, chain depth), taken under the
/// cache lock so a concurrent put can never hand out a split pair.
pub struct CachedBase {
    pub graph: DistMatrix,
    pub dist: DistMatrix,
    pub succ: Option<Vec<usize>>,
    pub chain: u32,
}

/// A thread-safe LRU result cache.
pub struct ResultCache {
    inner: Mutex<Inner>,
    capacity: usize,
}

struct Inner {
    map: HashMap<Key, Entry>,
    clock: u64,
    hits: u64,
    misses: u64,
}

impl ResultCache {
    /// `capacity` = max cached results (0 disables caching).
    pub fn new(capacity: usize) -> Self {
        ResultCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                clock: 0,
                hits: 0,
                misses: 0,
            }),
            capacity,
        }
    }

    pub fn get(&self, variant: &str, g: &DistMatrix) -> Option<DistMatrix> {
        self.get_for(Objective::Shortest, variant, g)
    }

    /// [`ResultCache::get`] under an explicit serving objective.
    pub fn get_for(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
    ) -> Option<DistMatrix> {
        if self.capacity == 0 {
            return None;
        }
        let key = make_key(objective, variant, g);
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(&key) {
            Some(entry) => {
                entry.last_used = clock;
                let dist = entry.dist.clone();
                inner.hits += 1;
                Some(dist)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Closure + successor lookup: hits only entries a path-carrying solve
    /// has populated (a distance-only entry cannot serve a paths request).
    pub fn get_paths(&self, variant: &str, g: &DistMatrix) -> Option<(DistMatrix, Vec<usize>)> {
        self.get_paths_for(Objective::Shortest, variant, g)
    }

    /// [`ResultCache::get_paths`] under an explicit serving objective.
    pub fn get_paths_for(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
    ) -> Option<(DistMatrix, Vec<usize>)> {
        if self.capacity == 0 {
            return None;
        }
        let key = make_key(objective, variant, g);
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(&key) {
            Some(Entry { dist, succ: Some(succ), last_used }) => {
                *last_used = clock;
                let hit = (dist.clone(), succ.clone());
                inner.hits += 1;
                Some(hit)
            }
            _ => {
                inner.misses += 1;
                None
            }
        }
    }

    pub fn put(&self, variant: &str, g: &DistMatrix, dist: DistMatrix) {
        self.insert(Objective::Shortest, variant, g, dist, None, 0);
    }

    /// [`ResultCache::put`] under an explicit serving objective.
    pub fn put_for(&self, objective: Objective, variant: &str, g: &DistMatrix, dist: DistMatrix) {
        self.insert(objective, variant, g, dist, None, 0);
    }

    /// Cache a path-carrying solve: the distance closure plus the successor
    /// matrix, under the same fingerprint key distance entries use.
    pub fn put_paths(&self, variant: &str, g: &DistMatrix, dist: DistMatrix, succ: Vec<usize>) {
        self.insert(Objective::Shortest, variant, g, dist, Some(succ), 0);
    }

    /// [`ResultCache::put_paths`] under an explicit serving objective.
    pub fn put_paths_for(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
        dist: DistMatrix,
        succ: Vec<usize>,
    ) {
        self.insert(objective, variant, g, dist, Some(succ), 0);
    }

    /// Cache an incrementally updated closure for the *mutated* graph `g`,
    /// recording how many updates separate it from its baseline.  A chain
    /// of updates is itself cache-hittable: the coordinator keys each link
    /// by the mutated graph's fingerprint, so replaying the same deltas —
    /// or solving the mutated graph outright — hits this entry.
    /// Chained closures are shortest-only, like the dynamic tier itself.
    pub fn put_chained(
        &self,
        variant: &str,
        g: &DistMatrix,
        dist: DistMatrix,
        succ: Option<Vec<usize>>,
        chain: u32,
    ) {
        self.insert(Objective::Shortest, variant, g, dist, succ, chain);
    }

    /// Atomic base-closure lookup for an `"update"` request, addressed by
    /// fingerprint (the request carries no graph — that is the point).
    /// Misses when the closure was never solved here or has been evicted;
    /// the caller surfaces that as a typed error the client retries as a
    /// full solve.  Like every lookup, trusts the 64-bit fingerprint not
    /// to collide (the request-path `get` makes the same bet).
    pub fn get_base(&self, variant: &str, n: usize, fingerprint: u64) -> Option<CachedBase> {
        if self.capacity == 0 {
            return None;
        }
        let key = Key {
            variant: variant.to_string(),
            n,
            fingerprint,
        };
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(&key) {
            Some(entry) => {
                entry.last_used = clock;
                let base = CachedBase {
                    graph: entry.graph.clone(),
                    dist: entry.dist.clone(),
                    succ: entry.succ.clone(),
                    chain: entry.chain,
                };
                inner.hits += 1;
                Some(base)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    fn insert(
        &self,
        objective: Objective,
        variant: &str,
        g: &DistMatrix,
        dist: DistMatrix,
        succ: Option<Vec<usize>>,
        chain: u32,
    ) {
        if self.capacity == 0 {
            return;
        }
        let key = make_key(objective, variant, g);
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        if let Some(entry) = inner.map.get_mut(&key) {
            // refresh in place.  A distance-only insert must neither
            // discard successors a paths solve already paid for NOR
            // overwrite their paired distances: different tiers can
            // produce bitwise-different (equally valid) closures, and a
            // (dist, succ) pair must stay internally consistent — so a
            // succ-less put against a succ-carrying entry only bumps LRU
            // (the surviving pair keeps its own chain depth; re-baselining
            // then happens at the pair's cadence, never against a mix).
            if succ.is_some() {
                entry.graph = g.clone();
                entry.dist = dist;
                entry.succ = succ;
                entry.chain = chain;
            } else if entry.succ.is_none() {
                entry.graph = g.clone();
                entry.dist = dist;
                entry.chain = chain;
            }
            entry.last_used = clock;
            return;
        }
        if inner.map.len() >= self.capacity {
            // evict the least-recently-used entry
            if let Some(victim) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                inner.map.remove(&victim);
            }
        }
        inner.map.insert(
            key,
            Entry {
                graph: g.clone(),
                dist,
                succ,
                chain,
                last_used: clock,
            },
        );
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.hits, inner.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn hit_after_put() {
        let cache = ResultCache::new(4);
        let g = generators::ring(8);
        let d = crate::apsp::naive::solve(&g);
        assert!(cache.get("staged", &g).is_none());
        cache.put("staged", &g, d.clone());
        assert_eq!(cache.get("staged", &g), Some(d));
        assert_eq!(cache.stats(), (1, 1));
    }

    #[test]
    fn variant_is_part_of_key() {
        let cache = ResultCache::new(4);
        let g = generators::ring(8);
        cache.put("staged", &g, crate::apsp::naive::solve(&g));
        assert!(cache.get("blocked", &g).is_none());
    }

    #[test]
    fn lru_evicts_oldest() {
        let cache = ResultCache::new(2);
        let g1 = generators::ring(4);
        let g2 = generators::ring(5);
        let g3 = generators::ring(6);
        cache.put("v", &g1, g1.clone());
        cache.put("v", &g2, g2.clone());
        assert!(cache.get("v", &g1).is_some()); // touch g1: g2 is now LRU
        cache.put("v", &g3, g3.clone()); // evicts g2
        assert!(cache.get("v", &g2).is_none());
        assert!(cache.get("v", &g1).is_some());
        assert!(cache.get("v", &g3).is_some());
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn distance_entry_cannot_serve_paths() {
        let cache = ResultCache::new(4);
        let g = generators::ring(6);
        cache.put("staged", &g, crate::apsp::naive::solve(&g));
        assert!(cache.get_paths("staged", &g).is_none());
        // ...but the distance half still hits
        assert!(cache.get("staged", &g).is_some());
    }

    #[test]
    fn paths_entry_serves_both_and_survives_distance_put() {
        let cache = ResultCache::new(4);
        let g = generators::ring(6);
        let r = crate::apsp::paths::solve(&g);
        cache.put_paths("staged", &g, r.dist.clone(), r.succ().to_vec());
        let (dist, succ) = cache.get_paths("staged", &g).expect("paths hit");
        assert_eq!(dist, r.dist);
        assert_eq!(succ, r.succ());
        assert_eq!(cache.get("staged", &g), Some(r.dist.clone()));
        // a later distance-only put must not discard the successors — nor
        // replace their paired distances with a different (equally valid)
        // closure, which would make the stored (dist, succ) inconsistent
        let mut other_dist = r.dist.clone();
        other_dist.set(0, 1, other_dist.get(0, 1) + 1e-4);
        cache.put("staged", &g, other_dist);
        let (dist2, succ2) = cache.get_paths("staged", &g).expect("pair intact");
        assert_eq!(dist2, r.dist, "distance-only put must not split the pair");
        assert_eq!(succ2, r.succ());
        assert_eq!(cache.len(), 1, "same fingerprint key, one entry");
    }

    #[test]
    fn get_base_roundtrips_graph_closure_and_chain() {
        let cache = ResultCache::new(4);
        let g = generators::ring(6);
        let r = crate::apsp::paths::solve(&g);
        cache.put_paths("staged", &g, r.dist.clone(), r.succ().to_vec());
        let fp = graph_fingerprint(&g);
        let base = cache.get_base("staged", g.n(), fp).expect("base hit");
        assert_eq!(base.graph, g);
        assert_eq!(base.dist, r.dist);
        assert_eq!(base.succ.as_deref(), Some(r.succ()));
        assert_eq!(base.chain, 0);
        // unknown fingerprint misses; n is part of the key
        assert!(cache.get_base("staged", g.n(), fp ^ 1).is_none());
        assert!(cache.get_base("staged", g.n() + 1, fp).is_none());
        // chained put records depth under the mutated graph's own key
        let mut g2 = g.clone();
        g2.set(0, 3, 1.5);
        let r2 = crate::apsp::paths::solve(&g2);
        cache.put_chained("staged", &g2, r2.dist.clone(), Some(r2.succ().to_vec()), 3);
        let b2 = cache
            .get_base("staged", g2.n(), graph_fingerprint(&g2))
            .expect("chained hit");
        assert_eq!(b2.chain, 3);
        assert_eq!(b2.graph, g2);
        // ...and the ordinary lookups see the chained closure too
        assert_eq!(cache.get("staged", &g2), Some(r2.dist.clone()));
        let (d, s) = cache.get_paths("staged", &g2).expect("paths hit");
        assert_eq!(d, r2.dist);
        assert_eq!(s, r2.succ());
    }

    #[test]
    fn chained_dist_only_put_never_splits_a_pair() {
        let cache = ResultCache::new(4);
        let g = generators::ring(5);
        let r = crate::apsp::paths::solve(&g);
        cache.put_paths("v", &g, r.dist.clone(), r.succ().to_vec());
        // dist-only chained put against the succ-carrying entry: the pair
        // survives intact, chain depth included
        let mut other = r.dist.clone();
        other.set(0, 1, other.get(0, 1) + 1e-3);
        cache.put_chained("v", &g, other, None, 5);
        let base = cache.get_base("v", g.n(), graph_fingerprint(&g)).unwrap();
        assert_eq!(base.dist, r.dist);
        assert_eq!(base.succ.as_deref(), Some(r.succ()));
        assert_eq!(base.chain, 0, "surviving pair keeps its own chain depth");
    }

    #[test]
    fn zero_capacity_disables() {
        let cache = ResultCache::new(0);
        let g = generators::ring(4);
        cache.put("v", &g, g.clone());
        assert!(cache.get("v", &g).is_none());
        assert!(cache.is_empty());
    }

    #[test]
    fn fingerprint_values_pinned() {
        // The chunked fold is part of the cache-key contract: changing it
        // silently invalidates every cached closure.  Values computed
        // independently (f32 bit patterns folded 8 bytes per multiply).
        assert_eq!(
            graph_fingerprint(&DistMatrix::unconnected(2)),
            0x4820_083e_b15f_2d0d
        );
        // odd element count exercises the trailing-word fold
        let g = DistMatrix::from_vec(
            3,
            vec![0.0, 1.5, 2.25, crate::INF, 0.0, -1.0, 0.5, crate::INF, 0.0],
        );
        assert_eq!(graph_fingerprint(&g), 0xc0ce_0e24_0b9f_3776);
        // single-element matrix is tail-only
        assert_eq!(
            graph_fingerprint(&DistMatrix::unconnected(1)),
            0x082f_2207_b4e8_8cc4
        );
    }

    #[test]
    fn objective_fingerprint_values_pinned() {
        // The objective mixing is part of the cache-key contract too.
        // Shortest is the identity — pre-semiring fingerprints (including
        // every wire `base` field) stay valid verbatim.
        let g = DistMatrix::unconnected(2);
        assert_eq!(
            objective_fingerprint(&g, Objective::Shortest),
            graph_fingerprint(&g)
        );
        // Values computed independently: (h ^ tag) * PRIME mod 2^64.
        assert_eq!(
            objective_fingerprint(&g, Objective::Bottleneck),
            0xed9b_0e87_64b9_8b64
        );
        assert_eq!(
            objective_fingerprint(&g, Objective::Minimax),
            0xed9b_1187_64b9_907d
        );
        assert_eq!(
            objective_fingerprint(&g, Objective::Reachability),
            0xed9b_1087_64b9_8eca
        );
    }

    #[test]
    fn objective_fingerprints_all_distinct() {
        for g in [DistMatrix::unconnected(2), generators::erdos_renyi(16, 0.5, 1)] {
            let fps: Vec<u64> = Objective::ALL
                .iter()
                .map(|&o| objective_fingerprint(&g, o))
                .collect();
            for i in 0..fps.len() {
                for j in i + 1..fps.len() {
                    assert_ne!(
                        fps[i], fps[j],
                        "{:?} vs {:?} collide on the same graph",
                        Objective::ALL[i],
                        Objective::ALL[j]
                    );
                }
            }
        }
    }

    #[test]
    fn objectives_never_share_cache_entries() {
        // a closure cached under one objective must not answer another:
        // the numbers would be algebra-correct for the wrong question
        let cache = ResultCache::new(8);
        let g = generators::ring(6);
        let shortest = crate::apsp::naive::solve(&g);
        cache.put("staged", &g, shortest.clone());
        for o in [Objective::Bottleneck, Objective::Minimax, Objective::Reachability] {
            assert!(cache.get_for(o, "staged", &g).is_none(), "{o:?} hit shortest entry");
        }
        // and the reverse: a bottleneck entry is invisible to shortest
        let widest = crate::apsp::naive::solve_semiring::<crate::apsp::semiring::MaxMin>(
            &Objective::Bottleneck.prepare(&g).unwrap(),
        );
        cache.put_for(Objective::Bottleneck, "staged", &g, widest.clone());
        assert_eq!(cache.get("staged", &g), Some(shortest));
        assert_eq!(cache.get_for(Objective::Bottleneck, "staged", &g), Some(widest));
        assert_eq!(cache.len(), 2, "distinct keys, distinct entries");
    }

    #[test]
    fn paths_pair_cached_under_one_objective_stays_there() {
        let cache = ResultCache::new(8);
        let g = generators::ring(6);
        let prepared = Objective::Bottleneck.prepare(&g).unwrap();
        let r = crate::apsp::paths::solve_semiring::<crate::apsp::semiring::MaxMin>(&prepared);
        cache.put_paths_for(Objective::Bottleneck, "staged", &g, r.dist.clone(), r.succ().to_vec());
        // the pair serves its own objective...
        let (d, s) = cache
            .get_paths_for(Objective::Bottleneck, "staged", &g)
            .expect("bottleneck paths hit");
        assert_eq!(d, r.dist);
        assert_eq!(s, r.succ());
        // ...and no other — neither paths nor plain distance lookups
        assert!(cache.get_paths("staged", &g).is_none());
        assert!(cache.get("staged", &g).is_none());
        for o in [Objective::Minimax, Objective::Reachability] {
            assert!(cache.get_paths_for(o, "staged", &g).is_none());
        }
    }

    #[test]
    fn fingerprint_sensitive_to_order_within_chunk() {
        // both halves of the 8-byte chunk must contribute
        let a = DistMatrix::from_vec(2, vec![0.0, 1.0, 2.0, 0.0]);
        let b = DistMatrix::from_vec(2, vec![1.0, 0.0, 2.0, 0.0]);
        let c = DistMatrix::from_vec(2, vec![0.0, 1.0, 0.0, 2.0]);
        assert_ne!(graph_fingerprint(&a), graph_fingerprint(&b));
        assert_ne!(graph_fingerprint(&a), graph_fingerprint(&c));
    }

    #[test]
    fn fingerprint_sensitive_to_content() {
        let g1 = generators::erdos_renyi(16, 0.5, 1);
        let mut g2 = g1.clone();
        assert_eq!(graph_fingerprint(&g1), graph_fingerprint(&g2));
        g2.set(3, 4, 0.123);
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&g2));
    }

    #[test]
    fn fingerprint_sensitive_to_size() {
        assert_ne!(
            graph_fingerprint(&generators::ring(8)),
            graph_fingerprint(&generators::ring(9))
        );
    }
}

//! Block-diagonal batching policy.
//!
//! APSP has a clean batching identity: graphs placed on the block diagonal
//! of a larger matrix (cross-blocks = +inf) do not interact — the solved
//! matrix contains each graph's independent APSP in its own block.
//!
//! **Cost model.** A device call on bucket `b` costs Θ(b³) compute plus a
//! fixed dispatch overhead.  Packing k items into a *larger* bucket is
//! therefore almost never a win (8 × n=60 packed into 512 does 64× the
//! arithmetic of 8 separate 64-bucket calls — measured as a 1000× loss in
//! `benches/coordinator.rs` before this policy existed).  Packing *is* a
//! win when several items share a natural bucket and fit in it together:
//! two n≤32 graphs in one 64-bucket call halve both dispatch overhead and
//! total arithmetic versus two calls.
//!
//! The planner therefore groups items by natural bucket (smallest lowered
//! size ≥ n) and first-fit packs within each group, never escalating to a
//! larger bucket.  This module is pure policy (no device, no threads) so
//! it is exhaustively testable; the engine applies its plans.

/// One queued item, identified by an opaque ticket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Item {
    pub ticket: u64,
    /// Vertex count of the item's graph.
    pub n: usize,
}

/// Where an item landed inside a packed batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub ticket: u64,
    /// Row/col offset of the item's block on the batch diagonal.
    pub offset: usize,
    pub n: usize,
}

/// One device call: a bucket size and the items packed into it.
/// `bucket == 0` marks items too large for any bucket (engine → error).
#[derive(Clone, Debug)]
pub struct Batch {
    pub bucket: usize,
    pub placements: Vec<Placement>,
}

impl Batch {
    /// Total vertices used of the bucket (fill factor numerator).
    pub fn used(&self) -> usize {
        self.placements.iter().map(|p| p.n).sum()
    }
}

/// Packing policy configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Enable same-bucket packing (vs one call per item).
    pub pack: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { pack: true }
    }
}

/// Plan device calls for `items` given the lowered `buckets` (ascending).
pub fn plan(items: &[Item], buckets: &[usize], policy: &BatchPolicy) -> Vec<Batch> {
    assert!(!buckets.is_empty(), "no buckets available");
    let natural = |n: usize| buckets.iter().copied().find(|&b| b >= n);

    let mut batches: Vec<Batch> = Vec::new();
    // group by natural bucket, preserving arrival order within groups
    for &bucket in buckets {
        let group: Vec<Item> = items
            .iter()
            .copied()
            .filter(|it| natural(it.n) == Some(bucket))
            .collect();
        if group.is_empty() {
            continue;
        }
        if !policy.pack {
            for it in group {
                batches.push(Batch {
                    bucket,
                    placements: vec![Placement {
                        ticket: it.ticket,
                        offset: 0,
                        n: it.n,
                    }],
                });
            }
            continue;
        }
        // first-fit-decreasing within the same bucket size
        let mut sorted = group;
        sorted.sort_by(|a, b| b.n.cmp(&a.n).then(a.ticket.cmp(&b.ticket)));
        let mut bins: Vec<(usize, Vec<Placement>)> = Vec::new();
        for it in sorted {
            match bins.iter_mut().find(|(used, _)| used + it.n <= bucket) {
                Some((used, placements)) => {
                    placements.push(Placement {
                        ticket: it.ticket,
                        offset: *used,
                        n: it.n,
                    });
                    *used += it.n;
                }
                None => bins.push((
                    it.n,
                    vec![Placement {
                        ticket: it.ticket,
                        offset: 0,
                        n: it.n,
                    }],
                )),
            }
        }
        for (_, placements) in bins {
            batches.push(Batch { bucket, placements });
        }
    }
    // oversize items: no bucket fits
    for it in items {
        if natural(it.n).is_none() {
            batches.push(Batch {
                bucket: 0,
                placements: vec![Placement {
                    ticket: it.ticket,
                    offset: 0,
                    n: it.n,
                }],
            });
        }
    }
    batches
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUCKETS: [usize; 4] = [64, 128, 256, 512];

    fn items(ns: &[usize]) -> Vec<Item> {
        ns.iter()
            .enumerate()
            .map(|(i, &n)| Item {
                ticket: i as u64,
                n,
            })
            .collect()
    }

    fn policy() -> BatchPolicy {
        BatchPolicy::default()
    }

    #[test]
    fn packs_within_natural_bucket_only() {
        // two n=30 graphs fit together in one 64-bucket call
        let batches = plan(&items(&[30, 30]), &BUCKETS, &policy());
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].bucket, 64);
        assert_eq!(batches[0].placements.len(), 2);
        assert_eq!(batches[0].used(), 60);
    }

    #[test]
    fn never_escalates_to_larger_bucket() {
        // 8 × n=60: natural bucket 64, only one fits per bin ⇒ 8 calls at
        // 64, NOT one call at 512 (which would cost 64× the arithmetic)
        let batches = plan(&items(&[60; 8]), &BUCKETS, &policy());
        assert_eq!(batches.len(), 8);
        for b in &batches {
            assert_eq!(b.bucket, 64);
            assert_eq!(b.placements.len(), 1);
        }
    }

    #[test]
    fn groups_do_not_mix_buckets() {
        // 30+30 pack into one 64; 100 gets its own 128; 300 its own 512
        let batches = plan(&items(&[30, 100, 30, 300]), &BUCKETS, &policy());
        let mut buckets: Vec<usize> = batches.iter().map(|b| b.bucket).collect();
        buckets.sort();
        assert_eq!(buckets, vec![64, 128, 512]);
        let b64 = batches.iter().find(|b| b.bucket == 64).unwrap();
        assert_eq!(b64.placements.len(), 2);
    }

    #[test]
    fn placements_disjoint_and_in_bounds() {
        let batches = plan(&items(&[20, 20, 20, 10, 30, 64]), &BUCKETS, &policy());
        for b in &batches {
            let mut spans: Vec<(usize, usize)> =
                b.placements.iter().map(|p| (p.offset, p.offset + p.n)).collect();
            spans.sort();
            for pair in spans.windows(2) {
                assert!(pair[0].1 <= pair[1].0, "overlap: {spans:?}");
            }
            assert!(spans.last().unwrap().1 <= b.bucket);
        }
    }

    #[test]
    fn oversize_marked_with_bucket_zero() {
        let batches = plan(&items(&[9999]), &BUCKETS, &policy());
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].bucket, 0);
    }

    #[test]
    fn no_pack_policy_gives_one_batch_per_item() {
        let p = BatchPolicy { pack: false };
        let batches = plan(&items(&[30, 30, 30]), &BUCKETS, &p);
        assert_eq!(batches.len(), 3);
        for b in &batches {
            assert_eq!(b.bucket, 64);
        }
    }

    #[test]
    fn every_ticket_appears_exactly_once() {
        let input = items(&[60, 60, 300, 100, 10, 10, 10, 500, 9999, 64, 65]);
        let batches = plan(&input, &BUCKETS, &policy());
        let mut tickets: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.placements.iter().map(|p| p.ticket))
            .collect();
        tickets.sort();
        assert_eq!(tickets, (0..input.len() as u64).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input_empty_plan() {
        assert!(plan(&[], &BUCKETS, &policy()).is_empty());
    }

    #[test]
    fn exact_bucket_fit() {
        // n == bucket exactly: its own call, offset 0
        let batches = plan(&items(&[64, 128]), &BUCKETS, &policy());
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().any(|b| b.bucket == 64));
        assert!(batches.iter().any(|b| b.bucket == 128));
    }

    #[test]
    fn many_tiny_items_fill_bins() {
        // 10 × n=16: four fit per 64-bucket (4×16=64) ⇒ 3 bins (4+4+2)
        let batches = plan(&items(&[16; 10]), &BUCKETS, &policy());
        assert_eq!(batches.len(), 3);
        let mut sizes: Vec<usize> = batches.iter().map(|b| b.placements.len()).collect();
        sizes.sort();
        assert_eq!(sizes, vec![2, 4, 4]);
    }
}

//! Request routing policy: where should a solve run?
//!
//! * tiny graphs (n ≤ `cpu_threshold`) run on the calling thread's CPU
//!   solver — padding a 16-vertex graph to a 64³-work device bucket costs
//!   more than solving it in-place (the same big/small split a GPU serving
//!   stack makes);
//! * the explicit "cpu" variant always routes to the CPU solver;
//! * graphs larger than every artifact bucket go to the super-block tier
//!   ([`crate::superblock`]), which runs the paper's three-phase schedule
//!   over device-bucket tiles (also reachable explicitly as the
//!   "superblock" variant);
//! * everything else goes to the device engine.
//!
//! Variants and buckets are **derived from the loaded manifest** at
//! coordinator construction ([`super::Coordinator::start`]), never
//! hardcoded here — new artifact variants become routable without touching
//! this file.  Pure policy, trivially testable.
//!
//! **Objectives.** Non-shortest objectives (bottleneck / minimax /
//! reachability) are gated ([`objective_gate`]) and routed
//! ([`route_objective`]) here: the AOT device artifacts bake in `(min, +)`,
//! so other semirings are downgraded from Device to the semiring-generic
//! CPU tiers; johnson and the incremental `"update"` tier are
//! shortest-only and reject with a typed wire code
//! ([`super::types::CODE_OBJECTIVE_UNSUPPORTED`]).

use crate::apsp::semiring::Objective;

/// Routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Solve on CPU in the calling thread (blocked solver, given tile).
    Cpu { tile: usize },
    /// Johnson's algorithm on the CPU (sparse graphs / explicit request).
    Johnson,
    /// Submit to the device engine.
    Device,
    /// Run the coordinator-level super-blocked schedule over device-bucket
    /// tiles of the given size.
    SuperBlock { bucket: usize },
}

/// Routing configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Graphs up to this size run on the CPU path.
    pub cpu_threshold: usize,
    /// Tile size for the CPU blocked solver.
    pub cpu_tile: usize,
    /// Variants the device knows about.  Empty by default on purpose:
    /// [`super::Coordinator::start`] fills this from the manifest.
    pub device_variants: Vec<String>,
    /// Lowered artifact sizes, ascending.  Filled from the manifest
    /// alongside `device_variants`.
    pub device_buckets: Vec<usize>,
    /// Explicit super-tile size for the superblock tier (must be a lowered
    /// bucket); `None` = pick per request via [`pick_superblock_bucket`].
    pub superblock_bucket: Option<usize>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            cpu_threshold: 32,
            cpu_tile: 32,
            device_variants: Vec::new(),
            device_buckets: Vec::new(),
            superblock_bucket: None,
        }
    }
}

/// Decide the route for (variant, n, want_paths). Errors on unknown
/// variants and on path requests no tier can serve.
///
/// `want_paths` mostly rides the distance policy unchanged — the CPU and
/// superblock tiers have successor-tracking twins, and a Device route is
/// downgraded to the engine's CPU path fallback at dispatch
/// ([`super::engine::Engine::solve_paths`]; the AOT artifacts compute
/// distances only).  The exception is Johnson: its Dijkstra inner loop has
/// no successor matrix, so path requests for it are rejected here, before
/// any work is queued.
pub fn route(
    config: &RouterConfig,
    variant: &str,
    n: usize,
    want_paths: bool,
) -> Result<Route, String> {
    if variant == "cpu" {
        return Ok(Route::Cpu {
            tile: config.cpu_tile,
        });
    }
    if variant == "johnson" {
        if want_paths {
            return Err(
                "paths are not available for the johnson variant \
                 (use cpu, staged, or superblock)"
                    .to_string(),
            );
        }
        return Ok(Route::Johnson);
    }
    if variant == "superblock" {
        return superblock_route(config, n);
    }
    if !config.device_variants.iter().any(|v| v == variant) {
        return Err(format!(
            "unknown variant {variant:?} (available: cpu, johnson, superblock, {})",
            config.device_variants.join(", ")
        ));
    }
    if n <= config.cpu_threshold {
        return Ok(Route::Cpu {
            tile: config.cpu_tile,
        });
    }
    match config.device_buckets.last() {
        // larger than every artifact bucket: the pre-superblock stack
        // hard-failed here (batcher `bucket == 0`); now it is served
        Some(&largest) if n > largest => superblock_route(config, n),
        _ => Ok(Route::Device),
    }
}

/// Pure pre-check for `"update"` requests: which variants the incremental
/// tier may chain from.  The base-closure *lookup* (and the typed
/// cache-miss error) happens in the coordinator — this rejects what no
/// cache state could fix, before any cache traffic:
///
/// * unknown variants, exactly like [`route`];
/// * `johnson` — its closures come from a different algorithm family
///   (bitwise-incompatible association, no successor matrix), so chaining
///   incremental relaxations onto them would silently mix families; the
///   client re-solves instead.
///
/// `want_paths` rides along unchanged: the incremental tier maintains
/// successors whenever the base entry carries them, and the coordinator
/// re-baselines through a full path solve when it does not.
pub fn route_update(
    config: &RouterConfig,
    variant: &str,
    n: usize,
    _want_paths: bool,
) -> Result<(), String> {
    if n == 0 {
        return Err("empty graph".to_string());
    }
    if variant == "johnson" {
        return Err(
            "updates are not available for the johnson variant \
             (re-solve the mutated graph instead)"
                .to_string(),
        );
    }
    if variant == "cpu"
        || variant == "superblock"
        || config.device_variants.iter().any(|v| v == variant)
    {
        return Ok(());
    }
    Err(format!(
        "unknown variant {variant:?} (available: cpu, superblock, {})",
        config.device_variants.join(", ")
    ))
}

/// Parse and gate a request's objective string against its variant.
///
/// Unknown objectives and johnson-with-non-shortest are policy errors the
/// server surfaces as [`super::types::CODE_OBJECTIVE_UNSUPPORTED`] — johnson
/// reweights via Dijkstra, which has no meaning outside `(min, +)`.
pub fn objective_gate(variant: &str, objective: &str) -> Result<Objective, String> {
    let parsed = Objective::parse(objective).ok_or_else(|| {
        format!(
            "unknown objective {objective:?} \
             (available: shortest, bottleneck, minimax, reachability)"
        )
    })?;
    if variant == "johnson" && parsed != Objective::Shortest {
        return Err(format!(
            "the johnson variant serves the shortest objective only \
             (requested {:?})",
            parsed.name()
        ));
    }
    Ok(parsed)
}

/// Gate an `"update"` request's objective: the incremental tier chains
/// `(min, +)` relaxations and serves nothing else.
pub fn objective_gate_update(objective: &str) -> Result<(), String> {
    match Objective::parse(objective) {
        Some(Objective::Shortest) => Ok(()),
        Some(other) => Err(format!(
            "updates serve the shortest objective only (requested {:?})",
            other.name()
        )),
        None => Err(format!(
            "unknown objective {objective:?} \
             (available: shortest, bottleneck, minimax, reachability)"
        )),
    }
}

/// [`route`] plus a short machine-stable reason for the decision.
///
/// The reason is recorded on request traces ([`crate::obs::trace`]) so a
/// span answers "why did this request land on that tier?" without the
/// reader re-deriving routing policy by hand.  It is derived from the
/// same inputs [`route`] saw, so the pair can never disagree.
pub fn route_reasoned(
    config: &RouterConfig,
    variant: &str,
    n: usize,
    want_paths: bool,
) -> Result<(Route, &'static str), String> {
    let r = route(config, variant, n, want_paths)?;
    let reason = match (variant, &r) {
        ("cpu", _) => "explicit cpu variant",
        ("johnson", _) => "explicit johnson variant",
        ("superblock", _) => "explicit superblock variant",
        (_, Route::Cpu { .. }) => "n within cpu threshold",
        (_, Route::SuperBlock { .. }) => "n exceeds largest device bucket",
        (_, Route::Device) => "fits a lowered device bucket",
        (_, Route::Johnson) => unreachable!("johnson is explicit-only"),
    };
    Ok((r, reason))
}

/// [`route`] under an explicit serving objective.  Shortest is exactly
/// [`route`]; other objectives never yield `Route::Device` or
/// `Route::Johnson` — the artifacts and Johnson's reweighting are
/// `(min, +)`-only, so Device downgrades to the CPU blocked tier (the
/// super-block tier already runs its tiles CPU-side for them).
pub fn route_objective(
    config: &RouterConfig,
    variant: &str,
    n: usize,
    want_paths: bool,
    objective: Objective,
) -> Result<Route, String> {
    route_objective_reasoned(config, variant, n, want_paths, objective).map(|(r, _)| r)
}

/// [`route_objective`] plus the decision reason (see [`route_reasoned`]).
pub fn route_objective_reasoned(
    config: &RouterConfig,
    variant: &str,
    n: usize,
    want_paths: bool,
    objective: Objective,
) -> Result<(Route, &'static str), String> {
    let (r, reason) = route_reasoned(config, variant, n, want_paths)?;
    if objective == Objective::Shortest {
        return Ok((r, reason));
    }
    match r {
        Route::Johnson => Err(format!(
            "the johnson variant serves the shortest objective only \
             (requested {:?})",
            objective.name()
        )),
        Route::Device => Ok((
            Route::Cpu {
                tile: config.cpu_tile,
            },
            "non-shortest objective served off-device",
        )),
        other => Ok((other, reason)),
    }
}

fn superblock_route(config: &RouterConfig, n: usize) -> Result<Route, String> {
    let bucket = match config.superblock_bucket {
        Some(b) => {
            if !config.device_buckets.contains(&b) {
                return Err(format!(
                    "superblock bucket {b} is not a lowered artifact size \
                     (available: {:?})",
                    config.device_buckets
                ));
            }
            b
        }
        None => match pick_superblock_bucket(&config.device_buckets, n) {
            Some(b) => b,
            None => {
                return Err("superblock tier unavailable: no device buckets loaded".to_string())
            }
        },
    };
    Ok(Route::SuperBlock { bucket })
}

/// Choose the device bucket the super-block tier tiles with.
///
/// Total work is `padded³` where `padded = ceil(n/b)·b`, so first minimize
/// padding waste; among ties prefer the **largest** bucket that still
/// yields ≥ 3 super-blocks (a 2×2 grid has a single interior tile per
/// round, starving the phase-3 pool), falling back to the largest tied
/// bucket.  `buckets` must be ascending (manifest order).
pub fn pick_superblock_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    if buckets.is_empty() || n == 0 {
        return None;
    }
    let padded = |b: usize| n.div_ceil(b) * b;
    let min_padded = buckets.iter().map(|&b| padded(b)).min().unwrap();
    let tied = || buckets.iter().copied().filter(|&b| padded(b) == min_padded);
    tied()
        .filter(|&b| min_padded / b >= 3)
        .max()
        .or_else(|| tied().max())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A manifest-shaped config (what `Coordinator::start` derives).
    fn cfg() -> RouterConfig {
        RouterConfig {
            device_variants: vec!["naive".into(), "blocked".into(), "staged".into()],
            device_buckets: vec![64, 128, 256, 512],
            ..RouterConfig::default()
        }
    }

    #[test]
    fn default_config_is_manifest_driven() {
        // regression: the variant list must come from the manifest, not be
        // hardcoded here (new artifact variants would silently 404)
        let d = RouterConfig::default();
        assert!(d.device_variants.is_empty());
        assert!(d.device_buckets.is_empty());
    }

    #[test]
    fn small_graphs_go_cpu() {
        assert_eq!(route(&cfg(), "staged", 16, false).unwrap(), Route::Cpu { tile: 32 });
        assert_eq!(route(&cfg(), "staged", 32, false).unwrap(), Route::Cpu { tile: 32 });
    }

    #[test]
    fn large_graphs_go_device() {
        assert_eq!(route(&cfg(), "staged", 33, false).unwrap(), Route::Device);
        assert_eq!(route(&cfg(), "blocked", 512, false).unwrap(), Route::Device);
    }

    #[test]
    fn oversize_goes_superblock() {
        // pre-superblock these were batcher `bucket == 0` hard errors
        assert_eq!(
            route(&cfg(), "staged", 1024, false).unwrap(),
            Route::SuperBlock { bucket: 256 }
        );
        assert_eq!(
            route(&cfg(), "staged", 768, false).unwrap(),
            Route::SuperBlock { bucket: 256 }
        );
        assert_eq!(
            route(&cfg(), "naive", 513, false).unwrap(),
            Route::SuperBlock { bucket: 64 }
        );
    }

    #[test]
    fn explicit_superblock_variant() {
        assert_eq!(
            route(&cfg(), "superblock", 1024, false).unwrap(),
            Route::SuperBlock { bucket: 256 }
        );
        // even below the largest bucket the explicit variant is honored
        assert_eq!(
            route(&cfg(), "superblock", 100, false).unwrap(),
            Route::SuperBlock { bucket: 128 }
        );
    }

    #[test]
    fn superblock_bucket_override() {
        let mut c = cfg();
        c.superblock_bucket = Some(512);
        assert_eq!(
            route(&c, "staged", 2048, false).unwrap(),
            Route::SuperBlock { bucket: 512 }
        );
        c.superblock_bucket = Some(100); // not a lowered size
        let err = route(&c, "staged", 2048, false).unwrap_err();
        assert!(err.contains("not a lowered artifact size"), "{err}");
    }

    #[test]
    fn pick_bucket_minimizes_padding_then_keeps_pool_busy() {
        let buckets = [64, 128, 256, 512];
        // n=1024: every bucket pads to 1024; 256 is the largest with ≥3 blocks
        assert_eq!(pick_superblock_bucket(&buckets, 1024), Some(256));
        // n=768: 512 would pad to 1024; among {64,128,256} prefer 256 (3 blocks)
        assert_eq!(pick_superblock_bucket(&buckets, 768), Some(256));
        // n=600: {64,128} pad to 640 (others worse); 128 gives 5 blocks
        assert_eq!(pick_superblock_bucket(&buckets, 600), Some(128));
        // n=100: min padding is 128 via {64,128}; neither reaches 3 blocks,
        // fall back to the largest tied bucket
        assert_eq!(pick_superblock_bucket(&buckets, 100), Some(128));
        assert_eq!(pick_superblock_bucket(&[], 100), None);
        assert_eq!(pick_superblock_bucket(&buckets, 0), None);
    }

    #[test]
    fn explicit_cpu_always_cpu() {
        assert_eq!(route(&cfg(), "cpu", 4096, false).unwrap(), Route::Cpu { tile: 32 });
    }

    #[test]
    fn explicit_johnson_routes_to_johnson() {
        assert_eq!(route(&cfg(), "johnson", 4096, false).unwrap(), Route::Johnson);
        assert_eq!(route(&cfg(), "johnson", 4, false).unwrap(), Route::Johnson);
    }

    #[test]
    fn unknown_variant_rejected() {
        let err = route(&cfg(), "warp9", 64, false).unwrap_err();
        assert!(err.contains("warp9"));
        assert!(err.contains("staged"));
        assert!(err.contains("superblock"));
    }

    #[test]
    fn no_buckets_loaded_degrades_to_device() {
        // without bucket metadata the router cannot size super-tiles; known
        // device variants keep the old behavior (engine reports oversize)
        let c = RouterConfig {
            device_variants: vec!["staged".into()],
            ..RouterConfig::default()
        };
        assert_eq!(route(&c, "staged", 4096, false).unwrap(), Route::Device);
        let err = route(&c, "superblock", 4096, false).unwrap_err();
        assert!(err.contains("no device buckets"), "{err}");
    }

    #[test]
    fn update_routing_policy() {
        // every cached-closure variant is updatable...
        for variant in ["cpu", "superblock", "staged", "blocked", "naive"] {
            assert!(route_update(&cfg(), variant, 64, false).is_ok(), "{variant}");
            assert!(route_update(&cfg(), variant, 64, true).is_ok(), "{variant}");
        }
        // ...except johnson (different algorithm family; no successors)
        let err = route_update(&cfg(), "johnson", 64, false).unwrap_err();
        assert!(err.contains("johnson"), "{err}");
        // unknown variants rejected with the same shape as route()
        let err = route_update(&cfg(), "warp9", 64, false).unwrap_err();
        assert!(err.contains("warp9") && err.contains("staged"), "{err}");
        assert!(route_update(&cfg(), "staged", 0, false).is_err());
    }

    #[test]
    fn objective_gate_policy() {
        // every known objective passes for generic-capable variants
        for (s, o) in [
            ("shortest", Objective::Shortest),
            ("bottleneck", Objective::Bottleneck),
            ("minimax", Objective::Minimax),
            ("reachability", Objective::Reachability),
        ] {
            assert_eq!(objective_gate("staged", s).unwrap(), o, "{s}");
            assert_eq!(objective_gate("cpu", s).unwrap(), o, "{s}");
        }
        // unknown objectives are rejected with the available list
        let err = objective_gate("staged", "widest").unwrap_err();
        assert!(err.contains("widest") && err.contains("bottleneck"), "{err}");
        // johnson is shortest-only
        assert_eq!(objective_gate("johnson", "shortest").unwrap(), Objective::Shortest);
        let err = objective_gate("johnson", "bottleneck").unwrap_err();
        assert!(err.contains("johnson") && err.contains("shortest"), "{err}");
        // updates are shortest-only regardless of variant
        assert!(objective_gate_update("shortest").is_ok());
        let err = objective_gate_update("reachability").unwrap_err();
        assert!(err.contains("shortest"), "{err}");
        assert!(objective_gate_update("widest").is_err());
    }

    #[test]
    fn non_shortest_objectives_never_route_to_device_or_johnson() {
        let c = cfg();
        for o in [Objective::Bottleneck, Objective::Minimax, Objective::Reachability] {
            // small stays CPU, device-size downgrades to CPU
            assert_eq!(
                route_objective(&c, "staged", 16, false, o).unwrap(),
                Route::Cpu { tile: 32 }
            );
            assert_eq!(
                route_objective(&c, "staged", 300, false, o).unwrap(),
                Route::Cpu { tile: 32 }
            );
            // oversize still goes superblock (CPU-side tiles)
            assert_eq!(
                route_objective(&c, "staged", 1024, false, o).unwrap(),
                Route::SuperBlock { bucket: 256 }
            );
            assert!(route_objective(&c, "johnson", 64, false, o).is_err());
        }
        // shortest is exactly route()
        for (variant, n) in [("staged", 16), ("staged", 300), ("johnson", 64), ("cpu", 9)] {
            assert_eq!(
                route_objective(&c, variant, n, false, Objective::Shortest).unwrap(),
                route(&c, variant, n, false).unwrap()
            );
        }
    }

    #[test]
    fn route_reasons_are_pinned() {
        // the reason strings ride request traces; pin them so dashboards
        // grouping by reason don't silently fragment
        let c = cfg();
        let cases = [
            ("cpu", 4096, "explicit cpu variant"),
            ("johnson", 4096, "explicit johnson variant"),
            ("superblock", 1024, "explicit superblock variant"),
            ("staged", 16, "n within cpu threshold"),
            ("staged", 300, "fits a lowered device bucket"),
            ("staged", 1024, "n exceeds largest device bucket"),
        ];
        for (variant, n, want) in cases {
            let (r, reason) = route_reasoned(&c, variant, n, false).unwrap();
            assert_eq!(reason, want, "{variant} n={n}");
            assert_eq!(r, route(&c, variant, n, false).unwrap(), "{variant} n={n}");
        }
        // objective-aware: the Device→Cpu downgrade gets its own reason...
        let (r, reason) =
            route_objective_reasoned(&c, "staged", 300, false, Objective::Bottleneck).unwrap();
        assert_eq!(r, Route::Cpu { tile: 32 });
        assert_eq!(reason, "non-shortest objective served off-device");
        // ...while routes the objective doesn't move keep the base reason
        let (r, reason) =
            route_objective_reasoned(&c, "staged", 1024, false, Objective::Minimax).unwrap();
        assert_eq!(r, Route::SuperBlock { bucket: 256 });
        assert_eq!(reason, "n exceeds largest device bucket");
        assert!(route_objective_reasoned(&c, "johnson", 64, false, Objective::Minimax).is_err());
    }

    #[test]
    fn threshold_configurable() {
        let mut c = cfg();
        c.cpu_threshold = 0;
        assert_eq!(route(&c, "staged", 1, false).unwrap(), Route::Device);
    }
}

//! Request routing policy: where should a solve run?
//!
//! * tiny graphs (n ≤ `cpu_threshold`) run on the calling thread's CPU
//!   solver — padding a 16-vertex graph to a 64³-work device bucket costs
//!   more than solving it in-place (the same big/small split a GPU serving
//!   stack makes);
//! * the explicit "cpu" variant always routes to the CPU solver;
//! * everything else goes to the device engine.
//!
//! Pure policy, trivially testable.

/// Routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Solve on CPU in the calling thread (blocked solver, given tile).
    Cpu { tile: usize },
    /// Johnson's algorithm on the CPU (sparse graphs / explicit request).
    Johnson,
    /// Submit to the device engine.
    Device,
}

/// Routing configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Graphs up to this size run on the CPU path.
    pub cpu_threshold: usize,
    /// Tile size for the CPU blocked solver.
    pub cpu_tile: usize,
    /// Variants the device knows about (from the manifest).
    pub device_variants: Vec<String>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            cpu_threshold: 32,
            cpu_tile: 32,
            device_variants: vec!["naive".into(), "blocked".into(), "staged".into()],
        }
    }
}

/// Decide the route for (variant, n). Errors on unknown variants.
pub fn route(config: &RouterConfig, variant: &str, n: usize) -> Result<Route, String> {
    if variant == "cpu" {
        return Ok(Route::Cpu {
            tile: config.cpu_tile,
        });
    }
    if variant == "johnson" {
        return Ok(Route::Johnson);
    }
    if !config.device_variants.iter().any(|v| v == variant) {
        return Err(format!(
            "unknown variant {variant:?} (available: cpu, johnson, {})",
            config.device_variants.join(", ")
        ));
    }
    if n <= config.cpu_threshold {
        Ok(Route::Cpu {
            tile: config.cpu_tile,
        })
    } else {
        Ok(Route::Device)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RouterConfig {
        RouterConfig::default()
    }

    #[test]
    fn small_graphs_go_cpu() {
        assert_eq!(route(&cfg(), "staged", 16).unwrap(), Route::Cpu { tile: 32 });
        assert_eq!(route(&cfg(), "staged", 32).unwrap(), Route::Cpu { tile: 32 });
    }

    #[test]
    fn large_graphs_go_device() {
        assert_eq!(route(&cfg(), "staged", 33).unwrap(), Route::Device);
        assert_eq!(route(&cfg(), "blocked", 512).unwrap(), Route::Device);
    }

    #[test]
    fn explicit_cpu_always_cpu() {
        assert_eq!(route(&cfg(), "cpu", 4096).unwrap(), Route::Cpu { tile: 32 });
    }

    #[test]
    fn explicit_johnson_routes_to_johnson() {
        assert_eq!(route(&cfg(), "johnson", 4096).unwrap(), Route::Johnson);
        assert_eq!(route(&cfg(), "johnson", 4).unwrap(), Route::Johnson);
    }

    #[test]
    fn unknown_variant_rejected() {
        let err = route(&cfg(), "warp9", 64).unwrap_err();
        assert!(err.contains("warp9"));
        assert!(err.contains("staged"));
    }

    #[test]
    fn threshold_configurable() {
        let mut c = cfg();
        c.cpu_threshold = 0;
        assert_eq!(route(&c, "staged", 1).unwrap(), Route::Device);
    }
}

//! Serving metrics: counters + latency summaries, snapshotable as JSON.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Samples;

/// Aggregated coordinator metrics (shared, thread-safe).
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    errors: u64,
    device_solves: u64,
    cpu_solves: u64,
    cache_hits: u64,
    superblock_solves: u64,
    superblock_rounds: u64,
    superblock_tiles: u64,
    incremental_solves: u64,
    update_edges: u64,
    update_recomputes: u64,
    batches: u64,
    batched_items: u64,
    latency: Samples,
    device_seconds: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
        }
    }

    pub fn record_request(&self) {
        self.inner.lock().unwrap().requests += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn record_solve(&self, source: super::types::Source, seconds: f64) {
        let mut m = self.inner.lock().unwrap();
        match source {
            super::types::Source::Device => m.device_solves += 1,
            super::types::Source::Cpu => m.cpu_solves += 1,
            super::types::Source::Cache => m.cache_hits += 1,
            super::types::Source::SuperBlock => m.superblock_solves += 1,
            super::types::Source::Incremental => m.incremental_solves += 1,
        }
        m.latency.push(seconds);
    }

    /// Account one superblock solve's schedule (rounds run, tile updates).
    pub fn record_superblock(&self, rounds: u64, tiles: u64) {
        let mut m = self.inner.lock().unwrap();
        m.superblock_rounds += rounds;
        m.superblock_tiles += tiles;
    }

    /// Account one `"update"` request: the edge-delta count it carried and
    /// whether it fell back to a full recompute (re-baseline, threshold, or
    /// a successor-less base).
    pub fn record_update(&self, edges: u64, recomputed: bool) {
        let mut m = self.inner.lock().unwrap();
        m.update_edges += edges;
        if recomputed {
            m.update_recomputes += 1;
        }
    }

    pub fn record_batch(&self, items: usize, device_seconds: f64) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batched_items += items as u64;
        m.device_seconds += device_seconds;
    }

    /// Snapshot as a JSON object (served by the `stats` request).
    ///
    /// With no latency samples yet, the summary fields render as `"-"`:
    /// `Samples` reports the empty case as NaN by contract (never a panic
    /// or a silent 0 — see `util::stats`), NaN has no JSON rendering, and
    /// `"-"` keeps "no data" distinguishable from "0 seconds" for humans
    /// and dashboards alike.
    pub fn snapshot(&self) -> Json {
        let mut m = self.inner.lock().unwrap();
        let uptime = self.started.elapsed().as_secs_f64();
        let percentiles = m.latency.percentiles(&[50.0, 95.0, 99.0]);
        let empty = m.latency.is_empty();
        let latency = |v: f64| if empty { Json::str("-") } else { Json::num(v) };
        Json::obj(vec![
            ("uptime_seconds", Json::num(uptime)),
            ("requests", Json::num(m.requests as f64)),
            ("errors", Json::num(m.errors as f64)),
            ("device_solves", Json::num(m.device_solves as f64)),
            ("cpu_solves", Json::num(m.cpu_solves as f64)),
            ("cache_hits", Json::num(m.cache_hits as f64)),
            ("superblock_solves", Json::num(m.superblock_solves as f64)),
            ("superblock_rounds", Json::num(m.superblock_rounds as f64)),
            ("superblock_tiles", Json::num(m.superblock_tiles as f64)),
            ("incremental_solves", Json::num(m.incremental_solves as f64)),
            ("update_edges", Json::num(m.update_edges as f64)),
            ("update_recomputes", Json::num(m.update_recomputes as f64)),
            ("batches", Json::num(m.batches as f64)),
            ("batched_items", Json::num(m.batched_items as f64)),
            ("device_seconds", Json::num(m.device_seconds)),
            ("latency_mean_s", latency(m.latency.mean())),
            ("latency_p50_s", latency(percentiles[0])),
            ("latency_p95_s", latency(percentiles[1])),
            ("latency_p99_s", latency(percentiles[2])),
            ("latency_max_s", latency(m.latency.max())),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::super::types::Source;
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_solve(Source::Device, 0.010);
        m.record_solve(Source::Cache, 0.0001);
        m.record_batch(3, 0.009);
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").as_usize(), Some(2));
        assert_eq!(snap.get("device_solves").as_usize(), Some(1));
        assert_eq!(snap.get("cache_hits").as_usize(), Some(1));
        assert_eq!(snap.get("batches").as_usize(), Some(1));
        assert_eq!(snap.get("batched_items").as_usize(), Some(3));
        assert!(snap.get("latency_mean_s").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn superblock_counters_accumulate() {
        let m = Metrics::new();
        m.record_solve(Source::SuperBlock, 1.5);
        m.record_superblock(4, 60);
        m.record_superblock(3, 24);
        let snap = m.snapshot();
        assert_eq!(snap.get("superblock_solves").as_usize(), Some(1));
        assert_eq!(snap.get("superblock_rounds").as_usize(), Some(7));
        assert_eq!(snap.get("superblock_tiles").as_usize(), Some(84));
    }

    #[test]
    fn latency_percentiles_exposed() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_solve(Source::Cpu, i as f64 / 1000.0);
        }
        let snap = m.snapshot();
        let p50 = snap.get("latency_p50_s").as_f64().unwrap();
        let p95 = snap.get("latency_p95_s").as_f64().unwrap();
        let p99 = snap.get("latency_p99_s").as_f64().unwrap();
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
        assert!((p95 - 0.095).abs() < 2e-3, "p95={p95}");
    }

    #[test]
    fn snapshot_with_no_latency_renders_dash() {
        // the empty sample set is pinned end to end: Samples reports NaN
        // (util::stats), and the snapshot renders the absence as "-" —
        // valid JSON, and distinguishable from a real 0-second latency
        let m = Metrics::new();
        let snap = m.snapshot();
        for key in [
            "latency_mean_s",
            "latency_p50_s",
            "latency_p95_s",
            "latency_p99_s",
            "latency_max_s",
        ] {
            assert_eq!(snap.get(key).as_str(), Some("-"), "{key}");
        }
        let reparsed = Json::parse(&snap.to_string());
        assert!(reparsed.is_ok(), "snapshot not parseable: {snap}");
        // one recorded solve flips every field back to numbers
        m.record_solve(Source::Cpu, 0.25);
        let snap = m.snapshot();
        assert_eq!(snap.get("latency_p99_s").as_f64(), Some(0.25));
        assert_eq!(snap.get("latency_max_s").as_f64(), Some(0.25));
    }

    #[test]
    fn update_counters_accumulate() {
        let m = Metrics::new();
        m.record_solve(Source::Incremental, 0.002);
        m.record_solve(Source::Incremental, 0.003);
        m.record_update(4, false);
        m.record_update(2, true);
        let snap = m.snapshot();
        assert_eq!(snap.get("incremental_solves").as_usize(), Some(2));
        assert_eq!(snap.get("update_edges").as_usize(), Some(6));
        assert_eq!(snap.get("update_recomputes").as_usize(), Some(1));
    }
}

//! Serving metrics: counters + latency summaries, snapshotable as JSON.
//!
//! Two latency views coexist on purpose:
//!
//! * `latency` ([`crate::util::stats::Samples`]) — a bounded sliding
//!   window of raw seconds, for exact recent percentiles;
//! * `hists` ([`crate::obs::Histogram`]) — log-bucketed histograms keyed
//!   by `(source, objective)`, O(1) memory forever, mergeable, and
//!   renderable as Prometheus text ([`Metrics::exposition`]).  These never
//!   forget: they describe the whole process lifetime, per tier.
//!
//! Errors are counted twice as well: the `errors` total (cheap dashboard
//! number) and `errors_by_code` keyed by the typed wire code, so a spike
//! can be attributed without grepping logs.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::apsp::semiring::Objective;
use crate::obs::hist::{escape_label_value, render_series};
use crate::obs::Histogram;
use crate::util::json::Json;
use crate::util::stats::Samples;

/// Aggregated coordinator metrics (shared, thread-safe).
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    errors: u64,
    errors_by_code: BTreeMap<String, u64>,
    connections_shed: u64,
    requests_shed: u64,
    idle_timeouts: u64,
    device_solves: u64,
    cpu_solves: u64,
    cache_hits: u64,
    superblock_solves: u64,
    superblock_rounds: u64,
    superblock_tiles: u64,
    incremental_solves: u64,
    update_edges: u64,
    update_recomputes: u64,
    batches: u64,
    batched_items: u64,
    /// Closure-store traffic (`coordinator/store.rs`).  `store_hits`
    /// counts every entry loaded and verified from disk — boot warm-start
    /// loads *and* request-path read-throughs (both are the store doing
    /// its job: serving a closure that survived a process death).
    store_hits: u64,
    store_misses: u64,
    store_writes: u64,
    store_evictions: u64,
    /// Entries rejected at load time (bad checksum, short read, version
    /// skew, stale tmp) and quarantined.  Nonzero means disk state was
    /// damaged and *detected* — never served.
    store_corrupt: u64,
    latency: Samples,
    hists: BTreeMap<(String, String), Histogram>,
    device_seconds: f64,
    queue_wait_seconds: f64,
    /// Serving-queue wait (enqueue → worker pickup) per data request —
    /// distinct from `queue_wait_seconds`, which sums *engine-batch* queue
    /// time inside device rounds.
    queue_wait: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
        }
    }

    pub fn record_request(&self) {
        crate::recover_lock!(&self.inner, "metrics.inner").requests += 1;
    }

    /// Count one error under its typed wire code (e.g.
    /// [`super::types::CODE_OBJECTIVE_UNSUPPORTED`]); free-form failures
    /// use `"error"`, the generic wire code.
    pub fn record_error(&self, code: &str) {
        let mut m = crate::recover_lock!(&self.inner, "metrics.inner");
        m.errors += 1;
        *m.errors_by_code.entry(code.to_string()).or_insert(0) += 1;
    }

    /// Count one connection refused at admission (the server's
    /// concurrent-connection cap).  Deliberately *not* an `errors` entry:
    /// a shed is connection-level backpressure working as designed, and
    /// folding it into request errors would make overload look like
    /// request failures on dashboards.
    pub fn record_shed(&self) {
        crate::recover_lock!(&self.inner, "metrics.inner").connections_shed += 1;
    }

    /// Count one *request* shed at queue admission (the bounded serving
    /// queue was full).  Same doctrine as [`Metrics::record_shed`]: this
    /// is backpressure working, not a request error.
    pub fn record_queue_shed(&self) {
        crate::recover_lock!(&self.inner, "metrics.inner").requests_shed += 1;
    }

    /// Count one connection closed for sitting idle past the configured
    /// read timeout.  Not an error either — the client did nothing wrong
    /// by going quiet; the server just reclaimed the admission slot.
    pub fn record_idle_timeout(&self) {
        crate::recover_lock!(&self.inner, "metrics.inner").idle_timeouts += 1;
    }

    /// Observe one data request's serving-queue wait (enqueue → worker
    /// pickup), feeding the `fw_queue_wait_seconds` histogram.
    pub fn record_queue_wait(&self, seconds: f64) {
        crate::recover_lock!(&self.inner, "metrics.inner").queue_wait.observe(seconds);
    }

    pub fn record_solve(&self, source: super::types::Source, objective: Objective, seconds: f64) {
        let mut m = crate::recover_lock!(&self.inner, "metrics.inner");
        match source {
            super::types::Source::Device => m.device_solves += 1,
            super::types::Source::Cpu => m.cpu_solves += 1,
            super::types::Source::Cache => m.cache_hits += 1,
            super::types::Source::SuperBlock => m.superblock_solves += 1,
            super::types::Source::Incremental => m.incremental_solves += 1,
        }
        m.latency.push(seconds);
        let key = (source.name().to_string(), objective.name().to_string());
        m.hists.entry(key).or_default().observe(seconds);
    }

    /// Account one superblock solve's schedule (rounds run, tile updates).
    pub fn record_superblock(&self, rounds: u64, tiles: u64) {
        let mut m = crate::recover_lock!(&self.inner, "metrics.inner");
        m.superblock_rounds += rounds;
        m.superblock_tiles += tiles;
    }

    /// Account one `"update"` request: the edge-delta count it carried and
    /// whether it fell back to a full recompute (re-baseline, threshold, or
    /// a successor-less base).
    pub fn record_update(&self, edges: u64, recomputed: bool) {
        let mut m = crate::recover_lock!(&self.inner, "metrics.inner");
        m.update_edges += edges;
        if recomputed {
            m.update_recomputes += 1;
        }
    }

    /// Count one closure served from the on-disk store (a boot warm-start
    /// load or a request-path read-through — both checksum-verified).
    pub fn record_store_hit(&self) {
        crate::recover_lock!(&self.inner, "metrics.inner").store_hits += 1;
    }

    /// Count one store lookup that found no entry on disk (a true cold
    /// miss: the memory cache already missed before the store was asked).
    pub fn record_store_miss(&self) {
        crate::recover_lock!(&self.inner, "metrics.inner").store_misses += 1;
    }

    /// Count one entry durably published (temp written, synced, renamed).
    pub fn record_store_write(&self) {
        crate::recover_lock!(&self.inner, "metrics.inner").store_writes += 1;
    }

    /// Count entries deleted by the size-budget eviction sweep.
    pub fn record_store_evictions(&self, n: u64) {
        crate::recover_lock!(&self.inner, "metrics.inner").store_evictions += n;
    }

    /// Count one corrupt entry detected at load (quarantined, never
    /// served) or one stale temp file swept at open.
    pub fn record_store_corrupt(&self) {
        crate::recover_lock!(&self.inner, "metrics.inner").store_corrupt += 1;
    }

    /// Account one engine batch: item count, device-kernel seconds, and
    /// the summed seconds its jobs sat queued before the round started.
    pub fn record_batch(&self, items: usize, device_seconds: f64, queue_wait_seconds: f64) {
        let mut m = crate::recover_lock!(&self.inner, "metrics.inner");
        m.batches += 1;
        m.batched_items += items as u64;
        m.device_seconds += device_seconds;
        m.queue_wait_seconds += queue_wait_seconds;
    }

    /// Snapshot as a JSON object (served by the `stats` request).
    ///
    /// With no latency samples yet, the summary fields render as `"-"`:
    /// `Samples` reports the empty case as NaN by contract (never a panic
    /// or a silent 0 — see `util::stats`), NaN has no JSON rendering, and
    /// `"-"` keeps "no data" distinguishable from "0 seconds" for humans
    /// and dashboards alike.
    ///
    /// `latency_hist` holds one object per `(source, objective)` pair seen
    /// so far, keyed `"source/objective"`; `errors_by_code` breaks the
    /// `errors` total out by typed wire code.
    pub fn snapshot(&self) -> Json {
        let mut m = crate::recover_lock!(&self.inner, "metrics.inner");
        let uptime = self.started.elapsed().as_secs_f64();
        let percentiles = m.latency.percentiles(&[50.0, 95.0, 99.0]);
        let empty = m.latency.is_empty();
        let latency = |v: f64| if empty { Json::str("-") } else { Json::num(v) };
        let codes = m
            .errors_by_code
            .iter()
            .map(|(code, &count)| (code.clone(), Json::num(count as f64)))
            .collect();
        let hists = m
            .hists
            .iter()
            .map(|((source, objective), h)| (format!("{source}/{objective}"), h.to_json()))
            .collect();
        Json::obj(vec![
            ("uptime_seconds", Json::num(uptime)),
            ("requests", Json::num(m.requests as f64)),
            ("errors", Json::num(m.errors as f64)),
            ("errors_by_code", Json::Obj(codes)),
            ("connections_shed", Json::num(m.connections_shed as f64)),
            ("requests_shed", Json::num(m.requests_shed as f64)),
            ("idle_timeouts", Json::num(m.idle_timeouts as f64)),
            ("device_solves", Json::num(m.device_solves as f64)),
            ("cpu_solves", Json::num(m.cpu_solves as f64)),
            ("cache_hits", Json::num(m.cache_hits as f64)),
            ("superblock_solves", Json::num(m.superblock_solves as f64)),
            ("superblock_rounds", Json::num(m.superblock_rounds as f64)),
            ("superblock_tiles", Json::num(m.superblock_tiles as f64)),
            ("incremental_solves", Json::num(m.incremental_solves as f64)),
            ("update_edges", Json::num(m.update_edges as f64)),
            ("update_recomputes", Json::num(m.update_recomputes as f64)),
            ("batches", Json::num(m.batches as f64)),
            ("batched_items", Json::num(m.batched_items as f64)),
            ("store_hits", Json::num(m.store_hits as f64)),
            ("store_misses", Json::num(m.store_misses as f64)),
            ("store_writes", Json::num(m.store_writes as f64)),
            ("store_evictions", Json::num(m.store_evictions as f64)),
            ("store_corrupt", Json::num(m.store_corrupt as f64)),
            ("device_seconds", Json::num(m.device_seconds)),
            ("queue_wait_seconds", Json::num(m.queue_wait_seconds)),
            ("latency_mean_s", latency(m.latency.mean())),
            ("latency_p50_s", latency(percentiles[0])),
            ("latency_p95_s", latency(percentiles[1])),
            ("latency_p99_s", latency(percentiles[2])),
            ("latency_max_s", latency(m.latency.max())),
            ("latency_hist", Json::Obj(hists)),
            ("queue_wait_hist", m.queue_wait.to_json()),
        ])
    }

    /// Prometheus-style text exposition: `fw_requests_total` /
    /// `fw_errors_total` counters plus one `fw_request_seconds` histogram
    /// series per `(source, objective)` pair, labeled
    /// `{objective="…",source="…"}`.  Round-trips through
    /// [`crate::obs::hist::parse_exposition`].
    pub fn exposition(&self) -> String {
        let m = crate::recover_lock!(&self.inner, "metrics.inner");
        let mut out = String::new();
        out.push_str("# TYPE fw_requests_total counter\n");
        out.push_str(&format!("fw_requests_total {}\n", m.requests));
        out.push_str("# TYPE fw_errors_total counter\n");
        out.push_str(&format!("fw_errors_total {}\n", m.errors));
        out.push_str("# TYPE fw_connections_shed_total counter\n");
        out.push_str(&format!("fw_connections_shed_total {}\n", m.connections_shed));
        out.push_str("# TYPE fw_requests_shed_total counter\n");
        out.push_str(&format!("fw_requests_shed_total {}\n", m.requests_shed));
        out.push_str("# TYPE fw_idle_timeouts_total counter\n");
        out.push_str(&format!("fw_idle_timeouts_total {}\n", m.idle_timeouts));
        out.push_str("# TYPE fw_store_hits_total counter\n");
        out.push_str(&format!("fw_store_hits_total {}\n", m.store_hits));
        out.push_str("# TYPE fw_store_misses_total counter\n");
        out.push_str(&format!("fw_store_misses_total {}\n", m.store_misses));
        out.push_str("# TYPE fw_store_writes_total counter\n");
        out.push_str(&format!("fw_store_writes_total {}\n", m.store_writes));
        out.push_str("# TYPE fw_store_evictions_total counter\n");
        out.push_str(&format!("fw_store_evictions_total {}\n", m.store_evictions));
        out.push_str("# TYPE fw_store_corrupt_total counter\n");
        out.push_str(&format!("fw_store_corrupt_total {}\n", m.store_corrupt));
        out.push_str("# TYPE fw_queue_wait_seconds histogram\n");
        render_series(&mut out, "fw_queue_wait_seconds", "", &m.queue_wait);
        out.push_str("# TYPE fw_request_seconds histogram\n");
        for ((source, objective), h) in &m.hists {
            // label values are escaped even though today's sources and
            // objectives are clean enum names — the exposition format must
            // not be corruptible by any future label source
            let labels = format!(
                "objective=\"{}\",source=\"{}\"",
                escape_label_value(objective),
                escape_label_value(source)
            );
            render_series(&mut out, "fw_request_seconds", &labels, h);
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::super::types::Source;
    use super::*;
    use crate::obs::hist::parse_exposition;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_solve(Source::Device, Objective::Shortest, 0.010);
        m.record_solve(Source::Cache, Objective::Shortest, 0.0001);
        m.record_batch(3, 0.009, 0.002);
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").as_usize(), Some(2));
        assert_eq!(snap.get("device_solves").as_usize(), Some(1));
        assert_eq!(snap.get("cache_hits").as_usize(), Some(1));
        assert_eq!(snap.get("batches").as_usize(), Some(1));
        assert_eq!(snap.get("batched_items").as_usize(), Some(3));
        assert!(snap.get("latency_mean_s").as_f64().unwrap() > 0.0);
        assert!(snap.get("queue_wait_seconds").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn superblock_counters_accumulate() {
        let m = Metrics::new();
        m.record_solve(Source::SuperBlock, Objective::Shortest, 1.5);
        m.record_superblock(4, 60);
        m.record_superblock(3, 24);
        let snap = m.snapshot();
        assert_eq!(snap.get("superblock_solves").as_usize(), Some(1));
        assert_eq!(snap.get("superblock_rounds").as_usize(), Some(7));
        assert_eq!(snap.get("superblock_tiles").as_usize(), Some(84));
    }

    #[test]
    fn latency_percentiles_exposed() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_solve(Source::Cpu, Objective::Shortest, i as f64 / 1000.0);
        }
        let snap = m.snapshot();
        let p50 = snap.get("latency_p50_s").as_f64().unwrap();
        let p95 = snap.get("latency_p95_s").as_f64().unwrap();
        let p99 = snap.get("latency_p99_s").as_f64().unwrap();
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
        assert!((p95 - 0.095).abs() < 2e-3, "p95={p95}");
    }

    #[test]
    fn snapshot_with_no_latency_renders_dash() {
        // the empty sample set is pinned end to end: Samples reports NaN
        // (util::stats), and the snapshot renders the absence as "-" —
        // valid JSON, and distinguishable from a real 0-second latency
        let m = Metrics::new();
        let snap = m.snapshot();
        for key in [
            "latency_mean_s",
            "latency_p50_s",
            "latency_p95_s",
            "latency_p99_s",
            "latency_max_s",
        ] {
            assert_eq!(snap.get(key).as_str(), Some("-"), "{key}");
        }
        let reparsed = Json::parse(&snap.to_string());
        assert!(reparsed.is_ok(), "snapshot not parseable: {snap}");
        // one recorded solve flips every field back to numbers
        m.record_solve(Source::Cpu, Objective::Shortest, 0.25);
        let snap = m.snapshot();
        assert_eq!(snap.get("latency_p99_s").as_f64(), Some(0.25));
        assert_eq!(snap.get("latency_max_s").as_f64(), Some(0.25));
    }

    #[test]
    fn update_counters_accumulate() {
        let m = Metrics::new();
        m.record_solve(Source::Incremental, Objective::Shortest, 0.002);
        m.record_solve(Source::Incremental, Objective::Shortest, 0.003);
        m.record_update(4, false);
        m.record_update(2, true);
        let snap = m.snapshot();
        assert_eq!(snap.get("incremental_solves").as_usize(), Some(2));
        assert_eq!(snap.get("update_edges").as_usize(), Some(6));
        assert_eq!(snap.get("update_recomputes").as_usize(), Some(1));
    }

    #[test]
    fn errors_break_out_by_code() {
        let m = Metrics::new();
        m.record_error("error");
        m.record_error("objective_unsupported");
        m.record_error("objective_unsupported");
        let snap = m.snapshot();
        assert_eq!(snap.get("errors").as_usize(), Some(3));
        let codes = snap.get("errors_by_code");
        assert_eq!(codes.get("error").as_usize(), Some(1));
        assert_eq!(codes.get("objective_unsupported").as_usize(), Some(2));
    }

    #[test]
    fn sheds_count_separately_from_errors() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_error("error");
        let snap = m.snapshot();
        assert_eq!(snap.get("connections_shed").as_usize(), Some(2));
        assert_eq!(snap.get("errors").as_usize(), Some(1), "sheds are not errors");
        let text = m.exposition();
        assert!(text.contains("fw_connections_shed_total 2\n"), "{text}");
    }

    #[test]
    fn queue_sheds_and_idle_timeouts_count_separately_from_errors() {
        // same backpressure-is-not-failure doctrine as connection sheds:
        // a full queue and a reclaimed idle slot are the server working,
        // not requests failing
        let m = Metrics::new();
        m.record_queue_shed();
        m.record_queue_shed();
        m.record_queue_shed();
        m.record_idle_timeout();
        let snap = m.snapshot();
        assert_eq!(snap.get("requests_shed").as_usize(), Some(3));
        assert_eq!(snap.get("idle_timeouts").as_usize(), Some(1));
        assert_eq!(snap.get("errors").as_usize(), Some(0), "sheds/timeouts are not errors");
        assert_eq!(snap.get("connections_shed").as_usize(), Some(0));
        let text = m.exposition();
        assert!(text.contains("fw_requests_shed_total 3\n"), "{text}");
        assert!(text.contains("fw_idle_timeouts_total 1\n"), "{text}");
    }

    #[test]
    fn store_counters_accumulate_and_expose() {
        let m = Metrics::new();
        m.record_store_hit();
        m.record_store_hit();
        m.record_store_miss();
        m.record_store_write();
        m.record_store_write();
        m.record_store_write();
        m.record_store_evictions(2);
        m.record_store_corrupt();
        let snap = m.snapshot();
        assert_eq!(snap.get("store_hits").as_usize(), Some(2));
        assert_eq!(snap.get("store_misses").as_usize(), Some(1));
        assert_eq!(snap.get("store_writes").as_usize(), Some(3));
        assert_eq!(snap.get("store_evictions").as_usize(), Some(2));
        assert_eq!(snap.get("store_corrupt").as_usize(), Some(1));
        // corruption and eviction are store health, not request errors —
        // the same doctrine as sheds
        assert_eq!(snap.get("errors").as_usize(), Some(0));
        let text = m.exposition();
        assert!(text.contains("fw_store_hits_total 2\n"), "{text}");
        assert!(text.contains("fw_store_writes_total 3\n"), "{text}");
        assert!(text.contains("fw_store_corrupt_total 1\n"), "{text}");
    }

    #[test]
    fn queue_wait_histogram_records_and_round_trips() {
        let m = Metrics::new();
        m.record_queue_wait(0.001);
        m.record_queue_wait(0.004);
        m.record_queue_wait(0.5);
        let snap = m.snapshot();
        assert_eq!(snap.get("queue_wait_hist").get("count").as_usize(), Some(3));
        let sum = snap.get("queue_wait_hist").get("sum_s").as_f64().unwrap();
        assert!((sum - 0.505).abs() < 1e-12, "{sum}");
        let parsed = parse_exposition(&m.exposition()).unwrap();
        // unlabeled series key back as `name{}` (parser convention)
        let h = &parsed["fw_queue_wait_seconds{}"];
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 0.505).abs() < 1e-12);
    }

    #[test]
    fn histograms_key_by_source_and_objective() {
        let m = Metrics::new();
        m.record_solve(Source::Cpu, Objective::Shortest, 0.010);
        m.record_solve(Source::Cpu, Objective::Shortest, 0.020);
        m.record_solve(Source::Cpu, Objective::Bottleneck, 0.030);
        m.record_solve(Source::Cache, Objective::Shortest, 0.0001);
        let snap = m.snapshot();
        let hists = snap.get("latency_hist");
        assert_eq!(hists.get("cpu/shortest").get("count").as_usize(), Some(2));
        assert_eq!(hists.get("cpu/bottleneck").get("count").as_usize(), Some(1));
        assert_eq!(hists.get("cache/shortest").get("count").as_usize(), Some(1));
        let sum = hists.get("cpu/shortest").get("sum_s").as_f64().unwrap();
        assert!((sum - 0.030).abs() < 1e-12, "{sum}");
    }

    #[test]
    fn exposition_round_trips() {
        let m = Metrics::new();
        m.record_solve(Source::Cpu, Objective::Shortest, 0.010);
        m.record_solve(Source::Device, Objective::Shortest, 0.002);
        m.record_solve(Source::Cpu, Objective::Minimax, 0.5);
        let text = m.exposition();
        assert!(text.contains("fw_requests_total"), "{text}");
        let parsed = parse_exposition(&text).unwrap();
        let cpu = &parsed["fw_request_seconds{objective=\"shortest\",source=\"cpu\"}"];
        assert_eq!(cpu.count(), 1);
        assert!((cpu.sum() - 0.010).abs() < 1e-12);
        let mm = &parsed["fw_request_seconds{objective=\"minimax\",source=\"cpu\"}"];
        assert_eq!(mm.count(), 1);
    }

    #[test]
    fn concurrent_records_never_tear_the_snapshot() {
        // property: every snapshot taken while writers hammer the metrics
        // is internally consistent — each histogram parses back whole, and
        // errors_by_code always sums to the errors total
        let m = Metrics::new();
        std::thread::scope(|scope| {
            for t in 0..4 {
                let m = &m;
                scope.spawn(move || {
                    for i in 0..200 {
                        let source = if t % 2 == 0 { Source::Cpu } else { Source::Device };
                        m.record_solve(source, Objective::Shortest, 1e-5 * (i + 1) as f64);
                        if i % 7 == 0 {
                            m.record_error("error");
                        }
                    }
                });
            }
            let m = &m;
            scope.spawn(move || {
                for _ in 0..50 {
                    let snap = m.snapshot();
                    let errors = snap.get("errors").as_usize().unwrap();
                    let codes = snap.get("errors_by_code").as_obj().unwrap();
                    let by_code: usize =
                        codes.values().map(|v| v.as_usize().unwrap()).sum();
                    assert_eq!(errors, by_code);
                    // exposition taken mid-flight still parses and obeys
                    // the cumulative-bucket invariant checked by the parser
                    parse_exposition(&m.exposition()).unwrap();
                }
            });
        });
        // final state is exact
        let snap = m.snapshot();
        let solves = snap.get("cpu_solves").as_usize().unwrap()
            + snap.get("device_solves").as_usize().unwrap();
        assert_eq!(solves, 800);
        let parsed = parse_exposition(&m.exposition()).unwrap();
        let total: u64 = parsed
            .iter()
            .filter(|(k, _)| k.starts_with("fw_request_seconds"))
            .map(|(_, h)| h.count())
            .sum();
        assert_eq!(total, 800);
    }
}

//! Serving-workload traces: deterministic request streams for the
//! coordinator benches and the `serve_demo` example.
//!
//! A trace is a list of (arrival-offset, graph spec) pairs.  Arrivals are
//! Poisson (exponential gaps); graph sizes follow either a uniform-bucket
//! or heavy-tail (Zipf-like over buckets) distribution, matching the two
//! regimes a routing service sees: homogeneous fleets vs mixed tenants.

use std::time::Duration;

use crate::apsp::incremental::EdgeUpdate;
use crate::graph::{generators, DistMatrix};
use crate::util::prng::Rng;

/// Which generator family a trace item uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphKind {
    ErdosRenyi,
    Grid,
    ScaleFree,
}

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct TraceItem {
    /// Offset from trace start at which the request arrives.
    pub at: Duration,
    pub n: usize,
    pub kind: GraphKind,
    pub seed: u64,
    /// Edge-delta batch.  Empty = a plain solve of [`TraceItem::graph`];
    /// non-empty = an `"update"` request against the graph of the earlier
    /// trace item with the same `(n, kind, seed)` (the update regime emits
    /// that base as a plain solve first).  Successive batches against one
    /// base are meant to be applied cumulatively by the replayer, so a
    /// trace exercises the coordinator's delta chains.
    pub updates: Vec<EdgeUpdate>,
    /// Wire `"objective"` the request is sent under (a semiring name:
    /// `"shortest"`, `"bottleneck"`, `"minimax"`, `"reachability"`).
    /// Copied verbatim from the config — never drawn from the PRNG, so the
    /// pinned trace shapes are objective-independent.
    pub objective: String,
}

impl TraceItem {
    /// Materialize the graph (deterministic in the item's seed).
    pub fn graph(&self) -> DistMatrix {
        match self.kind {
            GraphKind::ErdosRenyi => generators::erdos_renyi(self.n, 0.3, self.seed),
            GraphKind::Grid => {
                let side = (self.n as f64).sqrt().round().max(2.0) as usize;
                generators::grid(side, self.seed)
            }
            GraphKind::ScaleFree => generators::scale_free(self.n.max(4), 2, self.seed),
        }
    }
}

/// Trace shape parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate, requests/second.
    pub rate_hz: f64,
    /// Number of requests.
    pub count: usize,
    /// Candidate sizes (typically just below the artifact buckets).
    pub sizes: Vec<usize>,
    /// Heavy-tail toward small sizes if true; uniform otherwise.
    pub heavy_tail: bool,
    /// Generator families the trace draws from (uniformly).
    pub kinds: Vec<GraphKind>,
    pub seed: u64,
    /// Fraction of items (after the warm-up bases) that are edge-delta
    /// update batches against an earlier base solve.  0.0 disables the
    /// regime — and draws nothing from the RNG for it, so pre-existing
    /// trace configs reproduce byte-identically across PRs.  Regimes using
    /// this must stick to size-preserving kinds (`ErdosRenyi`/`ScaleFree`
    /// with n ≥ 4): update endpoints are drawn from the item's `n`, and
    /// `Grid` rounds its vertex count to a square.
    pub update_fraction: f64,
    /// Edges per update batch.
    pub update_batch: usize,
    /// Objective every item in the trace is requested under.  Stamped onto
    /// items without consuming PRNG state, so changing it cannot perturb a
    /// trace's (n, kind, seed, updates) shape.
    pub objective: String,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate_hz: 50.0,
            count: 100,
            sizes: vec![48, 60, 100, 120, 200],
            heavy_tail: true,
            kinds: vec![GraphKind::ErdosRenyi, GraphKind::Grid, GraphKind::ScaleFree],
            seed: 0xACE,
            update_fraction: 0.0,
            update_batch: 4,
            objective: "shortest".into(),
        }
    }
}

impl TraceConfig {
    /// Large-n regime: every request is bigger than the largest artifact
    /// bucket (512 in the default build), so the whole trace exercises the
    /// coordinator's super-block tier.  Sizes model continental road
    /// networks — big and sparse — hence only the sparse generator
    /// families (a dense n=1024 edge list is megabytes of JSON on the
    /// wire for no modeling gain).
    pub fn large_n(seed: u64) -> TraceConfig {
        TraceConfig {
            rate_hz: 4.0,
            count: 8,
            sizes: vec![600, 768, 900, 1024],
            heavy_tail: false,
            kinds: vec![GraphKind::Grid, GraphKind::ScaleFree],
            seed,
            update_fraction: 0.0,
            update_batch: 4,
            objective: "shortest".into(),
        }
    }

    /// Update-heavy regime: a handful of base topologies each solved once,
    /// then a stream of small edge-delta batches against them — the
    /// dynamic-graph traffic shape the incremental tier exists for.
    /// Weights are multiples of 0.25 (with occasional deletions), keeping
    /// batch sums exactly representable; kinds are size-preserving so
    /// update endpoints always index into the materialized graph.
    pub fn update_heavy(seed: u64) -> TraceConfig {
        TraceConfig {
            rate_hz: 120.0,
            count: 48,
            sizes: vec![48, 96],
            heavy_tail: false,
            kinds: vec![GraphKind::ErdosRenyi, GraphKind::ScaleFree],
            seed,
            update_fraction: 0.8,
            update_batch: 4,
            objective: "shortest".into(),
        }
    }

    /// Bottleneck regime: widest-path traffic (capacity planning over the
    /// same topologies the default trace uses).  Non-shortest objectives
    /// are CPU/superblock-routed, so sizes stay modest; shape params other
    /// than the objective match the default regime for like-with-like
    /// latency comparisons.
    pub fn bottleneck(seed: u64) -> TraceConfig {
        TraceConfig {
            seed,
            objective: "bottleneck".into(),
            ..TraceConfig::default()
        }
    }

    /// Reachability regime: transitive-closure traffic (connectivity
    /// audits).  Edge weights are irrelevant under (or, and) — the solver
    /// maps them to booleans — so any generator family works unchanged.
    pub fn reachability(seed: u64) -> TraceConfig {
        TraceConfig {
            seed,
            objective: "reachability".into(),
            ..TraceConfig::default()
        }
    }

    /// Saturation regime: arrivals far beyond any fixed pool's service
    /// rate, built to drive the front end's bounded queue into admission
    /// control.  Sizes are small and uniform — the interesting signal is
    /// queueing (sheds, deadline expiries, queue-wait quantiles), so
    /// per-request solve cost stays cheap and homogeneous; one generator
    /// family keeps the offered load's variance down.  Pure solves: a shed
    /// update would conflate cache-miss retries with admission behaviour.
    pub fn saturation(seed: u64) -> TraceConfig {
        TraceConfig {
            rate_hz: 500.0,
            count: 64,
            sizes: vec![48, 64, 96],
            heavy_tail: false,
            kinds: vec![GraphKind::ErdosRenyi],
            seed,
            update_fraction: 0.0,
            update_batch: 4,
            objective: "shortest".into(),
        }
    }
}

/// Generate a deterministic trace.
///
/// When [`TraceConfig::update_fraction`] is positive, the first
/// `min(count, 3)` items are base solves; later items flip an
/// update-fraction coin and either reference one of those bases with a
/// fresh edge-delta batch or stay plain solves.  With the fraction at 0
/// none of the update draws happen, so legacy configs generate the exact
/// byte-identical traces they always did (pinned by the regression tests
/// below — bench trajectories across PRs must compare like with like).
pub fn generate(config: &TraceConfig) -> Vec<TraceItem> {
    assert!(!config.sizes.is_empty(), "trace needs candidate sizes");
    assert!(!config.kinds.is_empty(), "trace needs generator kinds");
    assert!(config.rate_hz > 0.0);
    let mut rng = Rng::new(config.seed);
    let mut at = 0f64;
    let n_bases = if config.update_fraction > 0.0 {
        config.count.min(3)
    } else {
        0
    };
    let mut bases: Vec<(usize, GraphKind, u64)> = Vec::new();
    let mut items = Vec::with_capacity(config.count);
    for i in 0..config.count {
        // exponential inter-arrival gap
        let u = (1.0 - rng.next_f64()).max(f64::MIN_POSITIVE);
        at += -u.ln() / config.rate_hz;
        // short-circuit order matters: with the regime off (or during the
        // warm-up bases) no update coin is drawn at all
        let is_update = i >= n_bases
            && !bases.is_empty()
            && config.update_fraction > 0.0
            && rng.next_f64() < config.update_fraction;
        if is_update {
            let (bn, bkind, bseed) = bases[rng.range(0, bases.len())];
            assert!(bn >= 2, "update regime needs n >= 2");
            let mut updates = Vec::with_capacity(config.update_batch.max(1));
            for _ in 0..config.update_batch.max(1) {
                let src = rng.range(0, bn);
                let mut dst = rng.range(0, bn - 1);
                if dst >= src {
                    dst += 1; // uniform over dst != src
                }
                // quarter-integer weights (exact sums); 1-in-8 deletions
                let weight = if rng.next_below(8) == 0 {
                    crate::INF
                } else {
                    (1 + rng.next_below(64)) as f32 * 0.25
                };
                updates.push(EdgeUpdate { src, dst, weight });
            }
            items.push(TraceItem {
                at: Duration::from_secs_f64(at),
                n: bn,
                kind: bkind,
                seed: bseed,
                updates,
                objective: config.objective.clone(),
            });
            continue;
        }
        let idx = if config.heavy_tail {
            // Zipf-ish: P(bucket k) ∝ 1/(k+1)
            let weights: Vec<f64> = (0..config.sizes.len())
                .map(|k| 1.0 / (k + 1) as f64)
                .collect();
            let total: f64 = weights.iter().sum();
            let mut pick = rng.next_f64() * total;
            let mut chosen = 0;
            for (k, w) in weights.iter().enumerate() {
                if pick < *w {
                    chosen = k;
                    break;
                }
                pick -= w;
            }
            chosen
        } else {
            rng.range(0, config.sizes.len())
        };
        let kind = config.kinds[rng.next_below(config.kinds.len() as u64) as usize];
        let seed = config.seed.wrapping_add(i as u64 * 7919);
        if i < n_bases {
            bases.push((config.sizes[idx], kind, seed));
        }
        items.push(TraceItem {
            at: Duration::from_secs_f64(at),
            n: config.sizes[idx],
            kind,
            seed,
            updates: Vec::new(),
            objective: config.objective.clone(),
        });
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        for cfg in [TraceConfig::default(), TraceConfig::update_heavy(0xFEED)] {
            let a = generate(&cfg);
            let b = generate(&cfg);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.at, y.at);
                assert_eq!(x.n, y.n);
                assert_eq!(x.kind, y.kind);
                assert_eq!(x.seed, y.seed);
                assert_eq!(x.updates, y.updates);
            }
        }
    }

    fn kind_tag(k: GraphKind) -> u8 {
        match k {
            GraphKind::ErdosRenyi => 0,
            GraphKind::Grid => 1,
            GraphKind::ScaleFree => 2,
        }
    }

    #[test]
    fn default_trace_head_pinned() {
        // frozen (n, kind, seed) triples, cross-computed with an
        // independent implementation of the PRNG and generator: bench
        // trajectories across PRs compare like with like only if the
        // trace a config names never silently changes — a generator edit
        // must fail here loudly (note the update machinery draws nothing
        // when update_fraction is 0, so this also pins that legacy
        // configs are byte-identical to their pre-dynamic-tier selves)
        let items = generate(&TraceConfig {
            count: 4,
            ..TraceConfig::default()
        });
        let shape: Vec<(usize, u8, u64)> =
            items.iter().map(|t| (t.n, kind_tag(t.kind), t.seed)).collect();
        assert_eq!(
            shape,
            vec![(60, 2, 2766), (60, 2, 10685), (48, 2, 18604), (100, 0, 26523)]
        );
        assert!(items.iter().all(|t| t.updates.is_empty()));
        assert!(items.iter().all(|t| t.objective == "shortest"));
    }

    #[test]
    fn objective_regimes_preserve_trace_shape() {
        // the objective is stamped on, never drawn from the PRNG: a
        // bottleneck/reachability trace over the same seed has the exact
        // (at, n, kind, seed, updates) shape as the shortest one
        let base = generate(&TraceConfig { seed: 0xACE, ..TraceConfig::default() });
        for cfg in [TraceConfig::bottleneck(0xACE), TraceConfig::reachability(0xACE)] {
            let want = cfg.objective.clone();
            let items = generate(&cfg);
            assert_eq!(items.len(), base.len());
            for (x, y) in items.iter().zip(&base) {
                assert_eq!(x.at, y.at);
                assert_eq!((x.n, x.kind, x.seed), (y.n, y.kind, y.seed));
                assert_eq!(x.updates, y.updates);
                assert_eq!(x.objective, want);
            }
        }
    }

    #[test]
    fn saturation_regime_shape() {
        // the regime must offer load, not variety: pure solves, small
        // uniform sizes, one generator family, sub-millisecond-scale
        // inter-arrival gaps (500 req/s) — and, like every regime, be
        // deterministic by seed
        let cfg = TraceConfig::saturation(0xBEEF);
        let items = generate(&cfg);
        assert_eq!(items.len(), 64);
        assert!(items.iter().all(|t| t.updates.is_empty()));
        assert!(items.iter().all(|t| t.objective == "shortest"));
        assert!(items.iter().all(|t| [48, 64, 96].contains(&t.n)));
        assert!(items.iter().all(|t| t.kind == GraphKind::ErdosRenyi));
        let span = items.last().unwrap().at - items[0].at;
        assert!(
            span < 1.0,
            "64 arrivals at 500 req/s should land within a second (got {span}s)"
        );
        let again = generate(&cfg);
        assert!(items
            .iter()
            .zip(&again)
            .all(|(x, y)| (x.at, x.n, x.kind, x.seed) == (y.at, y.n, y.kind, y.seed)));
    }

    #[test]
    fn update_heavy_trace_head_pinned() {
        // same contract for the new regime, updates included (weights are
        // quarter-integers, pinned as weight·4; -1 = deletion)
        let items = generate(&TraceConfig {
            count: 8,
            ..TraceConfig::update_heavy(0x5EED)
        });
        let shape: Vec<_> = items
            .iter()
            .map(|t| {
                (
                    t.n,
                    kind_tag(t.kind),
                    t.seed,
                    t.updates
                        .iter()
                        .map(|u| {
                            (
                                u.src,
                                u.dst,
                                if u.weight.is_finite() {
                                    (u.weight * 4.0) as i64
                                } else {
                                    -1
                                },
                            )
                        })
                        .collect::<Vec<(usize, usize, i64)>>(),
                )
            })
            .collect();
        assert_eq!(
            shape,
            vec![
                (96, 0, 24301, vec![]),
                (48, 0, 32220, vec![]),
                (96, 0, 40139, vec![]),
                (48, 2, 48058, vec![]),
                (96, 0, 24301, vec![(0, 54, 61), (15, 92, 18), (58, 85, -1), (90, 70, 45)]),
                (96, 0, 24301, vec![(50, 88, 15), (9, 35, 32), (67, 27, -1), (76, 43, 31)]),
                (96, 0, 71815, vec![]),
                (96, 0, 24301, vec![(83, 74, -1), (16, 36, 17), (23, 54, -1), (32, 63, 19)]),
            ]
        );
    }

    #[test]
    fn update_heavy_regime_shape() {
        let cfg = TraceConfig::update_heavy(7);
        let items = generate(&cfg);
        assert_eq!(items.len(), cfg.count);
        // warm-up: the first three items are plain base solves
        for item in &items[..3] {
            assert!(item.updates.is_empty());
        }
        let n_updates = items.iter().filter(|t| !t.updates.is_empty()).count();
        assert!(
            n_updates > cfg.count / 2,
            "update-heavy produced only {n_updates} update items"
        );
        let bases: Vec<(usize, GraphKind, u64)> =
            items[..3].iter().map(|t| (t.n, t.kind, t.seed)).collect();
        for item in items.iter().filter(|t| !t.updates.is_empty()) {
            assert!(
                bases.contains(&(item.n, item.kind, item.seed)),
                "update item references a non-base graph"
            );
            assert_eq!(item.updates.len(), cfg.update_batch);
            // kinds are size-preserving, so endpoints index the graph
            let g = item.graph();
            assert_eq!(g.n(), item.n);
            for u in &item.updates {
                assert!(u.src < item.n && u.dst < item.n && u.src != u.dst);
                assert!(
                    u.weight.is_infinite()
                        || (u.weight > 0.0 && (u.weight * 4.0).fract() == 0.0),
                    "weight {} not a quarter-integer",
                    u.weight
                );
            }
        }
    }

    #[test]
    fn arrivals_are_monotone() {
        let items = generate(&TraceConfig::default());
        for pair in items.windows(2) {
            assert!(pair[0].at <= pair[1].at);
        }
    }

    #[test]
    fn rate_roughly_respected() {
        let cfg = TraceConfig {
            rate_hz: 100.0,
            count: 2000,
            ..TraceConfig::default()
        };
        let items = generate(&cfg);
        let span = items.last().unwrap().at.as_secs_f64();
        let rate = cfg.count as f64 / span;
        assert!((70.0..140.0).contains(&rate), "empirical rate {rate}");
    }

    #[test]
    fn heavy_tail_prefers_small() {
        let cfg = TraceConfig {
            count: 1000,
            heavy_tail: true,
            ..TraceConfig::default()
        };
        let items = generate(&cfg);
        let smallest = cfg.sizes[0];
        let small_count = items.iter().filter(|i| i.n == smallest).count();
        assert!(
            small_count > items.len() / 3,
            "smallest bucket got {small_count}/{}",
            items.len()
        );
    }

    #[test]
    fn large_n_regime_exceeds_every_bucket() {
        let cfg = TraceConfig::large_n(7);
        let items = generate(&cfg);
        assert_eq!(items.len(), cfg.count);
        for item in &items {
            assert!(item.n > 512, "large-n trace produced n={}", item.n);
            assert!(
                matches!(item.kind, GraphKind::Grid | GraphKind::ScaleFree),
                "large-n traces stay sparse, got {:?}",
                item.kind
            );
        }
        // materialized graphs stay beyond the bucket ceiling too (grid
        // rounds n to a square) and validate structurally
        let g = items[0].graph();
        g.validate().unwrap();
        assert!(g.n() > 512);
    }

    #[test]
    fn graphs_materialize_and_validate() {
        let items = generate(&TraceConfig {
            count: 12,
            ..TraceConfig::default()
        });
        for item in items {
            let g = item.graph();
            g.validate().unwrap();
            assert!(g.n() >= 4);
        }
    }
}

//! Serving-workload traces: deterministic request streams for the
//! coordinator benches and the `serve_demo` example.
//!
//! A trace is a list of (arrival-offset, graph spec) pairs.  Arrivals are
//! Poisson (exponential gaps); graph sizes follow either a uniform-bucket
//! or heavy-tail (Zipf-like over buckets) distribution, matching the two
//! regimes a routing service sees: homogeneous fleets vs mixed tenants.

use std::time::Duration;

use crate::graph::{generators, DistMatrix};
use crate::util::prng::Rng;

/// Which generator family a trace item uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphKind {
    ErdosRenyi,
    Grid,
    ScaleFree,
}

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct TraceItem {
    /// Offset from trace start at which the request arrives.
    pub at: Duration,
    pub n: usize,
    pub kind: GraphKind,
    pub seed: u64,
}

impl TraceItem {
    /// Materialize the graph (deterministic in the item's seed).
    pub fn graph(&self) -> DistMatrix {
        match self.kind {
            GraphKind::ErdosRenyi => generators::erdos_renyi(self.n, 0.3, self.seed),
            GraphKind::Grid => {
                let side = (self.n as f64).sqrt().round().max(2.0) as usize;
                generators::grid(side, self.seed)
            }
            GraphKind::ScaleFree => generators::scale_free(self.n.max(4), 2, self.seed),
        }
    }
}

/// Trace shape parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate, requests/second.
    pub rate_hz: f64,
    /// Number of requests.
    pub count: usize,
    /// Candidate sizes (typically just below the artifact buckets).
    pub sizes: Vec<usize>,
    /// Heavy-tail toward small sizes if true; uniform otherwise.
    pub heavy_tail: bool,
    /// Generator families the trace draws from (uniformly).
    pub kinds: Vec<GraphKind>,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate_hz: 50.0,
            count: 100,
            sizes: vec![48, 60, 100, 120, 200],
            heavy_tail: true,
            kinds: vec![GraphKind::ErdosRenyi, GraphKind::Grid, GraphKind::ScaleFree],
            seed: 0xACE,
        }
    }
}

impl TraceConfig {
    /// Large-n regime: every request is bigger than the largest artifact
    /// bucket (512 in the default build), so the whole trace exercises the
    /// coordinator's super-block tier.  Sizes model continental road
    /// networks — big and sparse — hence only the sparse generator
    /// families (a dense n=1024 edge list is megabytes of JSON on the
    /// wire for no modeling gain).
    pub fn large_n(seed: u64) -> TraceConfig {
        TraceConfig {
            rate_hz: 4.0,
            count: 8,
            sizes: vec![600, 768, 900, 1024],
            heavy_tail: false,
            kinds: vec![GraphKind::Grid, GraphKind::ScaleFree],
            seed,
        }
    }
}

/// Generate a deterministic trace.
pub fn generate(config: &TraceConfig) -> Vec<TraceItem> {
    assert!(!config.sizes.is_empty(), "trace needs candidate sizes");
    assert!(!config.kinds.is_empty(), "trace needs generator kinds");
    assert!(config.rate_hz > 0.0);
    let mut rng = Rng::new(config.seed);
    let mut at = 0f64;
    let mut items = Vec::with_capacity(config.count);
    for i in 0..config.count {
        // exponential inter-arrival gap
        let u = (1.0 - rng.next_f64()).max(f64::MIN_POSITIVE);
        at += -u.ln() / config.rate_hz;
        let idx = if config.heavy_tail {
            // Zipf-ish: P(bucket k) ∝ 1/(k+1)
            let weights: Vec<f64> = (0..config.sizes.len())
                .map(|k| 1.0 / (k + 1) as f64)
                .collect();
            let total: f64 = weights.iter().sum();
            let mut pick = rng.next_f64() * total;
            let mut chosen = 0;
            for (k, w) in weights.iter().enumerate() {
                if pick < *w {
                    chosen = k;
                    break;
                }
                pick -= w;
            }
            chosen
        } else {
            rng.range(0, config.sizes.len())
        };
        let kind = config.kinds[rng.next_below(config.kinds.len() as u64) as usize];
        items.push(TraceItem {
            at: Duration::from_secs_f64(at),
            n: config.sizes[idx],
            kind,
            seed: config.seed.wrapping_add(i as u64 * 7919),
        });
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.n, y.n);
            assert_eq!(x.seed, y.seed);
        }
    }

    #[test]
    fn arrivals_are_monotone() {
        let items = generate(&TraceConfig::default());
        for pair in items.windows(2) {
            assert!(pair[0].at <= pair[1].at);
        }
    }

    #[test]
    fn rate_roughly_respected() {
        let cfg = TraceConfig {
            rate_hz: 100.0,
            count: 2000,
            ..TraceConfig::default()
        };
        let items = generate(&cfg);
        let span = items.last().unwrap().at.as_secs_f64();
        let rate = cfg.count as f64 / span;
        assert!((70.0..140.0).contains(&rate), "empirical rate {rate}");
    }

    #[test]
    fn heavy_tail_prefers_small() {
        let cfg = TraceConfig {
            count: 1000,
            heavy_tail: true,
            ..TraceConfig::default()
        };
        let items = generate(&cfg);
        let smallest = cfg.sizes[0];
        let small_count = items.iter().filter(|i| i.n == smallest).count();
        assert!(
            small_count > items.len() / 3,
            "smallest bucket got {small_count}/{}",
            items.len()
        );
    }

    #[test]
    fn large_n_regime_exceeds_every_bucket() {
        let cfg = TraceConfig::large_n(7);
        let items = generate(&cfg);
        assert_eq!(items.len(), cfg.count);
        for item in &items {
            assert!(item.n > 512, "large-n trace produced n={}", item.n);
            assert!(
                matches!(item.kind, GraphKind::Grid | GraphKind::ScaleFree),
                "large-n traces stay sparse, got {:?}",
                item.kind
            );
        }
        // materialized graphs stay beyond the bucket ceiling too (grid
        // rounds n to a square) and validate structurally
        let g = items[0].graph();
        g.validate().unwrap();
        assert!(g.n() > 512);
    }

    #[test]
    fn graphs_materialize_and_validate() {
        let items = generate(&TraceConfig {
            count: 12,
            ..TraceConfig::default()
        });
        for item in items {
            let g = item.graph();
            g.validate().unwrap();
            assert!(g.n() >= 4);
        }
    }
}

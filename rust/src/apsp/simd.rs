//! Explicit-SIMD lane kernels and the runtime ISA dispatch table.
//!
//! PR 4's register-tiled microkernel (`apsp::kernel`) leans on the
//! autovectorizer; the KNL blocked-FW case study (arxiv 1811.01201,
//! PAPERS.md) shows that making the phase-3 panel loop's lanes *explicit*
//! is where the remaining order of magnitude lives.  This module holds the
//! per-ISA `std::arch` implementations of the panel kernels — AVX2 8-wide
//! f32, AVX-512 16-wide, NEON 4-wide — plus the dispatch machinery that
//! picks one at startup:
//!
//! * [`Isa`] names a lane shape; [`Isa::available`] is the runtime feature
//!   check (`is_x86_feature_detected!` / aarch64 twin), so a binary built
//!   for a generic target still uses the best ISA of the machine it lands
//!   on.
//! * [`active`] resolves the process-wide choice **once** and caches it in
//!   a `OnceLock`: best available ISA, unless the `FW_KERNEL` env var
//!   (`scalar|avx2|avx512|neon`) overrides it.  An override naming an ISA
//!   the host lacks is *rejected with a typed error* ([`resolve`]) rather
//!   than faulting on an illegal instruction mid-solve; the CLI calls
//!   [`init_from_env`] at startup so the rejection is a clean exit.
//! * `kernel::panel` / `kernel::panel_succ` / `kernel::relax_row_semiring`
//!   dispatch through [`active`]; `kernel::panel_with` exposes an explicit
//!   ISA so benches and the conformance matrix can pin every compiled path
//!   in one process.
//!
//! **Why the lanes are bitwise-safe.**  Phase 3 is a pure ⊕-fold per output
//! cell over `k`-indexed candidates (see `apsp::kernel` module docs): for
//! the selection semirings every fold order is exact, and for `(min, +)`
//! f32 `min` over NaN-free, `-0.0`-free candidates is associative and
//! commutative *bitwise* — the `⊗`-additions happen per candidate, never
//! across lanes, so no sum is ever reassociated.  Widening the fold from
//! one accumulator to 8/16 lane accumulators therefore cannot perturb a
//! bit, and `kernel::panel_reference` stays the oracle for every ISA.  The
//! x86 `MINPS`/`MAXPS` tie rule (return the second operand) is invisible on
//! a domain where equal floats share one bit pattern (pinned by
//! `semiring::tests::lane_ops_are_bitwise_scalar_ops`).  The successor
//! twins keep the scalar accept semantics exactly: ascending `k`, strict
//! [`Semiring::improves`] compare-mask, per-lane successor select — so
//! values *and* successors match the scalar twin.
//!
//! Each vector kernel covers the lane-aligned column prefix and hands the
//! ragged remainder (`cols % lanes`) to the pinned scalar edge loop
//! (`kernel::micro_edge*`), so every cell is updated exactly once by an
//! equivalent fold; the AVX-512 value path instead retires its remainder
//! with native masked loads/stores, exercising the third remainder idiom.

use std::sync::OnceLock;

/// Env var overriding the dispatch table: `scalar|avx2|avx512|neon`.
/// Unset or empty means "best available".  A name the host cannot run is
/// rejected at [`resolve`] time with a typed error.
pub const ENV_KERNEL: &str = "FW_KERNEL";

/// A lane shape the panel kernels are compiled for.  `Scalar` is always
/// available; the SIMD variants exist only on their target arch and are
/// additionally gated by runtime feature detection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    /// The register-tiled scalar loops of `apsp::kernel` (the PR 4 path).
    Scalar,
    /// x86-64 AVX2: 8 × f32 lanes.
    Avx2,
    /// x86-64 AVX-512F: 16 × f32 lanes, native masked ragged edges.
    Avx512,
    /// aarch64 NEON: 4 × f32 lanes.
    Neon,
}

impl Isa {
    /// Every ISA name the dispatcher knows, in preference order (best
    /// last is *not* implied; see [`Isa::detect_best`]).
    pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon];

    /// Parse an `FW_KERNEL` value.
    pub fn parse(name: &str) -> Option<Isa> {
        match name {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" => Some(Isa::Avx512),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Canonical name (round-trips through [`Isa::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// f32 lanes per vector register (1 for scalar).
    pub fn lanes(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 8,
            Isa::Avx512 => 16,
            Isa::Neon => 4,
        }
    }

    /// Can this host execute this ISA's kernels right now?  Compile-target
    /// gate plus runtime CPUID/hwcap detection (the std macros cache their
    /// answer, so this is cheap enough for asserts on kernel entry).
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 | Isa::Avx512 => false,
            #[cfg(not(target_arch = "aarch64"))]
            Isa::Neon => false,
        }
    }

    /// The widest ISA this host can run — what [`active`] uses absent an
    /// override.
    pub fn detect_best() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if Isa::Avx512.available() {
                return Isa::Avx512;
            }
            if Isa::Avx2.available() {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if Isa::Neon.available() {
                return Isa::Neon;
            }
        }
        Isa::Scalar
    }
}

/// Every ISA this host can run, in [`Isa::ALL`] order (always contains
/// `Scalar`).  Benches and the conformance matrix iterate this to pin each
/// compiled path.
pub fn available_isas() -> Vec<Isa> {
    Isa::ALL.iter().copied().filter(|i| i.available()).collect()
}

/// Comma-joined [`available_isas`] names — for error messages and the CLI
/// `kernel` report.
pub fn available_names() -> String {
    available_isas()
        .iter()
        .map(|i| i.name())
        .collect::<Vec<_>>()
        .join(",")
}

/// Resolve a requested kernel name (the `FW_KERNEL` value, or `None` for
/// auto-detect) to a runnable ISA.  This is the satellite bugfix: an
/// override naming an unknown or host-unsupported ISA comes back as a
/// clear `Err` instead of an illegal-instruction fault the first time a
/// panel runs.  Pure (no env access, no caching) so tests can probe every
/// case without process-global state.
pub fn resolve(requested: Option<&str>) -> Result<Isa, String> {
    match requested {
        None | Some("") => Ok(Isa::detect_best()),
        Some(name) => {
            let isa = Isa::parse(name).ok_or_else(|| {
                format!(
                    "{ENV_KERNEL}={name:?} is not a known kernel ISA \
                     (expected scalar, avx2, avx512, or neon)"
                )
            })?;
            if !isa.available() {
                return Err(format!(
                    "{ENV_KERNEL}={} names an ISA this host cannot execute \
                     (available: {}); refusing to dispatch rather than fault \
                     on an illegal instruction",
                    isa.name(),
                    available_names()
                ));
            }
            Ok(isa)
        }
    }
}

static ACTIVE: OnceLock<Isa> = OnceLock::new();

/// Validate `FW_KERNEL` and seed the dispatch table, returning the ISA the
/// process will use.  The CLI calls this before touching any solver so a
/// bad override is a clean startup error.  First caller wins: once the
/// table is set (by this or by a solve racing through [`active`]) the
/// choice is process-wide and permanent.
pub fn init_from_env() -> Result<Isa, String> {
    let requested = std::env::var(ENV_KERNEL).ok();
    let isa = resolve(requested.as_deref())?;
    Ok(*ACTIVE.get_or_init(|| isa))
}

/// The process-wide kernel ISA, resolving and caching on first use.
/// Panics if `FW_KERNEL` names an unusable ISA and nothing called
/// [`init_from_env`] first — library embedders who set the env var should
/// pre-validate the same way the CLI does.
pub fn active() -> Isa {
    *ACTIVE.get_or_init(|| {
        let requested = std::env::var(ENV_KERNEL).ok();
        match resolve(requested.as_deref()) {
            Ok(isa) => isa,
            Err(e) => panic!("{e}"),
        }
    })
}

// ------------------------------------------------------------- x86-64 ---

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    //! AVX2 (8-lane) and AVX-512F (16-lane) panel kernels.  All functions
    //! are `unsafe` solely for the `#[target_feature]` contract; slice
    //! geometry is the same as the scalar kernels'.

    use std::arch::x86_64::*;

    use crate::apsp::kernel::{self, MR};
    use crate::apsp::semiring::{LaneOp, Semiring};

    /// AVX2 f32 lanes per register.
    pub const W256: usize = 8;
    /// AVX-512 f32 lanes per register.
    pub const W512: usize = 16;

    /// One 8-lane semiring op.  The match is on an associated const, so
    /// after monomorphization each call site is a single instruction.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vop256(op: LaneOp, a: __m256, b: __m256) -> __m256 {
        match op {
            LaneOp::Min => _mm256_min_ps(a, b),
            LaneOp::Max => _mm256_max_ps(a, b),
            LaneOp::Add => _mm256_add_ps(a, b),
        }
    }

    /// 8-lane strict-improves mask: `⊕` is a selection, so `cand` strictly
    /// beats `cur` iff it wins the ordered compare in the combine
    /// direction (`<` for `Min`, `>` for `Max`) — exactly
    /// [`Semiring::improves`] per lane.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vimproves256(combine: LaneOp, cand: __m256, cur: __m256) -> __m256 {
        match combine {
            LaneOp::Min => _mm256_cmp_ps::<_CMP_LT_OQ>(cand, cur),
            _ => _mm256_cmp_ps::<_CMP_GT_OQ>(cand, cur),
        }
    }

    /// One 16-lane semiring op.
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn vop512(op: LaneOp, a: __m512, b: __m512) -> __m512 {
        match op {
            LaneOp::Min => _mm512_min_ps(a, b),
            LaneOp::Max => _mm512_max_ps(a, b),
            LaneOp::Add => _mm512_add_ps(a, b),
        }
    }

    /// 16-lane strict-improves predicate mask.
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn vimproves512(combine: LaneOp, cand: __m512, cur: __m512) -> __mmask16 {
        match combine {
            LaneOp::Min => _mm512_cmp_ps_mask::<_CMP_LT_OQ>(cand, cur),
            _ => _mm512_cmp_ps_mask::<_CMP_GT_OQ>(cand, cur),
        }
    }

    /// AVX2 phase-3 panel: `MR` rows × 8 lanes of `⊕`-accumulators per
    /// step over the lane-aligned column prefix, remainder rows one vector
    /// row at a time, ragged columns via the pinned scalar edge.
    ///
    /// # Safety
    ///
    /// The host must support AVX2 ([`super::Isa::Avx2`]`.available()`), and
    /// the slice geometry must satisfy the `kernel::panel` contract
    /// (disjoint `rows × kk` col panel at `col_stride`, `kk × cols` row
    /// panel at `row_stride`, `rows × cols` dst at `dst_stride`, all
    /// in-bounds).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn panel_avx2<S: Semiring>(
        dst: &mut [f32],
        dst_stride: usize,
        col: &[f32],
        col_stride: usize,
        row: &[f32],
        row_stride: usize,
        rows: usize,
        cols: usize,
        kk: usize,
    ) {
        let full = cols - cols % W256;
        let mut r0 = 0;
        while r0 + MR <= rows {
            let mut c0 = 0;
            while c0 < full {
                let mut acc = [_mm256_setzero_ps(); MR];
                for (r, a) in acc.iter_mut().enumerate() {
                    *a = _mm256_loadu_ps(dst.as_ptr().add((r0 + r) * dst_stride + c0));
                }
                for k in 0..kk {
                    let a0 = col[r0 * col_stride + k];
                    let a1 = col[(r0 + 1) * col_stride + k];
                    let a2 = col[(r0 + 2) * col_stride + k];
                    let a3 = col[(r0 + 3) * col_stride + k];
                    // hoisted annihilator guard — same bitwise no-op skip
                    // as the scalar micro_full (see kernel module docs)
                    if S::is_zero(S::combine(S::combine(S::combine(a0, a1), a2), a3)) {
                        continue;
                    }
                    let rv = _mm256_loadu_ps(row.as_ptr().add(k * row_stride + c0));
                    for (acc_r, a) in acc.iter_mut().zip([a0, a1, a2, a3]) {
                        let cand = vop256(S::EXTEND_OP, _mm256_set1_ps(a), rv);
                        *acc_r = vop256(S::COMBINE_OP, *acc_r, cand);
                    }
                }
                for (r, a) in acc.iter().enumerate() {
                    _mm256_storeu_ps(dst.as_mut_ptr().add((r0 + r) * dst_stride + c0), *a);
                }
                c0 += W256;
            }
            r0 += MR;
        }
        while r0 < rows {
            let mut c0 = 0;
            while c0 < full {
                let mut acc = _mm256_loadu_ps(dst.as_ptr().add(r0 * dst_stride + c0));
                for k in 0..kk {
                    let a = col[r0 * col_stride + k];
                    if S::is_zero(a) {
                        continue;
                    }
                    let rv = _mm256_loadu_ps(row.as_ptr().add(k * row_stride + c0));
                    acc = vop256(S::COMBINE_OP, acc, vop256(S::EXTEND_OP, _mm256_set1_ps(a), rv));
                }
                _mm256_storeu_ps(dst.as_mut_ptr().add(r0 * dst_stride + c0), acc);
                c0 += W256;
            }
            r0 += 1;
        }
        if full < cols {
            // mid-panel ragged fallback: cols % 8 columns for every row go
            // through the pinned scalar edge loop
            kernel::micro_edge::<S>(
                &mut dst[full..],
                dst_stride,
                col,
                col_stride,
                &row[full..],
                row_stride,
                rows,
                cols - full,
                kk,
            );
        }
    }

    /// AVX2 successor twin: ascending `k`, 8-lane strict compare-mask
    /// accept ([`vimproves256`]), blend for values, per-set-bit scalar
    /// writes for successors — the exact scalar accept sequence.
    ///
    /// # Safety
    ///
    /// As [`panel_avx2`]; `dsucc` shares `dst_stride`, `colsucc` shares
    /// `col_stride`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn panel_succ_avx2<S: Semiring>(
        dst: &mut [f32],
        dsucc: &mut [usize],
        dst_stride: usize,
        col: &[f32],
        colsucc: &[usize],
        col_stride: usize,
        row: &[f32],
        row_stride: usize,
        rows: usize,
        cols: usize,
        kk: usize,
    ) {
        let full = cols - cols % W256;
        for r in 0..rows {
            let mut c0 = 0;
            while c0 < full {
                let base = r * dst_stride + c0;
                let mut acc = _mm256_loadu_ps(dst.as_ptr().add(base));
                for k in 0..kk {
                    let a = col[r * col_stride + k];
                    if S::is_zero(a) {
                        continue;
                    }
                    let rv = _mm256_loadu_ps(row.as_ptr().add(k * row_stride + c0));
                    let cand = vop256(S::EXTEND_OP, _mm256_set1_ps(a), rv);
                    let mask = vimproves256(S::COMBINE_OP, cand, acc);
                    let bits = _mm256_movemask_ps(mask);
                    if bits != 0 {
                        acc = _mm256_blendv_ps(acc, cand, mask);
                        let sr = colsucc[r * col_stride + k];
                        for c in 0..W256 {
                            if bits & (1 << c) != 0 {
                                dsucc[base + c] = sr;
                            }
                        }
                    }
                }
                _mm256_storeu_ps(dst.as_mut_ptr().add(base), acc);
                c0 += W256;
            }
        }
        if full < cols {
            kernel::micro_edge_succ::<S>(
                &mut dst[full..],
                &mut dsucc[full..],
                dst_stride,
                col,
                colsucc,
                col_stride,
                &row[full..],
                row_stride,
                rows,
                cols - full,
                kk,
            );
        }
    }

    /// AVX2 branchless row sweep (`kernel::relax_row_semiring` shape).
    ///
    /// # Safety
    ///
    /// The host must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn relax_row_avx2<S: Semiring>(out: &mut [f32], row_k: &[f32], wik: f32) {
        let len = out.len().min(row_k.len());
        let wv = _mm256_set1_ps(wik);
        let mut j = 0;
        while j + W256 <= len {
            let o = _mm256_loadu_ps(out.as_ptr().add(j));
            let rv = _mm256_loadu_ps(row_k.as_ptr().add(j));
            let folded = vop256(S::COMBINE_OP, o, vop256(S::EXTEND_OP, wv, rv));
            _mm256_storeu_ps(out.as_mut_ptr().add(j), folded);
            j += W256;
        }
        while j < len {
            out[j] = S::combine(out[j], S::extend(wik, row_k[j]));
            j += 1;
        }
    }

    /// AVX-512F phase-3 panel: 16-lane accumulators; the ragged column
    /// remainder is retired in-vector with native masked loads/stores
    /// (`(1 << rem) - 1` lane mask) instead of a scalar edge loop — masked
    /// lanes are never read back or stored, so the fold per live cell is
    /// unchanged.
    ///
    /// # Safety
    ///
    /// The host must support AVX-512F; slice geometry as [`panel_avx2`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn panel_avx512<S: Semiring>(
        dst: &mut [f32],
        dst_stride: usize,
        col: &[f32],
        col_stride: usize,
        row: &[f32],
        row_stride: usize,
        rows: usize,
        cols: usize,
        kk: usize,
    ) {
        let full = cols - cols % W512;
        let rem = cols - full;
        let tail_mask: __mmask16 = if rem == 0 { 0 } else { (1u16 << rem) - 1 };
        for r in 0..rows {
            let mut c0 = 0;
            while c0 < full {
                let base = r * dst_stride + c0;
                let mut acc = _mm512_loadu_ps(dst.as_ptr().add(base));
                for k in 0..kk {
                    let a = col[r * col_stride + k];
                    if S::is_zero(a) {
                        continue;
                    }
                    let rv = _mm512_loadu_ps(row.as_ptr().add(k * row_stride + c0));
                    acc = vop512(S::COMBINE_OP, acc, vop512(S::EXTEND_OP, _mm512_set1_ps(a), rv));
                }
                _mm512_storeu_ps(dst.as_mut_ptr().add(base), acc);
                c0 += W512;
            }
            if rem != 0 {
                let base = r * dst_stride + full;
                let mut acc = _mm512_maskz_loadu_ps(tail_mask, dst.as_ptr().add(base));
                for k in 0..kk {
                    let a = col[r * col_stride + k];
                    if S::is_zero(a) {
                        continue;
                    }
                    let rv = _mm512_maskz_loadu_ps(tail_mask, row.as_ptr().add(k * row_stride + full));
                    let cand = vop512(S::EXTEND_OP, _mm512_set1_ps(a), rv);
                    // dead lanes compute garbage but tail_mask keeps them
                    // out of the store below
                    acc = vop512(S::COMBINE_OP, acc, cand);
                }
                _mm512_mask_storeu_ps(dst.as_mut_ptr().add(base), tail_mask, acc);
            }
        }
    }

    /// AVX-512F successor twin: predicate-mask strict accept
    /// (`_mm512_cmp_ps_mask`), masked blend for values, per-set-bit scalar
    /// successor writes.  Ragged columns go through the pinned scalar edge
    /// (succ lanes want the mask and blend anyway; the maskz idiom buys
    /// nothing here).
    ///
    /// # Safety
    ///
    /// As [`panel_avx512`]; successor slices share their value strides.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn panel_succ_avx512<S: Semiring>(
        dst: &mut [f32],
        dsucc: &mut [usize],
        dst_stride: usize,
        col: &[f32],
        colsucc: &[usize],
        col_stride: usize,
        row: &[f32],
        row_stride: usize,
        rows: usize,
        cols: usize,
        kk: usize,
    ) {
        let full = cols - cols % W512;
        for r in 0..rows {
            let mut c0 = 0;
            while c0 < full {
                let base = r * dst_stride + c0;
                let mut acc = _mm512_loadu_ps(dst.as_ptr().add(base));
                for k in 0..kk {
                    let a = col[r * col_stride + k];
                    if S::is_zero(a) {
                        continue;
                    }
                    let rv = _mm512_loadu_ps(row.as_ptr().add(k * row_stride + c0));
                    let cand = vop512(S::EXTEND_OP, _mm512_set1_ps(a), rv);
                    let mask = vimproves512(S::COMBINE_OP, cand, acc);
                    if mask != 0 {
                        acc = _mm512_mask_blend_ps(mask, acc, cand);
                        let sr = colsucc[r * col_stride + k];
                        for c in 0..W512 {
                            if mask & (1u16 << c) != 0 {
                                dsucc[base + c] = sr;
                            }
                        }
                    }
                }
                _mm512_storeu_ps(dst.as_mut_ptr().add(base), acc);
                c0 += W512;
            }
        }
        if full < cols {
            kernel::micro_edge_succ::<S>(
                &mut dst[full..],
                &mut dsucc[full..],
                dst_stride,
                col,
                colsucc,
                col_stride,
                &row[full..],
                row_stride,
                rows,
                cols - full,
                kk,
            );
        }
    }

    /// AVX-512F branchless row sweep.
    ///
    /// # Safety
    ///
    /// The host must support AVX-512F.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn relax_row_avx512<S: Semiring>(out: &mut [f32], row_k: &[f32], wik: f32) {
        let len = out.len().min(row_k.len());
        let wv = _mm512_set1_ps(wik);
        let mut j = 0;
        while j + W512 <= len {
            let o = _mm512_loadu_ps(out.as_ptr().add(j));
            let rv = _mm512_loadu_ps(row_k.as_ptr().add(j));
            let folded = vop512(S::COMBINE_OP, o, vop512(S::EXTEND_OP, wv, rv));
            _mm512_storeu_ps(out.as_mut_ptr().add(j), folded);
            j += W512;
        }
        if j < len {
            let rem = len - j;
            let tail_mask: __mmask16 = (1u16 << rem) - 1;
            let o = _mm512_maskz_loadu_ps(tail_mask, out.as_ptr().add(j));
            let rv = _mm512_maskz_loadu_ps(tail_mask, row_k.as_ptr().add(j));
            let folded = vop512(S::COMBINE_OP, o, vop512(S::EXTEND_OP, wv, rv));
            _mm512_mask_storeu_ps(out.as_mut_ptr().add(j), tail_mask, folded);
        }
    }
}

// ------------------------------------------------------------ aarch64 ---

#[cfg(target_arch = "aarch64")]
pub(crate) mod arm {
    //! NEON (4-lane) panel kernels — same structure as the AVX2 paths at
    //! quarter width.

    use std::arch::aarch64::*;

    use crate::apsp::kernel::{self, MR};
    use crate::apsp::semiring::{LaneOp, Semiring};

    /// NEON f32 lanes per register.
    pub const W128: usize = 4;

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn vop128(op: LaneOp, a: float32x4_t, b: float32x4_t) -> float32x4_t {
        match op {
            LaneOp::Min => vminq_f32(a, b),
            LaneOp::Max => vmaxq_f32(a, b),
            LaneOp::Add => vaddq_f32(a, b),
        }
    }

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn vimproves128(combine: LaneOp, cand: float32x4_t, cur: float32x4_t) -> uint32x4_t {
        match combine {
            LaneOp::Min => vcltq_f32(cand, cur),
            _ => vcgtq_f32(cand, cur),
        }
    }

    /// NEON phase-3 panel: `MR` rows × 4 lanes, scalar edge for ragged
    /// columns.
    ///
    /// # Safety
    ///
    /// The host must support NEON; slice geometry as `kernel::panel`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn panel_neon<S: Semiring>(
        dst: &mut [f32],
        dst_stride: usize,
        col: &[f32],
        col_stride: usize,
        row: &[f32],
        row_stride: usize,
        rows: usize,
        cols: usize,
        kk: usize,
    ) {
        let full = cols - cols % W128;
        let mut r0 = 0;
        while r0 + MR <= rows {
            let mut c0 = 0;
            while c0 < full {
                let mut acc = [vdupq_n_f32(0.0); MR];
                for (r, a) in acc.iter_mut().enumerate() {
                    *a = vld1q_f32(dst.as_ptr().add((r0 + r) * dst_stride + c0));
                }
                for k in 0..kk {
                    let a0 = col[r0 * col_stride + k];
                    let a1 = col[(r0 + 1) * col_stride + k];
                    let a2 = col[(r0 + 2) * col_stride + k];
                    let a3 = col[(r0 + 3) * col_stride + k];
                    if S::is_zero(S::combine(S::combine(S::combine(a0, a1), a2), a3)) {
                        continue;
                    }
                    let rv = vld1q_f32(row.as_ptr().add(k * row_stride + c0));
                    for (acc_r, a) in acc.iter_mut().zip([a0, a1, a2, a3]) {
                        let cand = vop128(S::EXTEND_OP, vdupq_n_f32(a), rv);
                        *acc_r = vop128(S::COMBINE_OP, *acc_r, cand);
                    }
                }
                for (r, a) in acc.iter().enumerate() {
                    vst1q_f32(dst.as_mut_ptr().add((r0 + r) * dst_stride + c0), *a);
                }
                c0 += W128;
            }
            r0 += MR;
        }
        while r0 < rows {
            let mut c0 = 0;
            while c0 < full {
                let mut acc = vld1q_f32(dst.as_ptr().add(r0 * dst_stride + c0));
                for k in 0..kk {
                    let a = col[r0 * col_stride + k];
                    if S::is_zero(a) {
                        continue;
                    }
                    let rv = vld1q_f32(row.as_ptr().add(k * row_stride + c0));
                    acc = vop128(S::COMBINE_OP, acc, vop128(S::EXTEND_OP, vdupq_n_f32(a), rv));
                }
                vst1q_f32(dst.as_mut_ptr().add(r0 * dst_stride + c0), acc);
                c0 += W128;
            }
            r0 += 1;
        }
        if full < cols {
            kernel::micro_edge::<S>(
                &mut dst[full..],
                dst_stride,
                col,
                col_stride,
                &row[full..],
                row_stride,
                rows,
                cols - full,
                kk,
            );
        }
    }

    /// NEON successor twin: 4-lane strict compare mask, bit-select blend,
    /// per-set-lane scalar successor writes.
    ///
    /// # Safety
    ///
    /// As [`panel_neon`]; successor slices share their value strides.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn panel_succ_neon<S: Semiring>(
        dst: &mut [f32],
        dsucc: &mut [usize],
        dst_stride: usize,
        col: &[f32],
        colsucc: &[usize],
        col_stride: usize,
        row: &[f32],
        row_stride: usize,
        rows: usize,
        cols: usize,
        kk: usize,
    ) {
        let full = cols - cols % W128;
        for r in 0..rows {
            let mut c0 = 0;
            while c0 < full {
                let base = r * dst_stride + c0;
                let mut acc = vld1q_f32(dst.as_ptr().add(base));
                for k in 0..kk {
                    let a = col[r * col_stride + k];
                    if S::is_zero(a) {
                        continue;
                    }
                    let rv = vld1q_f32(row.as_ptr().add(k * row_stride + c0));
                    let cand = vop128(S::EXTEND_OP, vdupq_n_f32(a), rv);
                    let mask = vimproves128(S::COMBINE_OP, cand, acc);
                    let mut mbits = [0u32; W128];
                    vst1q_u32(mbits.as_mut_ptr(), mask);
                    if mbits.iter().any(|m| *m != 0) {
                        acc = vbslq_f32(mask, cand, acc);
                        let sr = colsucc[r * col_stride + k];
                        for (c, m) in mbits.iter().enumerate() {
                            if *m != 0 {
                                dsucc[base + c] = sr;
                            }
                        }
                    }
                }
                vst1q_f32(dst.as_mut_ptr().add(base), acc);
                c0 += W128;
            }
        }
        if full < cols {
            kernel::micro_edge_succ::<S>(
                &mut dst[full..],
                &mut dsucc[full..],
                dst_stride,
                col,
                colsucc,
                col_stride,
                &row[full..],
                row_stride,
                rows,
                cols - full,
                kk,
            );
        }
    }

    /// NEON branchless row sweep.
    ///
    /// # Safety
    ///
    /// The host must support NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn relax_row_neon<S: Semiring>(out: &mut [f32], row_k: &[f32], wik: f32) {
        let len = out.len().min(row_k.len());
        let wv = vdupq_n_f32(wik);
        let mut j = 0;
        while j + W128 <= len {
            let o = vld1q_f32(out.as_ptr().add(j));
            let rv = vld1q_f32(row_k.as_ptr().add(j));
            let folded = vop128(S::COMBINE_OP, o, vop128(S::EXTEND_OP, wv, rv));
            vst1q_f32(out.as_mut_ptr().add(j), folded);
            j += W128;
        }
        while j < len {
            out[j] = S::combine(out[j], S::extend(wik, row_k[j]));
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_names_round_trip_and_report_lanes() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("sse2"), None);
        assert_eq!(Isa::parse("AVX2"), None, "names are case-sensitive");
        assert_eq!(Isa::Scalar.lanes(), 1);
        assert_eq!(Isa::Avx2.lanes(), 8);
        assert_eq!(Isa::Avx512.lanes(), 16);
        assert_eq!(Isa::Neon.lanes(), 4);
    }

    #[test]
    fn resolve_rejects_unknown_and_unavailable() {
        // satellite bugfix: both failure modes are typed errors, never a
        // fault
        let unknown = resolve(Some("sse9")).unwrap_err();
        assert!(unknown.contains("FW_KERNEL"), "{unknown}");
        assert!(unknown.contains("not a known"), "{unknown}");
        // an ISA from the other architecture family is never available,
        // making the unavailability arm deterministic on every host
        #[cfg(target_arch = "x86_64")]
        let foreign = "neon";
        #[cfg(not(target_arch = "x86_64"))]
        let foreign = "avx2";
        let unavailable = resolve(Some(foreign)).unwrap_err();
        assert!(unavailable.contains("cannot execute"), "{unavailable}");
        assert!(unavailable.contains("scalar"), "lists the alternatives: {unavailable}");
    }

    #[test]
    fn resolve_accepts_auto_scalar_and_every_available_isa() {
        assert_eq!(resolve(None).unwrap(), Isa::detect_best());
        assert_eq!(resolve(Some("")).unwrap(), Isa::detect_best());
        assert_eq!(resolve(Some("scalar")).unwrap(), Isa::Scalar);
        for isa in available_isas() {
            assert_eq!(resolve(Some(isa.name())).unwrap(), isa);
        }
    }

    #[test]
    fn detection_is_coherent() {
        assert!(Isa::Scalar.available());
        let best = Isa::detect_best();
        assert!(best.available());
        let avail = available_isas();
        assert!(avail.contains(&Isa::Scalar));
        assert!(avail.contains(&best));
        assert!(available_names().contains("scalar"));
        // the active table resolves to something runnable and is stable
        let a = active();
        assert!(a.available());
        assert_eq!(a, active());
        assert_eq!(init_from_env().unwrap(), a, "init after first use returns the cached pick");
    }
}

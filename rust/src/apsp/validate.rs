//! APSP result validation: structural invariants any correct solver output
//! must satisfy, plus negative-cycle detection.
//!
//! Used by the coordinator (optional response validation), the integration
//! tests (device results vs invariants, not just vs oracle), and the
//! property tests.

use crate::graph::DistMatrix;

/// Check the invariants of an APSP *result* `d` for *input* `w`:
///
/// 1. `d[i][j] ≤ w[i][j]` (a relaxation never lengthens),
/// 2. `d[i][i] == 0` (absent negative cycles),
/// 3. triangle inequality `d[i][j] ≤ d[i][k] + d[k][j]` (+ f32 slack),
/// 4. no NaN / -inf,
/// 5. reachability closure: `d[i][j]` finite iff j reachable from i in `w`
///    (checked via BFS on the support graph).
///
/// Returns the first violation as a human-readable string.
pub fn check_invariants(w: &DistMatrix, d: &DistMatrix) -> Result<(), String> {
    let n = w.n();
    if d.n() != n {
        return Err(format!("result size {} != input size {n}", d.n()));
    }
    d.validate()?;
    // (1) and (2)
    for i in 0..n {
        if d.get(i, i) != 0.0 {
            return Err(format!("d[{i}][{i}] = {} != 0", d.get(i, i)));
        }
        for j in 0..n {
            if d.get(i, j) > w.get(i, j) {
                return Err(format!(
                    "lengthened: d[{i}][{j}] = {} > w = {}",
                    d.get(i, j),
                    w.get(i, j)
                ));
            }
        }
    }
    // (3) triangle inequality with f32 tolerance
    for k in 0..n {
        for i in 0..n {
            let dik = d.get(i, k);
            if !dik.is_finite() {
                continue;
            }
            for j in 0..n {
                let lhs = d.get(i, j) as f64;
                let rhs = dik as f64 + d.get(k, j) as f64;
                if lhs > rhs + 1e-3 + 1e-5 * rhs.abs() {
                    return Err(format!(
                        "triangle violated: d[{i}][{j}]={lhs} > d[{i}][{k}]+d[{k}][{j}]={rhs}"
                    ));
                }
            }
        }
    }
    // (5) reachability closure
    for i in 0..n {
        let reach = bfs_reach(w, i);
        for j in 0..n {
            let finite = d.get(i, j).is_finite();
            if finite != reach[j] {
                return Err(format!(
                    "reachability mismatch at ({i},{j}): dist finite={finite}, BFS={}",
                    reach[j]
                ));
            }
        }
    }
    Ok(())
}

/// Vertices on or reaching a negative cycle: `d[i][i] < 0` after closure.
/// (Run on a *solved* matrix.)
pub fn negative_cycle_vertices(d: &DistMatrix) -> Vec<usize> {
    (0..d.n()).filter(|&i| d.get(i, i) < 0.0).collect()
}

/// Does the input graph contain a negative cycle? (solves a copy)
pub fn has_negative_cycle(w: &DistMatrix) -> bool {
    let d = super::naive::solve(w);
    !negative_cycle_vertices(&d).is_empty()
}

fn bfs_reach(w: &DistMatrix, src: usize) -> Vec<bool> {
    let n = w.n();
    let mut seen = vec![false; n];
    let mut queue = std::collections::VecDeque::from([src]);
    seen[src] = true;
    while let Some(u) = queue.pop_front() {
        for v in 0..n {
            if !seen[v] && w.get(u, v).is_finite() {
                seen[v] = true;
                queue.push_back(v);
            }
        }
    }
    seen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::{blocked, naive, parallel};
    use crate::graph::{generators, DistMatrix};

    #[test]
    fn all_solvers_pass_invariants() {
        let g = generators::erdos_renyi(64, 0.25, 61);
        for d in [
            naive::solve(&g),
            blocked::solve(&g, 16),
            blocked::solve(&g, 32),
            parallel::solve(&g, 16, 4),
        ] {
            check_invariants(&g, &d).unwrap();
        }
    }

    #[test]
    fn detects_lengthening() {
        let g = generators::ring(8);
        let mut d = naive::solve(&g);
        d.set(0, 1, 99.0);
        assert!(check_invariants(&g, &d).unwrap_err().contains("lengthened"));
    }

    #[test]
    fn detects_triangle_violation() {
        let g = generators::erdos_renyi(16, 0.8, 63);
        let mut d = naive::solve(&g);
        // raise one entry enough to break the triangle inequality but stay
        // below the input weight (so the 'lengthened' check doesn't fire first)
        let mut broke = false;
        'outer: for i in 0..16 {
            for j in 0..16 {
                if i != j && g.get(i, j).is_finite() && d.get(i, j) + 1.0 < g.get(i, j) {
                    d.set(i, j, g.get(i, j) - 0.001);
                    broke = true;
                    break 'outer;
                }
            }
        }
        assert!(broke, "test graph had no slack edge");
        assert!(check_invariants(&g, &d)
            .unwrap_err()
            .contains("triangle violated"));
    }

    #[test]
    fn detects_wrong_reachability() {
        let g = generators::ring(6);
        let mut d = naive::solve(&g);
        d.set(2, 3, f32::INFINITY); // 3 is reachable from 2 in a ring
        let err = check_invariants(&g, &d).unwrap_err();
        assert!(
            err.contains("reachability") || err.contains("lengthened"),
            "{err}"
        );
    }

    #[test]
    fn detects_nonzero_diag() {
        let g = generators::ring(4);
        let mut d = naive::solve(&g);
        d.set(1, 1, -0.5);
        assert!(check_invariants(&g, &d).unwrap_err().contains("!= 0"));
    }

    #[test]
    fn negative_cycle_detection() {
        let mut g = DistMatrix::unconnected(4);
        g.set(0, 1, 1.0);
        g.set(1, 2, -3.0);
        g.set(2, 0, 1.0); // cycle 0→1→2→0 weighs -1
        assert!(has_negative_cycle(&g));
        let no = generators::layered_dag(4, 4, 3); // negative edges, no cycles
        assert!(!has_negative_cycle(&no));
    }

    #[test]
    fn size_mismatch_rejected() {
        let g = generators::ring(4);
        let d = DistMatrix::unconnected(5);
        assert!(check_invariants(&g, &d).is_err());
    }
}

//! Johnson's algorithm — the sparse-graph APSP comparator.
//!
//! Floyd-Warshall is Θ(n³) regardless of density (the property the paper
//! leans on); Johnson's algorithm runs in O(n·m·log n) and wins on sparse
//! graphs.  A production APSP service should know the crossover, so this
//! solver exists both as a correctness oracle from a different algorithmic
//! family and as a routing option (`variant = "johnson"`).
//!
//! Pipeline: Bellman–Ford from a virtual source (computes the reweighting
//! potentials and detects negative cycles exactly), reweight
//! `ŵ(u,v) = w(u,v) + h(u) − h(v) ≥ 0`, then one binary-heap Dijkstra per
//! source, un-reweighting on output.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::graph::DistMatrix;
use crate::{Dist, INF};

/// Adjacency-list edge.
#[derive(Clone, Copy, Debug)]
struct Edge {
    to: u32,
    w: f32,
}

/// Errors Johnson can hit that FW silently tolerates.
#[derive(Debug, PartialEq)]
pub enum JohnsonError {
    NegativeCycle(usize),
}

impl std::fmt::Display for JohnsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JohnsonError::NegativeCycle(v) => {
                write!(f, "graph contains a negative cycle (vertex {v} improves on pass n)")
            }
        }
    }
}

impl std::error::Error for JohnsonError {}

/// Solve APSP via Johnson's algorithm.
pub fn solve(w: &DistMatrix) -> Result<DistMatrix, JohnsonError> {
    let n = w.n();
    if n == 0 {
        return Ok(DistMatrix::unconnected(0));
    }
    // adjacency lists once (dense scan; inputs are DistMatrix)
    let mut adj: Vec<Vec<Edge>> = vec![Vec::new(); n];
    for u in 0..n {
        let row = w.row(u);
        for (v, &wt) in row.iter().enumerate() {
            if u != v && wt.is_finite() {
                adj[u].push(Edge { to: v as u32, w: wt });
            }
        }
    }

    let h = bellman_ford_potentials(n, &adj)?;

    // reweight: ŵ(u,v) = w + h[u] − h[v]  (≥ 0 up to f32 rounding)
    let mut radj = adj;
    for (u, edges) in radj.iter_mut().enumerate() {
        for e in edges.iter_mut() {
            e.w = (e.w as f64 + h[u] - h[e.to as usize]).max(0.0) as f32;
        }
    }

    let mut out = DistMatrix::unconnected(n);
    let mut dist = vec![INF; n];
    let mut heap: BinaryHeap<HeapItem> = BinaryHeap::new();
    for src in 0..n {
        dijkstra(&radj, src, &mut dist, &mut heap);
        let row = &mut out.as_mut_slice()[src * n..(src + 1) * n];
        for v in 0..n {
            if dist[v].is_finite() {
                // undo the reweighting
                row[v] = (dist[v] as f64 - h[src] + h[v]) as Dist;
            }
        }
        row[src] = 0.0;
    }
    Ok(out)
}

/// Bellman–Ford from a virtual source connected to every vertex with
/// weight 0; returns the potential vector `h` (f64 for stable reweighting).
fn bellman_ford_potentials(n: usize, adj: &[Vec<Edge>]) -> Result<Vec<f64>, JohnsonError> {
    let mut h = vec![0f64; n]; // virtual source: h starts at 0 everywhere
    for _ in 0..n {
        let mut changed = false;
        for (u, edges) in adj.iter().enumerate() {
            let hu = h[u];
            if !hu.is_finite() {
                continue;
            }
            for e in edges {
                let cand = hu + e.w as f64;
                if cand < h[e.to as usize] - 1e-12 {
                    h[e.to as usize] = cand;
                    changed = true;
                }
            }
        }
        if !changed {
            return Ok(h);
        }
    }
    // one more pass: any improvement now proves a negative cycle
    for (u, edges) in adj.iter().enumerate() {
        for e in edges {
            if h[u] + (e.w as f64) < h[e.to as usize] - 1e-9 {
                return Err(JohnsonError::NegativeCycle(e.to as usize));
            }
        }
    }
    Ok(h)
}

/// Min-heap item (BinaryHeap is a max-heap; reverse the ordering).
#[derive(PartialEq)]
struct HeapItem {
    dist: f32,
    vertex: u32,
}

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.vertex.cmp(&self.vertex))
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Standard lazy-deletion Dijkstra over non-negative weights.
fn dijkstra(adj: &[Vec<Edge>], src: usize, dist: &mut [f32], heap: &mut BinaryHeap<HeapItem>) {
    dist.fill(INF);
    heap.clear();
    dist[src] = 0.0;
    heap.push(HeapItem {
        dist: 0.0,
        vertex: src as u32,
    });
    while let Some(HeapItem { dist: d, vertex: u }) = heap.pop() {
        if d > dist[u as usize] {
            continue; // stale entry
        }
        for e in &adj[u as usize] {
            let cand = d + e.w;
            if cand < dist[e.to as usize] {
                dist[e.to as usize] = cand;
                heap.push(HeapItem {
                    dist: cand,
                    vertex: e.to,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::naive;
    use crate::graph::{generators, DistMatrix};

    fn assert_matches_fw(g: &DistMatrix, tol: f64) {
        let fw = naive::solve(g);
        let jn = solve(g).expect("no negative cycle");
        assert!(
            jn.allclose(&fw, tol, tol),
            "johnson diverges from FW by {}",
            jn.max_abs_diff(&fw)
        );
    }

    #[test]
    fn matches_fw_on_random_graphs() {
        for (n, p, seed) in [(32, 0.1, 1u64), (64, 0.3, 2), (96, 0.05, 3), (48, 0.9, 4)] {
            assert_matches_fw(&generators::erdos_renyi(n, p, seed), 1e-4);
        }
    }

    #[test]
    fn matches_fw_structured() {
        assert_matches_fw(&generators::ring(40), 1e-5);
        assert_matches_fw(&generators::grid(7, 5), 1e-4);
        assert_matches_fw(&generators::scale_free(64, 2, 6), 1e-4);
    }

    #[test]
    fn negative_weights_no_cycle() {
        // reweighting is the whole point: negative edges, no negative cycle
        assert_matches_fw(&generators::layered_dag(6, 8, 7), 1e-3);
    }

    #[test]
    fn negative_cycle_detected() {
        let mut g = DistMatrix::unconnected(4);
        g.set(0, 1, 1.0);
        g.set(1, 2, -3.0);
        g.set(2, 0, 1.0);
        assert!(matches!(solve(&g), Err(JohnsonError::NegativeCycle(_))));
    }

    #[test]
    fn disconnected_and_empty() {
        let g = DistMatrix::unconnected(5);
        let d = solve(&g).unwrap();
        assert_eq!(d, g);
        assert_eq!(solve(&DistMatrix::unconnected(0)).unwrap().n(), 0);
    }

    #[test]
    fn sparse_large_graph_smoke() {
        // the regime Johnson exists for: n=256, ~4 edges/vertex
        let g = generators::erdos_renyi(256, 4.0 / 256.0, 9);
        assert_matches_fw(&g, 1e-4);
    }
}

//! CPU all-pairs-shortest-paths solvers.
//!
//! These serve three roles:
//! 1. the paper's "CPU" baseline (Table 1, column 1) — [`naive`];
//! 2. correctness oracles for the PJRT-executed artifacts — any solver here
//!    cross-checks the device results ([`validate`]);
//! 3. the cache-blocked CPU implementation mirroring Venkataraman et al.
//!    ([`blocked`]) and a multithreaded variant ([`parallel`]) that shows
//!    the same blocking win the paper builds on.
//!
//! All solvers consume a [`crate::graph::DistMatrix`] and return the closed
//! matrix; [`paths`] additionally reconstructs shortest paths via a
//! successor matrix.  The hot phase-3 inner loops of every blocked tier
//! ([`blocked`], [`parallel`], and `crate::superblock::minplus`) share one
//! register-tiled microkernel ([`kernel`]), generic over the closed
//! semiring ([`semiring`]) — the blocked schedule only ever uses
//! `⊕`/`⊗` algebra, so the same tiers serve shortest path `(min, +)`,
//! bottleneck `(max, min)`, minimax `(min, max)`, and transitive closure
//! `(or, and)`; `(min, +)` stays the monomorphized, bitwise-pinned
//! specialization.  The microkernel dispatches at runtime to explicit
//! SIMD lane kernels ([`simd`]: AVX2/AVX-512/NEON, `FW_KERNEL` override,
//! scalar fallback), every one held bitwise to the scalar reference.  [`incremental`] applies edge-weight deltas to an
//! existing `(dist, succ)` closure — the dynamic-graph tier the
//! coordinator serves `"update"` requests with (shortest-only, as is
//! [`johnson`]).

pub mod blocked;
pub mod incremental;
pub mod johnson;
pub mod kernel;
pub mod naive;
pub mod parallel;
pub mod paths;
pub mod semiring;
pub mod simd;
pub mod validate;

pub use validate::{check_invariants, negative_cycle_vertices};
